"""Memory-budgeted storage tier: EntityStore + BufferPool correctness and
the §3.5.2 probe economics (ISSUE 5).

The non-negotiables:
  * eviction NEVER drops a pinned (hot-buffer) page, whatever the budget;
  * `get_row` after an eviction re-reads byte-identical rows from disk;
  * tier counters reconcile — hits + misses + coalesced == probes, and
    the engines' cold `disk_touches` equals the pool's miss count;
  * cold reads run OFF the pool lock: a concurrent miss storm on one
    page coalesces to exactly ONE disk read, eviction never reclaims an
    in-flight frame, and the `Prefetcher` shuts down cleanly;
  * hybrid labels under a tiny (5%) budget are BIT-IDENTICAL to the
    all-in-RAM eager path on the same insert stream.
"""
import numpy as np
import pytest

from repro.core import MulticlassView, sgd_step, zero_model
from repro.core.engine import TIER_DISK, TIER_POOL
from repro.core.hazy import HazyEngine
from repro.data import cora_like, multiclass_example_stream, synthetic_corpus
from repro.storage import BufferPool, EntityStore


def _pool(F, frac, page_bytes=512):
    store = EntityStore.from_array(F, page_bytes=page_bytes)
    return BufferPool(store, max(1, int(frac * F.nbytes)))


def _features(n=96, d=16, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# EntityStore: the mmap'd rows and the page directory are exact
# ---------------------------------------------------------------------------

def test_store_roundtrip_is_byte_exact():
    F = _features()
    store = EntityStore.from_array(F, page_bytes=256)
    assert store.num_pages == -(-store.n // store.rows_per_page)
    for i in range(F.shape[0]):
        pid, slot = store.page_of(i), store.slot_of(i)
        row = store.read_page(pid)[slot]
        assert row.tobytes() == F[i].tobytes(), i
    store.close()
    with pytest.raises(ValueError):
        store.read_page(0)


def test_store_wide_rows_get_one_row_pages():
    F = _features(n=8, d=200)               # stride 800 B > 256 B page
    store = EntityStore.from_array(F, page_bytes=256)
    assert store.rows_per_page == 1 and store.num_pages == 8
    pool = BufferPool(store, store.page_bytes)      # budget: ONE page
    for i in range(8):
        assert pool.get_row(i).tobytes() == F[i].tobytes()
    assert len(pool.frames) == 1 and pool.evictions == 7


# ---------------------------------------------------------------------------
# BufferPool: budget, eviction, pins, warming, counters
# ---------------------------------------------------------------------------

def test_eviction_never_drops_pinned_page():
    F = _features()
    pool = _pool(F, 0.10)                   # room for a few pages only
    budget_pages = pool.budget_bytes // pool.store.page_bytes
    hot_ids = [0, 1, 2]
    pool.repin_rows(hot_ids)
    pinned = set(pool._hot_pins)
    assert pinned                            # the window really pinned pages
    for i in range(F.shape[0]):              # sweep the whole table repeatedly
        pool.get_row(i)
        assert pinned <= set(pool.frames), i
        for pid in pinned:
            assert pool.frames[pid].pin_count > 0
    assert pool.evictions > 0                # the budget really evicted
    assert len(pool.frames) <= budget_pages + 1
    # after unpinning, the pages become evictable again
    pool.repin_rows([])
    for i in range(F.shape[0]):
        pool.get_row(i)
    assert all(fr.pin_count == 0 for fr in pool.frames.values())


def test_repin_keeps_the_full_window_across_reorgs():
    """Regression: repin_rows must release the OLD window's budget claim
    before capping the new one — a full-budget window used to cap its own
    replacement at ~one page, silently unpinning the hot buffer."""
    F = _features()
    pool = _pool(F, 0.30)
    pool.repin_rows(range(0, 24))
    first = list(pool._hot_pins)
    assert len(first) > 1
    for _ in range(3):                       # reorgs with an identical window
        pool.repin_rows(range(0, 24))
        assert list(pool._hot_pins) == first
    # engine-level: the hot window stays fully pinned through reorgs
    c = cora_like(scale=0.15)
    epool = _pool(c.features, 0.10, page_bytes=1024)
    eng = HazyEngine(c.features, p=2.0, q=2.0, policy="hybrid",
                     buffer_frac=0.03, store=epool)
    pinned_after_init = len(epool._hot_pins)
    eng.reorganize()
    eng.reorganize()
    assert len(epool._hot_pins) == pinned_after_init > 0


def test_refresh_features_does_not_close_a_shared_store():
    """Regression: two budgeted views share ONE EntityStore per table (the
    catalog layout); refreshing one view must not brick its sibling."""
    from repro.core import ClassificationView
    F1 = _features(n=128, d=16, seed=5)
    F2 = _features(n=128, d=16, seed=6)
    store = EntityStore.from_array(F1, page_bytes=512)
    pool_a = BufferPool(store, 2048)
    pool_b = BufferPool(store, 2048)
    va = ClassificationView(F1, policy="hybrid", norm=(2.0, 2.0),
                            buffer_frac=0.05, store=pool_a)
    vb = ClassificationView(F1, policy="hybrid", norm=(2.0, 2.0),
                            buffer_frac=0.05, store=pool_b)
    va.refresh_features(entities=F2)
    # sibling pool still reads through the shared store
    assert pool_b.get_row(3).tobytes() == F1[3].tobytes()
    # the refreshed view got a NEW store over the NEW rows, same geometry
    new_pool = va.engine.store
    assert new_pool is not pool_a and new_pool.store is not store
    assert new_pool.store.page_bytes == store.page_bytes
    assert new_pool.budget_bytes == pool_a.budget_bytes
    assert new_pool.get_row(3).tobytes() == F2[3].tobytes()
    assert vb.engine.store is pool_b


def test_pins_alone_never_exceed_budget():
    F = _features()
    pool = _pool(F, 0.10)
    pool.repin_rows(range(F.shape[0]))       # ask to pin EVERYTHING
    assert pool.pinned_bytes() <= pool.budget_bytes
    assert len(pool._hot_pins) >= 1          # but at least one page pinned


def test_get_row_after_eviction_rereads_identical_bytes():
    F = _features()
    pool = _pool(F, 0.08)
    first = pool.get_row(0).copy()
    assert pool.misses == 1
    evicted_reads = pool.store.page_reads
    # flood with rows from OTHER pages until page 0 is evicted
    for i in range(F.shape[0] - 1, pool.store.rows_per_page, -1):
        pool.get_row(i)
    assert not pool.resident(0)
    again = pool.get_row(0)
    assert again.tobytes() == first.tobytes() == F[0].tobytes()
    assert pool.store.page_reads > evicted_reads     # it really re-read disk


def test_counters_reconcile_and_warm_is_not_a_miss():
    F = _features()
    pool = _pool(F, 0.25)
    pool.warm(range(F.shape[0]))             # prefetches, not misses
    assert pool.misses == 0 and pool.prefetches > 0
    assert pool.resident_bytes <= pool.budget_bytes
    n_calls = 0
    rng = np.random.default_rng(3)
    for i in rng.integers(0, F.shape[0], 200):
        pool.get_row(int(i))
        n_calls += 1
    assert pool.hits + pool.misses == pool.probes == n_calls
    st = pool.stats()
    assert st["hits"] == pool.hits and st["misses"] == pool.misses
    assert 0.0 <= st["hit_rate"] <= 1.0


def test_full_budget_pool_never_cold_misses_after_warm():
    F = _features()
    pool = _pool(F, 1.0)
    pool.warm(range(F.shape[0]))
    for i in range(F.shape[0]):
        _, how = pool.touch(i)
        assert how == "pool", i
    assert pool.misses == 0 and pool.evictions == 0


# ---------------------------------------------------------------------------
# Engines over the pool: exactness, pinned hot buffers, tier accounting
# ---------------------------------------------------------------------------

def _drive_multiclass(c, policy, store=None, rounds=15, batch=16):
    view = MulticlassView(c.features, c.num_classes, policy=policy,
                          buffer_frac=0.05, p=2.0, q=2.0, lr=0.1,
                          cost_mode="modeled", store=store)
    stream = multiclass_example_stream(c, seed=13)
    for _ in range(rounds):
        chunk = [next(stream) for _ in range(batch)]
        view.insert_examples([i for i, _ in chunk], [cl for _, cl in chunk])
    return view


def test_hybrid_labels_under_5pct_budget_equal_eager_all_in_ram():
    c = cora_like(scale=0.15)
    pool = _pool(c.features, 0.05, page_bytes=1024)
    hyb = _drive_multiclass(c, "hybrid", store=pool)
    eag = _drive_multiclass(c, "eager")          # all-in-RAM twin, same stream
    assert np.array_equal(hyb.W, eag.W) and np.array_equal(hyb.b, eag.b)
    for i in range(c.features.shape[0]):
        labs, _ = hyb.engine.hybrid_labels_of(i)
        assert np.array_equal(labs, eag.engine.labels_of(i)), i
    # the cold fraction was really bounded by the budgeted pool, not RAM
    assert hyb.engine.disk_touches == pool.misses
    assert hyb.engine.check_consistent()


def test_multiview_tier_counts_reconcile_with_pool():
    c = cora_like(scale=0.15)
    pool = _pool(c.features, 0.10, page_bytes=1024)
    view = _drive_multiclass(c, "hybrid", store=pool)
    eng = view.engine
    h0, p0 = eng.hybrid_hits.copy(), pool.stats()
    rng = np.random.default_rng(7)
    reads = 150
    for i in rng.integers(0, c.features.shape[0], reads):
        v = int(rng.integers(0, c.num_classes))
        eng.hybrid_label(v, int(i))
    dh = eng.hybrid_hits - h0
    assert dh.sum() == reads                 # every probe landed in one tier
    p1 = pool.stats()
    # every buffer/pool/disk probe is exactly one pool call; hits landed on
    # buffer (pinned) + pool tiers, misses are exactly the cold disk tier
    assert (p1["probes"] - p0["probes"]) == dh[1] + dh[TIER_POOL] + dh[TIER_DISK]
    assert (p1["misses"] - p0["misses"]) == dh[TIER_DISK]
    assert (p1["hits"] - p0["hits"]) == dh[1] + dh[TIER_POOL]


def test_hot_buffer_reads_are_pinned_pool_hits():
    c = cora_like(scale=0.15)
    pool = _pool(c.features, 0.10, page_bytes=1024)
    view = _drive_multiclass(c, "hybrid", store=pool)
    eng = view.engine
    assert eng.buffer_F is None              # no separately materialized copy
    probed = 0
    for v in range(eng.k):
        lo, hi = int(eng.buffer_lo[v]), int(eng.buffer_hi[v])
        for pos in range(lo, hi, 3):
            i = int(eng.perm[v, pos])
            misses_before = pool.misses
            lab, how = eng.hybrid_label(v, i)
            if how == "buffer":              # waters may already resolve it,
                probed += 1                  # unpinned tails fall to pool/disk
                # a buffer-tier read is served from a resident (pinned)
                # pool page — NEVER a cold disk read
                assert pool.misses == misses_before, (v, i)
    assert probed > 0


def test_hazy_store_probe_exact_and_cold_counting():
    c = synthetic_corpus("hzst", 400, 24, seed=2)
    pool = _pool(c.features, 0.10, page_bytes=1024)
    eng = HazyEngine(c.features, p=2.0, q=2.0, policy="hybrid",
                     buffer_frac=0.05, store=pool)
    model = zero_model(c.features.shape[1])
    rng = np.random.default_rng(11)
    for _t in range(200):
        i = int(rng.integers(0, c.features.shape[0]))
        model = sgd_step(model, c.features[i], float(c.labels[i]),
                         lr=0.05, l2=1e-3)
        eng.apply_model(model)
    truth = np.where(c.features @ model.w - model.b >= 0, 1, -1)
    tiers = {"water": 0, "buffer": 0, "pool": 0, "disk": 0}
    for i in range(c.features.shape[0]):
        lab, how = eng.hybrid_label(i)
        assert lab == truth[i], (i, how)
        tiers[how] += 1
    assert sum(tiers.values()) == c.features.shape[0]
    assert eng.disk_touches == pool.misses   # cold reads only


# ---------------------------------------------------------------------------
# BufferPool under threads (ISSUE 6): the SQL server probes one shared
# pool from N sessions while commits repin the hot window
# ---------------------------------------------------------------------------

def test_pool_concurrent_probes_never_corrupt_or_evict_pins():
    """Regression for the pre-lock races: (a) two threads admitting the
    same page double-counted resident_bytes, (b) the clock sweep could
    evict a page between another thread's admission and its pin bump, and
    (c) unsynchronized `hits += 1` lost increments. 8 threads hammer ONE
    tiny-budget pool (constant eviction pressure) against a pinned hot
    window: every row byte-exact, no pinned page ever leaves the pool,
    and the counters reconcile exactly with the probes issued."""
    import threading

    F = _features(n=256, d=16, seed=9)
    pool = _pool(F, 0.08)                   # a few pages: sweeps constantly
    pool.repin_rows(range(8))
    pinned = set(pool._hot_pins)
    assert pinned
    probes0 = pool.probes
    per_thread, n_threads = 400, 8
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(per_thread):
                i = int(rng.integers(0, F.shape[0]))
                if pool.get_row(i).tobytes() != F[i].tobytes():
                    errors.append(f"row {i} corrupt")
                    return
                if not pinned <= set(pool.frames):
                    errors.append("pinned page evicted")
                    return
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(repr(e))

    threads = [threading.Thread(target=hammer, args=(100 + t,), daemon=True)
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not any(t.is_alive() for t in threads)
    assert not errors, errors[:3]
    # exact counter reconciliation: no increment was lost to a data race
    # (a probe is a hit, a miss, or coalesced onto another miss's read)
    assert pool.hits + pool.misses + pool.coalesced == pool.probes
    assert pool.probes - probes0 == per_thread * n_threads
    for pid in pinned:
        assert pool.frames[pid].pin_count > 0
    assert pool.in_flight == 0
    assert pool.resident_bytes <= pool.budget_bytes + pool.store.page_bytes
    stats = pool.stats()
    assert (stats["hits"] + stats["misses"] + stats["coalesced"]
            == stats["probes"])
    # coalesced probes share a read: every miss paid one read_page, every
    # coalesced probe paid none (pins/warming are counted separately)
    assert pool.store.page_reads <= pool.misses + pool.prefetches


def test_cold_miss_storm_coalesces_to_one_disk_read():
    """N threads cold-miss ONE page simultaneously: exactly one
    `read_page` hits the store, one probe is the miss, the other N-1 are
    coalesced waiters — and every thread gets byte-exact rows."""
    import threading

    F = _features(n=64, d=16, seed=21)
    store = EntityStore.from_array(F, page_bytes=512)
    pool = BufferPool(store, F.nbytes)
    rows = store.page_row_ids(0)             # all ids on page 0
    n_threads = 8
    start = threading.Barrier(n_threads)
    results, errors = [], []
    inner = store.read_page

    def gated_read(pid):                     # hold the one cold read open
        deadline = 200                       # until every waiter has parked
        while pool.coalesced < n_threads - 1 and deadline:
            threading.Event().wait(0.01)
            deadline -= 1
        return inner(pid)

    store.read_page = gated_read

    def storm(t):
        i = int(rows[t % len(rows)])
        try:
            start.wait()
            row, how = pool.touch(i)
            results.append((i, row.tobytes(), how))
        except Exception as e:              # noqa: BLE001 — surfaced below
            errors.append(repr(e))

    threads = [threading.Thread(target=storm, args=(t,), daemon=True)
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30)
    assert not errors, errors[:3]
    assert len(results) == n_threads
    assert store.page_reads == 1             # THE coalescing guarantee
    assert pool.misses == 1
    assert pool.coalesced == n_threads - 1
    assert pool.hits == 0
    assert pool.in_flight == 0
    for i, raw, how in results:
        assert raw == F[i].tobytes(), i
        assert how == "disk"                 # miss AND waiters: cold tier


def test_eviction_never_reclaims_in_flight_frames():
    """The clock sweep must skip placeholder (data=None) frames: an
    in-flight page under budget pressure survives until its loader
    publishes, and the waiter still gets exact bytes."""
    import threading

    F = _features(n=64, d=16, seed=22)
    store = EntityStore.from_array(F, page_bytes=512)
    pool = BufferPool(store, store.page_bytes)       # budget: ONE page
    gate = threading.Event()
    inner = store.read_page

    def slow_read(pid):
        gate.wait(10)                        # hold page 0's read open
        return inner(pid)

    store.read_page = slow_read
    t = threading.Thread(target=lambda: pool.get_row(0), daemon=True)
    t.start()
    while pool.in_flight == 0:               # loader installed, now blocked
        pass
    store.read_page = inner                  # other pages read normally
    pool.get_row(int(store.page_row_ids(1)[0]))      # forces a sweep
    with pool._lock:
        assert 0 in pool.frames              # placeholder NOT evicted
        assert pool.frames[0].data is None
    gate.set()
    t.join(30)
    assert not t.is_alive()
    assert pool.get_row(0).tobytes() == F[0].tobytes()
    assert pool.in_flight == 0


def test_read_pages_batches_are_byte_exact():
    F = _features(n=96, d=16, seed=23)
    store = EntityStore.from_array(F, page_bytes=256)
    assert store.num_pages >= 8
    pids = [0, 1, 2, 5, 7, 3, 4]             # contiguous runs + scatter
    before = store.page_reads
    pages = store.read_pages(pids)
    assert store.page_reads - before == len(pids)
    for pid, page in zip(pids, pages):
        assert page.tobytes() == store.read_page(pid).tobytes(), pid


def test_prefetcher_readahead_counters_and_clean_shutdown():
    from repro.storage import Prefetcher

    F = _features(n=256, d=16, seed=24)
    pool = _pool(F, 0.50)
    pre = Prefetcher(pool, batch_pages=4)
    assert pool.prefetcher is pre and pre.alive
    pre.enqueue(range(64), evict=True)       # streaming readahead
    assert pre.drain(10)
    assert pool.readahead_pages > 0
    used0 = pool.readahead_used
    pool.get_row(0)                          # consume a readahead page
    assert pool.readahead_used == used0 + 1
    assert pool.hits >= 1                    # readahead turned it into a hit
    st = pool.stats()
    assert 0.0 <= st["readahead_hit_rate"] <= 1.0
    assert st["readahead_pages"] == pool.readahead_pages
    pre.close()
    assert not pre.alive                     # no dangling thread
    assert pool.prefetcher is None
    pre.close()                              # idempotent


def test_prefetcher_warm_mode_respects_budget_and_pins():
    from repro.storage import Prefetcher

    F = _features(n=256, d=16, seed=25)
    pool = _pool(F, 0.10)
    pool.repin_rows(range(8))
    pinned = set(pool._hot_pins)
    pre = Prefetcher(pool)
    pre.enqueue(range(F.shape[0]))           # warm semantics: stop at budget
    assert pre.drain(10)
    assert pool.resident_bytes <= pool.budget_bytes
    assert pinned <= set(pool.frames)        # pins untouched
    for pid in pinned:
        assert pool.frames[pid].pin_count > 0
    # streaming mode may overshoot transiently but sweeps back per batch
    pre.enqueue(range(F.shape[0]), evict=True)
    assert pre.drain(10)
    assert pinned <= set(pool.frames)
    assert (pool.resident_bytes
            <= pool.budget_bytes + pre.batch_pages * pool.store.page_bytes)
    pre.close()
