"""Hypothesis property tests for the system's invariants.

The golden invariants of the paper:
  P1 (safety, Lemma 3.1): entities outside [lw, hw] NEVER change label
     between reorganizations.
  P2 (view exactness): after any update/reorg interleaving, the maintained
     view equals a from-scratch relabel under the current model.
  P3 (SKIING competitiveness): cost(SKIING) <= (1+alpha+sigma)*OPT + O(S)
     on any monotone cost matrix.
  P4 (waters monotonicity, Eq. 2): lw non-increasing, hw non-decreasing
     between reorganizations.
"""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (HazyEngine, LinearModel, Waters,
                        holder_M, opt_cost, skiing_schedule, sgd_step,
                        zero_model)

DIMS = st.integers(min_value=2, max_value=12)


def _rand_floats(r, shape, scale=1.0):
    return (r.standard_normal(shape) * scale).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS,
       pq=st.sampled_from([(2.0, 2.0), (math.inf, 1.0)]))
def test_p1_safety_outside_band(seed, d, pq):
    p, q = pq
    r = np.random.default_rng(seed)
    F = _rand_floats(r, (64, d))
    M = holder_M(F, q)
    stored = LinearModel(_rand_floats(r, d, 0.5), float(r.normal()))
    waters = Waters(p=p, M=M)
    eps_stored = F @ stored.w - stored.b
    labels_at_store = eps_stored >= 0
    cur = stored.copy()
    for _ in range(5):
        cur = LinearModel(cur.w + _rand_floats(r, d, 0.05),
                          cur.b + float(r.normal() * 0.02))
        lw, hw = waters.update(cur, stored)
        eps_cur = F @ cur.w - cur.b
        safe_pos = eps_stored >= hw
        safe_neg = eps_stored <= lw
        assert np.all(eps_cur[safe_pos] >= 0)
        assert np.all(eps_cur[safe_neg] < 0)
        # P4 monotonicity
        assert waters.lw <= 0.0 <= waters.hw or waters.lw <= waters.hw


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_updates=st.integers(5, 60),
       alpha=st.sampled_from([0.5, 1.0, 2.0]))
def test_p2_view_exactness(seed, n_updates, alpha):
    r = np.random.default_rng(seed)
    d = 8
    F = _rand_floats(r, (256, d))
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
    eng = HazyEngine(F, p=2.0, q=2.0, alpha=alpha, policy="eager",
                     cost_mode="modeled")
    model = zero_model(d)
    for _ in range(n_updates):
        f = F[int(r.integers(0, 256))]
        y = float(r.choice([-1.0, 1.0]))
        model = sgd_step(model, f, y, lr=0.1, l2=1e-3)
        eng.apply_model(model)
    assert eng.check_consistent()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 40),
       sigma=st.sampled_from([0.25, 0.5, 1.0]))
def test_p3_skiing_competitive(seed, n, sigma):
    """Random monotone costs with the paper's §3.3 assumptions: c(s,i)
    nondecreasing in i for fixed s, and c <= sigma*S (an incremental step
    never costs more than a scan). With alpha = alpha_star(sigma), Lemma 3.2
    gives ratio (1 + alpha + sigma); finite horizons add O(S) edge slack."""
    from repro.core import alpha_star
    r = np.random.default_rng(seed)
    S = 1.0
    # §3.3 requires BOTH: (i) c(s,i) nondecreasing in i for fixed s, and
    # (ii) c(s,i) <= c(s',i) for s >= s' (a fresher reorg never costs more).
    # c(s,i) = g(i - s) with g a random nondecreasing function satisfies both.
    incr = r.uniform(0.0, 0.15, size=n + 1)
    g = np.minimum(np.cumsum(incr), sigma * S)

    def costs(s, i):
        return float(g[i - s])

    alpha = alpha_star(sigma)
    _, total = skiing_schedule(costs, n, S, alpha=alpha)
    opt = opt_cost(costs, n, S)
    assert total <= (1 + alpha + sigma) * opt + 3 * S + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS)
def test_sgd_reduces_hinge_on_example(seed, d):
    r = np.random.default_rng(seed)
    f = _rand_floats(r, d)
    f /= max(np.linalg.norm(f), 1e-9)
    y = float(r.choice([-1.0, 1.0]))
    m = LinearModel(_rand_floats(r, d, 0.1), 0.0)
    z0 = y * (f @ m.w - m.b)
    m2 = sgd_step(m, f, y, lr=0.1, l2=0.0)
    z1 = y * (f @ m2.w - m2.b)
    if z0 < 1.0:           # active hinge: margin must improve
        assert z1 > z0
    else:                  # inactive: model unchanged (l2=0)
        assert np.allclose(m2.w, m.w) and m2.b == m.b


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_random_features_approximate_kernel(seed):
    from repro.core import RandomFeatures
    from repro.core.random_features import gaussian_kernel
    r = np.random.default_rng(seed)
    X = _rand_floats(r, (20, 6))
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)
    rf = RandomFeatures(6, 2048, sigma=1.0, seed=seed)
    Z = rf(X)
    K_approx = Z @ Z.T
    K_true = gaussian_kernel(X, X, sigma=1.0)
    assert np.max(np.abs(K_approx - K_true)) < 0.15
