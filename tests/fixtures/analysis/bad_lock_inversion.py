"""Deliberately inverted pool -> commit acquisition.

Caught twice: statically (LCK001 at the `self.log.flush()` call — the
call graph sees `flush` take the commit lock while the pool lock is
held) and live (the runtime witness raises LockOrderError when
`evict_and_commit` runs with `repro.analysis.witness` enabled).
EXECUTABLE on purpose — tests/test_analysis.py actually runs it.
"""
import threading

from repro.analysis.witness import wrap


class UpdateLog:
    def __init__(self):
        self._commit_lock = wrap(threading.RLock(), "wal_commit")

    def flush(self):
        with self._commit_lock:
            return 1


class BufferPool:
    def __init__(self):
        self._lock = wrap(threading.RLock(), "pool")
        self.log = UpdateLog()

    def evict_and_commit(self):
        with self._lock:                   # pool, level 2, held ...
            return self.log.flush()        # ... acquires wal_commit, level 1
