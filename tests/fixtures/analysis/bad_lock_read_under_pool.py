"""Disk page reads inlined under the pool lock (LCK004) — the exact
regression the async read path removed: a cold mmap copy serializing
every concurrent probe behind `_lock`."""
import threading

from repro.analysis.witness import wrap


class EntityStore:
    def __init__(self, pages):
        self.pages = pages
        self.page_reads = 0

    def read_page(self, pid):
        self.page_reads += 1
        return self.pages[pid]

    def read_pages(self, pids):
        self.page_reads += len(pids)
        return [self.pages[p] for p in pids]


class BufferPool:
    def __init__(self, store):
        self.store = store
        self._lock = wrap(threading.RLock(), "pool")
        self.frames = {}

    def touch(self, pid):
        with self._lock:                           # every concurrent probe
            data = self.store.read_page(pid)       # stalls on this cold read
            self.frames[pid] = data
            return data

    def _admit_all(self, pids):
        return self.store.read_pages(pids)         # blocking, via callee

    def warm(self, pids):
        with self._lock:
            for pid, data in zip(pids, self._admit_all(pids)):
                self.frames[pid] = data
