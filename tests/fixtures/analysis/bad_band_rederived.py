"""Lemma 3.1 band partition re-derived outside core/engine.py (SRC001)."""
import numpy as np


def count_certain(eps, lw, hw):
    band = (eps >= lw) & (eps < hw)            # re-derived band mask
    n_pos = int(np.count_nonzero(eps >= hw))   # re-derived certain-positive
    return band, n_pos


def band_lo(eps_sorted, lw):
    return int(np.searchsorted(eps_sorted, lw))  # re-derived partition edge
