"""A shell reaching into an `EngineState` it was handed (PUR004)."""


def clamp_band(state, idx):
    state.labels[idx] = -1                     # in-place pytree mutation
    state.hw = 0.0                             # rebinding a frozen field
    return state
