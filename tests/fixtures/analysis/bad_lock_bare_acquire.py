"""Bare `.acquire()` without the try/finally release shape (LCK002)."""
import threading

from repro.analysis.witness import wrap


class BufferPool:
    def __init__(self):
        self._lock = wrap(threading.RLock(), "pool")
        self.frames = {}

    def unsafe_touch(self, pid):
        self._lock.acquire()               # an exception here leaks the lock
        value = self.frames.get(pid)
        self._lock.release()
        return value
