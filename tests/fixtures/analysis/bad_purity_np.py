"""Host numpy + side effects inside an xp-parameterized pure step
(PUR001/PUR002) and in-place parameter mutation (PUR003)."""
import numpy as np


def relabel_step(eps, labels, xp=np):
    flipped = xp.where(eps >= 0, 1, -1)
    total = np.cumsum(flipped)                 # host numpy, unguarded
    print("relabeled", int(total[-1]))         # side effect under jit
    labels[0] = 1                              # mutates its argument
    return flipped, total[-1].item()           # host sync
