"""Deliberately-broken fixture: raw wall-clock calls outside repro.obs
(TEL001). Line numbers are pinned by tests/test_analysis.py."""
import time
from time import perf_counter


def measure_step(fn):
    t0 = time.perf_counter()                       # TEL001 (line 8)
    fn()
    elapsed = time.time() - t0                     # TEL001 (line 10)
    return elapsed


def measure_bare(fn):                              # bare imported name
    t0 = perf_counter()                            # TEL001 (line 15)
    fn()
    return perf_counter() - t0                     # TEL001 (line 17)


def fine(fn):
    clock = time.perf_counter                      # alias, not a call: OK
    time.sleep(0.0)                                # not a measurement: OK
    t0 = clock()
    fn()
    return clock() - t0
