"""SKIING charge/trigger arithmetic re-derived outside engine (SRC002)."""


class Maintainer:
    def __init__(self, alpha, size):
        self.alpha = alpha
        self.size = size
        self.acc = 0.0

    def record(self, cost):
        self.acc += cost                       # re-derived skiing_charge
        return self.acc >= self.alpha * self.size  # re-derived skiing_due
