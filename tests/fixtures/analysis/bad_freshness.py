"""A module forking freshness semantics on its own (FRS001): it
re-derives refresh order from the raw DAG edges and flips view runtime
state without going through the scheduler's gate section."""


def sneak_refresh(catalog, vd, batch):
    order = [up for up in vd.upstreams]            # raw edge access
    for child in catalog.views[vd.name].downstreams:
        order.append(child)
    vd.runtime.inbox.append(batch)                 # hand-delivered batch
    vd.runtime.stale_since = None                  # forged freshness stamp
    vd.runtime.suspended = True                    # suspend, no scheduler
    vd.runtime.rows_applied += len(batch)
    return order
