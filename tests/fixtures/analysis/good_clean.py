"""Known-good: every near-miss idiom the rules must stay quiet on."""
import threading

from repro.analysis.witness import wrap
from repro.core.engine import band_partition, skiing_due


class UpdateLog:
    def __init__(self):
        self._commit_lock = wrap(threading.RLock(), "wal_commit")

    def append(self):
        with self._commit_lock:
            return self.flush()            # same-RLock reentry: legal

    def flush(self):
        with self._commit_lock:
            return 1


class BufferPool:
    def __init__(self):
        self._lock = wrap(threading.RLock(), "pool")
        self.frames = {}

    def admit(self, pid):
        with self._lock:
            self.frames[pid] = pid         # plain dict work: not blocking
            return len(self.frames)


class Engine:
    def __init__(self):
        self.log = UpdateLog()
        self.pool = BufferPool()

    def commit(self):
        with self.log._commit_lock:        # wal_commit (1) -> pool (2):
            return self.pool.admit(0)      # the declared downward order


def band_count(eps_sorted, lw, hw):
    lo, hi = band_partition(eps_sorted, lw, hw)   # bounds as ARGUMENTS
    return int(hi - lo)


def due(acc, alpha, size):
    return skiing_due(acc, alpha, size)           # delegation, no arithmetic
