"""Blocking file I/O while holding the pool lock (LCK003)."""
import threading

from repro.analysis.witness import wrap


class BufferPool:
    def __init__(self, path):
        self._lock = wrap(threading.RLock(), "pool")
        self.path = path

    def read_cold(self):
        with self._lock:                   # every concurrent probe now
            with open(self.path) as fh:    # waits on this disk read
                return fh.read()
