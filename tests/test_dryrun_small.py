"""Dry-run machinery on a tiny fake-device mesh (CI-scale twin of the
512-device production dry-run): lower+compile smoke archs on a (2,4) mesh,
assert cost/memory/collective extraction works and the loop-correction
composes."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900, env=env)
    if (out.returncode == -11 and not out.stderr.strip()
            and not os.environ.get("REPRO_STRICT_SUBPROCESS")):
        # XLA CPU segfault compiling large programs on fake-device meshes:
        # a jaxlib/kernel interaction on some hosts, not a property of the
        # code under test (see ROADMAP open items). Set
        # REPRO_STRICT_SUBPROCESS=1 to turn these skips into failures.
        pytest.skip("jaxlib segfault (SIGSEGV) in XLA compile on this host")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_small_mesh_train_lower_compile_and_metrics():
    out = _run("""
        import dataclasses, jax
        from repro.configs import smoke_config, ShapeConfig
        from repro.models import build
        from repro.models.steps import batch_specs, make_train_step, train_state_specs
        from repro.launch.hlo_stats import collective_bytes
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = dataclasses.replace(smoke_config("granite-3-2b"),
                                  d_model=64, num_heads=8, num_kv_heads=4)
        mdl = build(cfg)
        shape = ShapeConfig("t", 64, 4, "train")
        with mesh:
            state = train_state_specs(mdl, mesh)
            batch = batch_specs(cfg, shape, mesh)
            comp = jax.jit(make_train_step(mdl)).lower(state, batch).compile()
        ca = comp.cost_analysis()
        assert ca["flops"] > 0
        coll = collective_bytes(comp.as_text())
        assert coll["total"] > 0          # TP must produce collectives
        ma = comp.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        print("SMALL_DRYRUN_OK", int(ca["flops"]), coll["total"])
    """)
    assert "SMALL_DRYRUN_OK" in out


def test_loop_correction_matches_unrolled():
    """corrected flops from the block-composition must match a fully
    python-unrolled model's raw cost_analysis (within a few %)."""
    out = _run("""
        import dataclasses, jax
        from repro.configs import smoke_config, ShapeConfig
        from repro.models import build
        from repro.models.steps import batch_specs, make_train_step, train_state_specs
        from repro.launch.analysis import corrected_cell_metrics
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((2, 2), ("data", "model"))
        base = dataclasses.replace(smoke_config("granite-3-2b"),
                                   num_layers=4, d_model=64,
                                   num_heads=4, num_kv_heads=2)
        shape = ShapeConfig("t", 64, 4, "train")

        def flops(cfg):
            mdl = build(cfg)
            with mesh:
                state = train_state_specs(mdl, mesh)
                batch = batch_specs(cfg, shape, mesh)
                comp = jax.jit(make_train_step(mdl)).lower(state, batch).compile()
            return mdl, comp.cost_analysis()["flops"]

        mdl_scan, f_scan = flops(base)
        _, f_unroll = flops(dataclasses.replace(base, scan_layers=False,
                                                unroll_inner_scans=True))
        with mesh:
            corr = corrected_cell_metrics(
                mdl_scan, shape, mesh,
                {"flops": f_scan, "bytes": 0.0, "coll": 0.0}, "train")
        got = corr["corrected"]["flops"]
        rel = abs(got - f_unroll) / f_unroll
        print("CORRECTION_REL", rel)
        assert rel < 0.05, (got, f_unroll)
    """)
    assert "CORRECTION_REL" in out
