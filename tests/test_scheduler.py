"""Freshness scheduler: TARGET_LAG views, SUSPEND/RESUME, views-over-views.

The load-bearing property (ISSUE 10): freshness scheduling changes WHEN
maintenance happens, never WHAT it computes. A lagged view, a suspended-
then-resumed view, and a whole derived cascade must all land on labels
and models bit-identical to an immediate (on-commit) replay of the same
stream at the same commit boundaries — the scheduler only moves the work
in time. Everything runs with cost_mode=modeled so engine reorganization
is deterministic; freshness time runs on an injected modeled clock.
"""
import threading
import time

import numpy as np
import pytest

from repro.data import synthetic_corpus
from repro.rdbms import Catalog, Executor, PlanError
from repro.rdbms.options import DOWNSTREAM, parse_lag
from repro.scheduler import FreshnessScheduler
from repro.scheduler import refresh as fr


class FakeClock:
    """Deterministic freshness time: advances only when told."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += float(dt)
        return self.t


def _cascade(seed=7, *, lags=("2 s", "downstream", None), group=8,
             n=240, d=12):
    """table t -> root view a -> derived b -> derived c, with the given
    target lags (None = immediate), on a modeled freshness clock."""
    c = synthetic_corpus("sched", n, d, seed=seed)
    catalog = Catalog()
    clock = FakeClock()
    catalog.clock = clock
    catalog.register_table("t", c.features, truth=c.labels)
    opts = {"policy": "eager", "cost_mode": "modeled"}
    for name, parent, lag in (("a", "t", lags[0]), ("b", "a", lags[1]),
                              ("c", "b", lags[2])):
        o = dict(opts)
        if lag is not None:
            o["target_lag"] = lag
        catalog.create_view(name, parent, "svm", o)
    ex = Executor(catalog, group_commit=group)
    return c, catalog, clock, ex


def _stream(ex, corpus, count, *, start=0):
    for j in range(start, start + count):
        i = j % corpus.features.shape[0]
        ex.execute_one(f"INSERT INTO t (id, label) VALUES "
                       f"({i}, {int(corpus.labels[i])})")


def _state(catalog, name):
    """Bit-comparable state of one view: labels, model, waters, counts."""
    vd = catalog.view(name)
    v = vd.facade.view
    n = v.F.shape[0]
    return (np.array([vd.facade.label(i) for i in range(n)], np.int8),
            v.model.w.copy(), float(v.model.b),
            tuple(float(x) for w in vd.facade.waters() for x in w),
            vd.facade.counts().copy(), v.engine.stats.rounds)


def _assert_same_state(catalog_a, catalog_b, names=("a", "b", "c")):
    for name in names:
        sa, sb = _state(catalog_a, name), _state(catalog_b, name)
        np.testing.assert_array_equal(sa[0], sb[0], err_msg=f"{name} labels")
        np.testing.assert_array_equal(sa[1], sb[1], err_msg=f"{name} w")
        assert sa[2] == sb[2], f"{name} bias"
        assert sa[3] == sb[3], f"{name} waters"
        np.testing.assert_array_equal(sa[4], sb[4], err_msg=f"{name} counts")
        assert sa[5] == sb[5], f"{name} rounds"


# ---------------------------------------------------------------------------
# DDL surface: typed options, lag parsing, DAG registration, cycles
# ---------------------------------------------------------------------------

def test_parse_lag_units_and_errors():
    assert parse_lag("5 s") == 5.0
    assert parse_lag("500 ms") == 0.5
    assert parse_lag("2 m") == 120.0
    assert parse_lag(3) == 3.0
    assert parse_lag("downstream") is DOWNSTREAM
    assert parse_lag(None) is None
    with pytest.raises(PlanError):
        parse_lag("fortnight")
    with pytest.raises(PlanError):
        parse_lag("-2 s")
    with pytest.raises(PlanError):
        parse_lag(0)


def test_create_derived_view_registers_dag_edge():
    _c, catalog, _clock, _ex = _cascade()
    b = catalog.view("b")
    assert b.source == "a" and b.table == "t"     # resolves to the ROOT
    assert [v.name for v in catalog.parents_of("b")] == ["a"]
    assert [v.name for v in catalog.children_of("a")] == ["b"]
    assert [v.name for v in catalog.topo_order()] == ["a", "b", "c"]
    assert b.facade.d == 1                        # the margin column
    assert not b.facade.supports_delete


def test_cycle_rejected_at_create():
    c = synthetic_corpus("cyc", 64, 8, seed=3)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    with pytest.raises(PlanError, match="cycle"):
        catalog.create_view("self", "self", "svm", {})
    catalog.create_view("root", "t", "svm", {"cost_mode": "modeled"})
    ex = Executor(catalog)
    with pytest.raises(PlanError, match="cycle"):
        ex.execute_one("CREATE CLASSIFICATION VIEW loop ON loop "
                       "USING MODEL svm")
    # a straight chain is NOT a cycle
    catalog.create_view("kid", "root", "svm", {"cost_mode": "modeled"})
    assert [v.name for v in catalog.topo_order()] == ["root", "kid"]


def test_derived_view_restrictions():
    _c, catalog, _clock, _ex = _cascade()
    with pytest.raises(PlanError, match="margin column"):
        catalog.create_view("d1", "a", "svm", {"k": 3})
    with pytest.raises(PlanError, match="engine=hazy"):
        catalog.create_view("d2", "a", "svm", {"engine": "sharded"})
    with pytest.raises(PlanError, match="in RAM"):
        catalog.create_view("d3", "a", "svm", {"memory_budget": 0.5})


def test_alter_set_is_schema_checked():
    _c, catalog, _clock, ex = _cascade()
    ex.execute_one("ALTER VIEW c SET (target_lag = '3 s')")
    assert catalog.view("c").options.target_lag == 3.0
    with pytest.raises(PlanError, match="alterable"):
        ex.execute_one("ALTER VIEW c SET (policy = lazy)")   # CREATE-only
    with pytest.raises(PlanError, match="valid view option"):
        ex.execute_one("ALTER VIEW c SET (bogus = 1)")
    with pytest.raises(PlanError):
        ex.execute_one("ALTER VIEW c SET (target_lag = 'soon')")


def test_downstream_lag_propagation():
    _c, catalog, _clock, ex = _cascade(lags=("downstream", "downstream",
                                             "2 s"))
    assert catalog.effective_lag("c") == 2.0
    assert catalog.effective_lag("b") == 2.0      # derived from consumer
    assert catalog.effective_lag("a") == 2.0
    ex.execute_one("ALTER VIEW c SET (target_lag = '500 ms')")
    assert catalog.effective_lag("a") == 0.5      # tightens transitively
    # no numeric consumer anywhere -> the chain degrades to immediate
    _c2, catalog2, _cl2, _ex2 = _cascade(lags=("downstream", "downstream",
                                               None))
    assert catalog2.effective_lag("a") is None
    assert not fr.is_scheduled(catalog2, catalog2.view("a"))


# ---------------------------------------------------------------------------
# semantics: lagged == immediate at the same commit boundaries
# ---------------------------------------------------------------------------

def test_lagged_cascade_bit_identical_to_immediate_replay():
    """The acceptance property: a 3-view cascade under target_lag, with
    refreshes happening whenever the scheduler decides, lands bit-
    identical (labels, model, waters, counts, ROUNDS) to the same stream
    into an identical immediate cascade — after one freshness barrier on
    each side."""
    c, lagged, clock, ex_l = _cascade(lags=("2 s", "downstream", "500 ms"))
    _c2, immediate, _clk, ex_i = _cascade(lags=(None, None, None))
    sched = FreshnessScheduler(ex_l, clock=clock)
    for round_no in range(6):
        _stream(ex_l, c, 24, start=24 * round_no)
        _stream(ex_i, c, 24, start=24 * round_no)
        clock.advance(0.4)
        sched.tick()                    # refreshes only what is due
    ex_l.execute_one("COMMIT")
    ex_i.execute_one("COMMIT")
    ex_l.refresh_views()                # freshness barrier on both sides
    ex_i.refresh_views()
    _assert_same_state(lagged, immediate)


def test_scheduler_is_deterministic_under_modeled_clock():
    """Same stream + same lags + same clock advances => the same tick-by-
    tick refresh schedule and the same final state, run-to-run."""
    def run():
        c, catalog, clock, ex = _cascade(lags=("2 s", "downstream", "1 s"))
        sched = FreshnessScheduler(ex, clock=clock)
        rng = np.random.default_rng(11)
        for step in range(40):
            _stream(ex, c, int(rng.integers(1, 7)), start=step * 7)
            clock.advance(float(rng.uniform(0.05, 0.6)))
            sched.tick()
        ex.execute_one("COMMIT")
        ex.refresh_views()
        return sched.schedule_log, catalog

    log1, cat1 = run()
    log2, cat2 = run()
    assert log1 == log2
    assert any(names for _, names in log1)        # it actually refreshed
    _assert_same_state(cat1, cat2)


def test_refresh_runs_in_topological_order():
    c, catalog, clock, ex = _cascade(lags=("2 s", "2 s", "2 s"))
    _stream(ex, c, 16)
    ex.execute_one("COMMIT")
    clock.advance(5.0)
    names = ex.refresh_views()
    order = {n: i for i, n in enumerate(names)}
    assert order["a"] < order["b"] < order["c"]
    # a single leaf refresh drains its ancestors first, in order
    _stream(ex, c, 16, start=16)
    ex.execute_one("COMMIT")
    clock.advance(5.0)
    assert ex.refresh_views("c") == ["a", "b", "c"]


def test_suspend_freezes_resume_catches_up_exactly_once():
    """SUSPEND freezes labels while base updates queue; RESUME replays
    the queued batches once, bit-identical to never having suspended."""
    c, suspended, clock, ex_s = _cascade(lags=("2 s", None, None))
    _c2, straight, _clk, ex_n = _cascade(lags=("2 s", None, None))
    _stream(ex_s, c, 24)
    _stream(ex_n, c, 24)
    ex_s.execute_one("COMMIT")
    ex_n.execute_one("COMMIT")
    ex_s.refresh_views()
    ex_n.refresh_views()

    ex_s.execute_one("ALTER VIEW a SUSPEND")
    frozen = _state(suspended, "a")
    _stream(ex_s, c, 40, start=24)
    _stream(ex_n, c, 40, start=24)
    ex_s.execute_one("COMMIT")
    ex_n.execute_one("COMMIT")
    assert "a" not in ex_s.refresh_views()        # suspended: stays frozen
    after_commits = _state(suspended, "a")
    np.testing.assert_array_equal(frozen[0], after_commits[0])
    assert frozen[5] == after_commits[5]          # no hidden rounds
    rt = suspended.view("a").runtime
    clock.advance(3.0)
    assert rt.inbox_rows() == 40 and rt.staleness(clock()) > 0

    ex_s.execute_one("ALTER VIEW a RESUME")       # catches up EXACTLY once
    assert suspended.view("a").runtime.inbox_rows() == 0
    ex_s.refresh_views()                          # barrier on both sides
    ex_n.refresh_views()
    _assert_same_state(suspended, straight)
    # resuming again is a no-op round-wise (nothing queued)
    rounds = _state(suspended, "a")[5]
    ex_s.execute_one("ALTER VIEW a RESUME")
    assert _state(suspended, "a")[5] == rounds


def test_suspended_ancestor_blocks_descendants():
    c, catalog, clock, ex = _cascade(lags=("2 s", "2 s", "2 s"))
    ex.execute_one("ALTER VIEW b SUSPEND")
    _stream(ex, c, 16)
    ex.execute_one("COMMIT")
    clock.advance(10.0)
    names = ex.refresh_views()
    assert "a" in names and "b" not in names
    # c cannot become fresh while b dams the stream: staleness sticks
    assert catalog.view("c").runtime.stale_since is not None
    assert fr.upstream_blocked(catalog, catalog.view("c"))
    sched = FreshnessScheduler(ex, clock=clock)
    assert catalog.view("c") not in sched.due(clock())
    ex.execute_one("ALTER VIEW b RESUME")
    ex.refresh_views()
    assert catalog.view("c").runtime.stale_since is None


def test_delete_rejected_on_scheduled_or_derived_views():
    # derived views downstream: rejected at plan time (supports_delete)
    c, _catalog, _clock, ex = _cascade(lags=("2 s", None, None))
    _stream(ex, c, 8)
    with pytest.raises(Exception, match="cannot"):
        ex.execute_one("DELETE FROM t WHERE id = 3")
    # no derived views, but the one view is LAGGED: the footnote-2 retrain
    # cannot replay through an inbox, so the flush itself refuses
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("solo", "t", "svm",
                        {"cost_mode": "modeled", "target_lag": "2 s"})
    ex2 = Executor(catalog, group_commit=4)
    _stream(ex2, c, 4)
    with pytest.raises(Exception, match="immediate"):
        ex2.execute_one("DELETE FROM t WHERE id = 3")
        ex2.execute_one("COMMIT")      # the flush carries the rejection


# ---------------------------------------------------------------------------
# surfaces: SHOW VIEWS / SHOW SCHEDULE / metrics / wire barrier
# ---------------------------------------------------------------------------

def test_show_views_and_schedule_surfaces():
    c, catalog, clock, ex = _cascade(lags=("2 s", "downstream", None))
    res = ex.execute_one("SHOW VIEWS")
    rows = {r[0]: r for r in res.rows}
    assert res.columns[:2] == ("view", "on")
    assert rows["a"][1] == "t" and rows["b"][1] == "a"
    assert rows["a"][4] == "scheduled" and rows["c"][4] == "immediate"
    # b declares 'downstream' but its only consumer is immediate, so the
    # chain degrades: declared lag shown verbatim, effective lag '-'
    assert rows["b"][5] == "downstream" and rows["b"][6] == "-"
    assert rows["b"][4] == "immediate"
    _stream(ex, c, 8)
    ex.execute_one("COMMIT")
    clock.advance(1.0)
    sched_rows = {r[0]: r for r in ex.execute_one("SHOW SCHEDULE").rows}
    cols = ex.execute_one("SHOW SCHEDULE").columns
    staleness = dict(zip(cols, sched_rows["a"]))
    assert staleness["staleness_s"] == pytest.approx(1.0)
    assert staleness["inbox_rows"] == 8
    assert staleness["priority"] != "-"
    ex.execute_one("ALTER VIEW a SUSPEND")
    rows = {r[0]: r for r in ex.execute_one("SHOW VIEWS").rows}
    assert rows["a"][4] == "suspended"
    # the freshness ledger also rides the unified metrics snapshot
    snap = ex.metrics_snapshot()
    assert {r["view"] for r in snap["schedule"]} == {"a", "b", "c"}


def test_daemon_thread_keeps_staleness_under_lag():
    """Live mode: a real daemon thread + real clock on a small cascade —
    observed staleness stays under the effective lag while a stream
    commits, and the refresher honors gate < wal_commit < pool under the
    runtime lock witness (exercised via a memory-budgeted root view)."""
    from repro.analysis import witness

    with witness.enabled():
        c = synthetic_corpus("live", 240, 12, seed=5)
        catalog = Catalog()
        catalog.register_table("t", c.features, truth=c.labels)
        catalog.create_view("a", "t", "svm",
                            {"policy": "eager", "cost_mode": "modeled",
                             "memory_budget": 0.5, "target_lag": "2 s"})
        catalog.create_view("b", "a", "svm",
                            {"cost_mode": "modeled",
                             "target_lag": "downstream"})
        ex = Executor(catalog, group_commit=8)
        errors = []
        done = threading.Event()

        def ticker(sched):
            try:
                while not done.is_set():
                    sched.tick()
                    done.wait(0.005)
            except Exception as e:      # LockOrderError included
                errors.append(e)

        sched = FreshnessScheduler(ex, interval=0.01)
        worker = threading.Thread(target=ticker, args=(sched,))
        worker.start()
        peak = 0.0
        for j in range(120):
            i = j % 240
            ex.execute_one(f"INSERT INTO t (id, label) VALUES "
                           f"({i}, {int(c.labels[i])})")
            time.sleep(0.012)           # ~1.5 s of stream: past headroom
            now = catalog.clock()
            for vd in catalog.topo_order():
                if catalog.effective_lag(vd.name) is not None:
                    peak = max(peak, vd.runtime.staleness(now))
        done.set()
        worker.join(timeout=30)
        assert not worker.is_alive()
        assert errors == []
        assert peak <= 2.0, f"staleness {peak:.3f}s blew the 2 s lag"
        assert ex.metrics.counter("scheduler.refreshes").value > 0
        ex.refresh_views()


def test_wire_refresh_barrier_and_typed_client():
    """The wire `refresh` op is a freshness barrier; the redesigned
    client surface (alter_view/suspend/resume/refresh/show) drives the
    whole lifecycle; legacy query()/execute() emit identical frames."""
    from repro.rdbms import start_server_thread
    from repro.rdbms.client import SqlClient

    c = synthetic_corpus("wire", 200, 10, seed=9)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    ex = Executor(catalog, group_commit=4)
    handle = start_server_thread(ex)
    try:
        with SqlClient.connect(*handle.address) as cl:
            cl.run("CREATE CLASSIFICATION VIEW a ON t USING MODEL svm "
                   "WITH (cost_mode = modeled, target_lag = '60 s');"
                   "CREATE CLASSIFICATION VIEW b ON a USING MODEL svm "
                   "WITH (cost_mode = modeled, target_lag = 'downstream')")
            for j in range(12):
                cl.run(f"INSERT INTO t (id, label) VALUES "
                       f"({j}, {int(c.labels[j])})")
            rows = {r.view: r for r in cl.show("schedule")}
            assert rows["a"].inbox_rows == 12     # queued, lag is 60 s
            assert cl.refresh() == ["a", "b"]     # the barrier drains it
            rows = {r.view: r for r in cl.show("schedule")}
            assert rows["a"].inbox_rows == 0
            assert rows["a"].staleness_s == 0.0
            cl.suspend("a")
            assert {r.view: r.state for r in cl.show("views")}["a"] \
                == "suspended"
            cl.resume("a")
            # lag 'downstream' resolves UP the DAG from consumers: give b
            # a numeric lag and point a at its consumers
            cl.alter_view("a", target_lag="downstream")
            cl.alter_view("b", target_lag="1 s")
            rows = {r.view: r for r in cl.show("views")}
            assert rows["a"].target_lag == "downstream"
            assert rows["a"].effective_lag == "1 s"
            assert rows["b"].target_lag == "1 s"
            # legacy wrappers: same wire frames, same results, deprecated
            with pytest.deprecated_call():
                legacy = cl.query_one("SHOW SCHEDULE")
            assert legacy.rows == cl.run_one("SHOW SCHEDULE").rows
    finally:
        handle.stop()


def test_legacy_client_wrappers_pin_wire_format():
    """query()/query_one()/execute() must emit byte-identical request
    frames to run()/run_one()/run_prepared() — embedders speaking the old
    surface stay protocol-compatible."""
    from repro.rdbms.client import SqlClient

    sent = []

    class Probe(SqlClient):
        def __init__(self):
            super().__init__(sock=None)

        def request(self, obj):
            sent.append(obj)
            return {"ok": True, "results": [{"columns": [], "rows": []}]}

    p = Probe()
    p.run("SHOW TABLES")
    with pytest.deprecated_call():
        p.query("SHOW TABLES")
    p.run_prepared("pt", [1, 2])
    with pytest.deprecated_call():
        p.execute("pt", [1, 2])
    assert sent[0] == sent[1] == {"op": "query", "sql": "SHOW TABLES"}
    assert sent[2] == sent[3] == {"op": "execute", "name": "pt",
                                  "params": [1, 2]}
