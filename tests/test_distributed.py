"""Distributed features: grad compression, stragglers, multi-device subprocess
tests (sharded hazy consistency, elastic re-mesh restore)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600, env=env)
    if (out.returncode == -11 and not out.stderr.strip()
            and not os.environ.get("REPRO_STRICT_SUBPROCESS")):
        # XLA CPU segfault compiling large programs on fake-device meshes:
        # a jaxlib/kernel interaction on some hosts, not a property of the
        # code under test (see ROADMAP open items). Set
        # REPRO_STRICT_SUBPROCESS=1 to turn these skips into failures.
        pytest.skip("jaxlib segfault (SIGSEGV) in XLA compile on this host")
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Straggler logic (pure python)
# ---------------------------------------------------------------------------

def test_straggler_detection_and_reassignment():
    from repro.distributed import ShardAssigner, StragglerDetector
    det = StragglerDetector(n_workers=4, threshold=1.5, patience=2)
    asg = ShardAssigner(n_shards=8, n_workers=4)
    flagged = []
    for _ in range(5):
        times = {0: 1.0, 1: 1.0, 2: 1.05, 3: 3.0}  # worker 3 is slow
        flagged = det.observe(times)
    assert flagged == [3]
    newmap = asg.reassign(flagged, det)
    assert 3 not in newmap and 3 in asg.evicted
    covered = sorted(s for shards in newmap.values() for s in shards)
    assert covered == list(range(8))        # every shard still owned
    assert asg.owner_of(3) != 3


def test_straggler_no_false_positive():
    from repro.distributed import StragglerDetector
    det = StragglerDetector(n_workers=4, threshold=1.5, patience=3)
    for _ in range(10):
        assert det.observe({w: 1.0 + 0.05 * w for w in range(4)}) == []


# ---------------------------------------------------------------------------
# Compression (multi-device, subprocess)
# ---------------------------------------------------------------------------

def test_compressed_allreduce_accuracy():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.distributed import (make_compressed_grad_allreduce,
                                       error_feedback_init)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("pod",))
        allred = make_compressed_grad_allreduce("pod", 8)
        r = np.random.default_rng(0)
        g_all = jnp.asarray(r.normal(size=(8, 64)), jnp.float32)
        err0 = {"g": jnp.zeros((8, 64), jnp.float32)}

        def f(g, err):
            out, err2 = allred({"g": g}, err)
            return out["g"], err2["g"]

        from repro.core.sharded import shard_map
        fn = jax.jit(shard_map(f, mesh=mesh,
                                   in_specs=(P("pod"), P("pod")),
                                   out_specs=(P("pod"), P("pod"))))
        # accumulate over rounds: error feedback must keep the running mean
        # close to the true mean
        total_hat = np.zeros(64); total_true = np.zeros(64)
        err = err0["g"]
        for step in range(20):
            g_step = g_all * (1.0 + 0.1 * step)
            mean_hat, err = fn(g_step, err)
            total_hat += np.asarray(mean_hat)[0]
            total_true += np.asarray(jnp.mean(g_step, axis=0))
        rel = np.abs(total_hat - total_true).max() / (np.abs(total_true).max() + 1e-9)
        print("REL", rel)
        assert rel < 0.02, rel
    """)
    assert "REL" in out


# ---------------------------------------------------------------------------
# Sharded hazy engine on a real (fake-device) mesh
# ---------------------------------------------------------------------------

def test_sharded_hazy_multidevice_consistency():
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.sharded import ShardedHazy
        from repro.core import zero_model, sgd_step
        from repro.data import forest_like, example_stream
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        corpus = forest_like(scale=0.01)
        n = (corpus.features.shape[0] // 8) * 8
        F = np.ascontiguousarray(corpus.features[:n, :52])  # 52 % 2 == 0
        sh = ShardedHazy(mesh=mesh, n=n, d=52, M=1.0, p=2.0, cap_frac=1/4)
        state = sh.init_state(F)
        model = zero_model(52)
        stream = example_stream(corpus, seed=3, label_noise=0.0)
        for _, f, y in [next(stream) for _ in range(400)]:
            model = sgd_step(model, f[:52], y, lr=0.02, l2=1e-3)
            state = sh.apply_model(state, jnp.asarray(model.w),
                                   jnp.asarray(model.b, jnp.float32))
        truth = np.where(F @ model.w - model.b >= 0, 1, -1)
        # per-shard permutations: compare via perm indices
        perm = np.asarray(state.perm)
        labels = np.asarray(state.labels)
        assert np.array_equal(truth[perm], labels)
        assert sh.all_members(state) == int((truth == 1).sum())
        print("OK reorgs=", sh.skiing.reorgs)
    """)
    assert "OK" in out


def test_sharded_multiview_multidevice_consistency():
    """k one-vs-all views over ONE shared scratch table on a (4, 2) mesh,
    maintained through the `multiview_band_reclassify` kernel against the
    device-resident shared clustering order: after the same cora_like SGD
    stream, the sharded labels and counts must equal the host
    `MultiViewEngine`'s (both are exact w.r.t. the current model, so any
    disagreement is a maintenance bug on one side)."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.sharded import ShardedMultiViewHazy
        from repro.core.multiview import MultiViewEngine
        from repro.core.waters import holder_M
        from repro.data import cora_like, multiclass_example_stream
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        c = cora_like(scale=0.8)
        n, k = 2048, c.num_classes            # 4 row shards of 512
        F = np.ascontiguousarray(c.features[:n]); d = F.shape[1]
        host = MultiViewEngine(F, k, p=2.0, q=2.0, cost_mode="modeled")
        sh = ShardedMultiViewHazy(mesh=mesh, n=n, d=d, k=k,
                                  M=holder_M(F, 2.0), p=2.0, cap_frac=1/2)
        state = sh.init_state(F)
        W = np.zeros((k, d), np.float32); b = np.zeros(k, np.float64)
        lr, l2 = 0.1, 1e-4
        stream = multiclass_example_stream(c, seed=11)
        for i, cls in (next(stream) for _ in range(300)):
            if i >= n:
                continue
            f = F[i]
            y = np.where(np.arange(k) == cls, 1.0, -1.0)
            z = W @ f - b.astype(np.float32)
            g = np.where(y * z.astype(np.float64) < 1.0, -y, 0.0)
            W = W * (1.0 - lr * l2)
            W -= (lr * g).astype(np.float32)[:, None] * f[None, :]
            b = b - lr * (-g)
            host.apply_models(W, b)
            state = sh.apply_models(state, W, b)
        # labels: sharded rows live in the shared clustering order (gids);
        # scatter the host's per-view eps order back to entity order first
        gids = np.asarray(state.gids)
        labels = np.asarray(state.labels)
        host_full = np.empty((k, n), np.int8)
        for v in range(k):
            host_full[v, host.perm[v]] = host.labels_sorted[v]
        assert np.array_equal(labels, host_full[:, gids])
        counts = sh.all_members(state)
        assert np.array_equal(counts, host.all_members()), counts
        assert counts.min() > 0 and counts.max() < n   # non-degenerate views
        assert sh.skiing.reorgs >= 1
        assert sh.skiing.total_incremental > 0   # kernel rounds did real work
        # §3.5.2 hybrid probe: device-side waters short-circuit (zero feature
        # bytes) + one shared feature-row gather for the views that miss —
        # must agree with the host labels for every sampled entity
        resolved_total = 0
        for i in range(0, n, 61):
            lab, resolved = sh.hybrid_labels_of(state, W, b, int(i))
            assert np.array_equal(lab, host_full[:, i]), (i, lab)
            resolved_total += int(resolved.sum())
        assert resolved_total > 0      # the waters tier did real work
        print("OK reorgs=", sh.skiing.reorgs, "overflows=", sh.overflows,
              "counts=", counts, "water_resolved=", resolved_total)
    """)
    assert "OK" in out


def test_reorganize_step_has_no_cross_row_collectives():
    """DESIGN.md claim: shard-local clustering -> reorganization needs no
    collectives beyond the model-axis eps psum (no all-to-all / all-gather
    of the feature table)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.core.sharded import make_reorganize_step, state_specs
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        st = state_specs(1024, 64, mesh)
        w = jax.ShapeDtypeStruct((64,), jnp.float32,
                                 sharding=NamedSharding(mesh, P("model")))
        b = jax.ShapeDtypeStruct((), jnp.float32,
                                 sharding=NamedSharding(mesh, P()))
        with mesh:
            txt = jax.jit(make_reorganize_step(mesh)).lower(st, w, b)\
                     .compile().as_text()
        bad = [l for l in txt.splitlines()
               if ("all-to-all" in l or "all-gather" in l or
                   "collective-permute" in l)]
        assert not bad, bad[:3]
        print("NO_CROSS_ROW_COLLECTIVES")
    """)
    assert "NO_CROSS_ROW_COLLECTIVES" in out


# ---------------------------------------------------------------------------
# Elastic scaling: checkpoint on one mesh, restore on a smaller one
# ---------------------------------------------------------------------------

def test_elastic_remesh_restore(tmp_path):
    tmp_path = str(tmp_path)
    out = _run_subprocess(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import build
        from repro.models.steps import (init_train_state, make_train_step,
                                        train_state_specs)
        from repro.checkpoint import save_checkpoint, restore_checkpoint
        from repro.launch.mesh import make_elastic_mesh
        from repro.data import TokenStream

        cfg = smoke_config("tinyllama-1.1b")
        mdl = build(cfg)
        ds = TokenStream(vocab_size=cfg.vocab_size, batch=4, seq_len=16, seed=0)
        step_fn = jax.jit(make_train_step(mdl))

        def batches(i):
            return {{k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}}

        # train 3 steps on an 8-device mesh
        mesh8 = make_elastic_mesh(8, model_parallel=2)
        with mesh8:
            state = init_train_state(mdl)
            for i in range(3):
                state, _ = step_fn(state, batches(i))
        save_checkpoint({tmp_path!r}, state, 3)

        # "lose" 4 devices: restore onto a 4-device mesh and keep training
        mesh4 = make_elastic_mesh(4, model_parallel=2)
        from repro.models.steps import train_state_specs
        abstract = train_state_specs(mdl, mesh4)
        with mesh4:
            restored, step = restore_checkpoint({tmp_path!r}, abstract)
            assert step == 3
            restored, m = step_fn(restored, batches(3))
        assert np.isfinite(float(m["loss"]))
        print("ELASTIC_OK", float(m["loss"]))
    """)
    assert "ELASTIC_OK" in out
