"""End-to-end LM training behaviour: loss decreases on structured data,
preemption (SIGTERM) checkpoints and resumes cleanly."""
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_tiny_lm_loss_decreases():
    import dataclasses
    from repro.configs import smoke_config
    from repro.data import TokenStream
    from repro.models import build
    from repro.models.steps import init_train_state, make_train_step

    cfg = dataclasses.replace(smoke_config("tinyllama-1.1b"), num_layers=2,
                              d_model=64, d_ff=256, vocab_size=512)
    mdl = build(cfg)
    ds = TokenStream(vocab_size=cfg.vocab_size, batch=8, seq_len=32, seed=0)
    step = jax.jit(make_train_step(mdl, lr=3e-3, warmup=5, total_steps=60))
    state = init_train_state(mdl)
    losses = []
    for i in range(60):
        b = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)  # Markov structure is learnable


def test_train_launcher_preemption_resume(tmp_path):
    """SIGTERM mid-run -> checkpoint -> relaunch resumes past the kill point
    (the fault-tolerance contract of launch/train.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    ckpt = str(tmp_path / "ckpt")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "tinyllama-1.1b", "--smoke", "--steps", "300", "--batch", "2",
           "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "5",
           "--log-every", "5"]
    proc = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    # let it make progress, then preempt
    deadline = time.time() + 300
    while time.time() < deadline:
        if os.path.isdir(ckpt) and any(d.startswith("step_")
                                       for d in os.listdir(ckpt)):
            break
        time.sleep(1.0)
        if proc.poll() is not None:
            break
    proc.send_signal(signal.SIGTERM)
    out1, _ = proc.communicate(timeout=300)
    assert proc.returncode == 0, out1[-2000:]

    from repro.checkpoint import latest_step
    resumed_from = latest_step(ckpt)
    assert resumed_from is not None and resumed_from > 0

    out2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)
    assert out2.returncode == 0, out2.stdout[-2000:]
    assert f"resumed at step" in out2.stdout
    assert latest_step(ckpt) == 300
