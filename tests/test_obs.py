"""The unified telemetry layer (`repro.obs`) and its surfaces.

Contracts (ISSUE 9):

  * metrics primitives are exact: histogram bucket routing and the
    bucket-edge quantile rule on known distributions, and an 8-thread
    hammer on one registry reconciles to the exact totals;
  * spans nest by the ambient thread-local stack, and `finish` unwinds
    THROUGH a span so an exception path never corrupts later statements;
  * `EXPLAIN ANALYZE` executes the inner statement and its tier row is
    the exact facade `tier_hits` delta — the same counters the registry
    snapshot carries (one ledger, three surfaces);
  * `SHOW METRICS`, the wire `metrics` op, and `Executor.metrics_snapshot`
    agree; `SHOW COST ON v` reports modeled-vs-measured SKIING rows;
  * the slow-statement log fires above the threshold and only above it;
    the server access log emits one line per statement when armed;
  * the REPL footer reports the same span-derived gate-wait/execute split
    the server's elapsed_us carries.
"""
import io
import logging
import threading

import numpy as np
import pytest

from repro.core.facade import TIERS
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, Span,
                       ViewCostRecorder, trace)
from repro.rdbms import Catalog, Executor
from repro.rdbms.ast_nodes import SqlError


def _executor(policy="hybrid", **view_opts) -> Executor:
    ex = Executor(group_commit=4)
    ex.execute_one("CREATE TABLE t FROM CORPUS synthetic WITH (scale = 0.05)")
    opts = {"policy": policy, "cost_mode": "modeled", **view_opts}
    with_clause = ", ".join(f"{k} = {v}" for k, v in opts.items())
    ex.execute_one(f"CREATE CLASSIFICATION VIEW v ON t USING MODEL svm "
                   f"WITH ({with_clause})")
    for i in range(8):
        ex.execute_one(f"INSERT INTO t VALUES ({i}, {1 if i % 2 else -1})")
    return ex


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c, g = Counter(), Gauge()
    c.inc()
    c.inc(41)
    g.set(2.5)
    assert c.value == 42 and g.value == 2.5


def test_histogram_bucket_routing_and_quantiles():
    h = Histogram(bounds=(1.0, 2.0, 4.0, 8.0))
    for x in (0.5, 1.0, 1.5, 3.0, 3.0, 7.9, 100.0):
        h.observe(x)
    # inclusive upper edges: 0.5,1.0 -> b0; 1.5 -> b1; 3.0 x2 -> b2;
    # 7.9 -> b3; 100 -> overflow
    assert h.counts == [2, 1, 2, 1, 1]
    assert h.count == 7 and h.sum == pytest.approx(116.9)
    assert h.quantile(0.5) == 4.0          # cum 2,3,5 >= 3.5 at bucket 2
    assert h.quantile(0.99) == float("inf")  # lands in the overflow bucket
    assert h.mean == pytest.approx(116.9 / 7)
    snap = h.snapshot()
    assert snap["count"] == 7 and snap["p50"] == 4.0
    assert snap["p99"] == float("inf") and snap["counts"] == h.counts


def test_histogram_quantile_exact_on_bucket_edges():
    h = Histogram(bounds=(1, 2, 3, 4, 5, 6, 7, 8, 9, 10))
    for x in range(1, 101):
        h.observe((x - 1) % 10 + 1)        # 10 observations per bucket
    assert h.quantile(0.50) == 5
    assert h.quantile(0.99) == 10
    assert h.quantile(0.10) == 1


def test_empty_histogram():
    h = Histogram()
    assert h.quantile(0.5) == 0.0 and h.mean == 0.0
    assert h.snapshot()["p99"] == 0.0


def test_registry_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(3)
    reg.gauge("g").set(7)
    reg.register_collector("comp", lambda: {"x": 1})
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3 and snap["gauges"]["g"] == 7
    assert snap["comp"] == {"x": 1}


def test_registry_collector_errors_are_contained():
    reg = MetricsRegistry()

    def boom():
        raise RuntimeError("dead component")

    reg.register_collector("bad", boom)
    assert reg.snapshot()["bad"] == {"error": "RuntimeError"}


def test_registry_hammer_reconciles_exactly():
    """8 threads x 5000 ops on ONE registry: counters and histogram
    count/sum land on the exact totals (CPython += is not atomic across
    bytecodes — this is what the per-instrument locks buy)."""
    reg = MetricsRegistry()
    threads_n, ops = 8, 5000

    def work():
        c = reg.counter("hits")
        h = reg.histogram("lat", buckets=(1, 2, 4))
        for i in range(ops):
            c.inc()
            reg.counter("hits")            # get-or-create races too
            h.observe(1 + (i % 3))

    ts = [threading.Thread(target=work) for _ in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    h = reg.histogram("lat")
    assert reg.counter("hits").value == threads_n * ops
    assert h.count == threads_n * ops
    # observations 1,2,3 cycle: buckets (<=1, <=2, <=4) + empty overflow
    expected = [0, 0, 0, 0]
    for i in range(ops):
        expected[i % 3] += threads_n
    assert h.counts == expected
    assert h.sum == pytest.approx(threads_n * sum(1 + (i % 3)
                                                  for i in range(ops)))


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_and_parenting():
    with trace.span("root") as root:
        with trace.span("child", k=1) as c1:
            assert trace.current() is c1
            with trace.span("grand"):
                pass
        with trace.span("child"):
            pass
    assert trace.current() is None
    assert [c.name for c in root.children] == ["child", "child"]
    assert [g.name for g in root.children[0].children] == ["grand"]
    assert root.t1 is not None and root.duration_s >= 0
    assert root.find("grand") is not None
    assert root.sum_us("child") >= root.children[0].children[0].duration_us


def test_span_finish_unwinds_through_exceptions():
    """An exception that leaves children open must not leak them onto the
    ambient stack: finishing the root pops THROUGH the orphans."""
    root = trace.start("root")
    trace.start("orphan1")
    trace.start("orphan2")
    trace.finish(root)
    assert trace.current() is None
    sp = trace.start("fresh")              # a fresh root, not a child
    trace.finish(sp)
    assert root.children[0].name == "orphan1"


def test_span_records_into_registry():
    reg = MetricsRegistry()
    with trace.span("phase", metrics=reg):
        pass
    assert reg.histogram("span.phase.seconds").count == 1


def test_render_tree_shape():
    with trace.span("a", kind="x") as a:
        with trace.span("b"):
            pass
    text = trace.render_tree(a)
    lines = text.splitlines()
    assert lines[0].startswith("a ") and "[kind=x]" in lines[0]
    assert lines[1].startswith("  b ")


# ---------------------------------------------------------------------------
# ViewCostRecorder
# ---------------------------------------------------------------------------

def test_cost_recorder_snapshot():
    rec = ViewCostRecorder(2)
    rec.record_reorg(0, 0.5)
    rec.record_reorg(0, 1.5)
    rec.record_step(0, 0.25, 2.0)
    rec.record_step(0, 0.75, 2.0)
    s = rec.snapshot(0)
    assert s["reorgs_measured"] == 2
    assert s["S_measured_mean_s"] == pytest.approx(1.0)
    assert s["steps_measured"] == 2
    assert s["charge_modeled"] == pytest.approx(4.0)
    assert s["seconds_measured"] == pytest.approx(1.0)
    assert s["seconds_per_charge"] == pytest.approx(0.25)
    empty = rec.snapshot(1)
    assert empty["steps_measured"] == 0
    assert empty["seconds_per_charge"] is None


# ---------------------------------------------------------------------------
# executor surfaces: statement traces, EXPLAIN ANALYZE, SHOW METRICS/COST
# ---------------------------------------------------------------------------

def test_statement_trace_phases():
    ex = _executor()
    res = ex.execute_one("SELECT id, label FROM v WHERE id = 3")
    assert res.trace is not None and res.trace.name == "statement"
    names = [c.name for c in res.trace.children]
    assert "parse" in names and "execute" in names and "gate.wait" in names
    exec_children = [c.name
                     for c in res.trace.find("execute").children]
    assert "plan" in exec_children and "probe" in exec_children
    assert res.trace.t1 is not None      # finished before it was returned


def test_statement_counters_and_errors():
    ex = _executor()
    before = ex.metrics.counter("statements").value
    errs = ex.metrics.counter("statements.errors").value
    ex.execute_one("SELECT id, label FROM v WHERE id = 1")
    with pytest.raises(SqlError):
        ex.execute_one("SELECT id, label FROM nosuch WHERE id = 1")
    assert ex.metrics.counter("statements").value == before + 2
    assert ex.metrics.counter("statements.errors").value == errs + 1
    assert ex.metrics.counter("statements.select").value >= 2


def test_explain_analyze_tier_row_is_the_exact_facade_delta():
    """The acceptance contract: EXPLAIN ANALYZE on a hybrid point SELECT
    reports tier counts that reconcile EXACTLY with the facade's
    tier_hits deltas (sampled independently here)."""
    ex = _executor(memory_budget=0.25)
    f = ex.catalog.view("v").facade
    before = dict(f.tier_hits)
    res = ex.execute_one(
        "EXPLAIN ANALYZE SELECT id, label FROM v WHERE id IN (1, 2, 3)")
    after = dict(f.tier_hits)
    tier_row = next(r for r in res.rows if r[0] == "tiers")
    reported = dict(kv.split("=") for kv in tier_row[2].split(";"))
    for t in TIERS:
        assert int(reported[t]) == after[t] - before[t], (t, reported)
    assert sum(int(v) for v in reported.values()) == 3
    phases = [r[0].strip() for r in res.rows]
    assert "analyze" in phases and "probe" in phases and "epoch" in phases
    assert next(r for r in res.rows if r[0] == "rows")[2] == "3"


def test_explain_analyze_executes_dml():
    ex = _executor()
    epoch0 = ex.epoch
    queued0 = ex.metrics.counter("wal.appends").value
    ex.execute_one("EXPLAIN ANALYZE INSERT INTO t VALUES (9, 1)")
    assert ex.metrics.counter("wal.appends").value == queued0 + 1
    # plain EXPLAIN never mutates
    ex.execute_one("EXPLAIN INSERT INTO t VALUES (10, 1)")
    assert ex.metrics.counter("wal.appends").value == queued0 + 1
    assert ex.epoch >= epoch0


def test_explain_analyze_flushes_read_your_writes():
    ex = _executor()
    ex.execute_one("INSERT INTO t VALUES (11, 1)")
    assert ex.log.has_pending("t")
    ex.execute_one("EXPLAIN ANALYZE SELECT id, label FROM v WHERE id = 11")
    assert not ex.log.has_pending("t")


def test_show_metrics_and_snapshot_agree():
    ex = _executor()
    res = ex.execute_one("SHOW METRICS")
    flat = dict(res.rows)
    snap = ex.metrics_snapshot()
    assert res.columns == ("metric", "value")
    assert flat["epoch"] == snap["epoch"] == ex.log.commits
    assert flat["counters.wal.commits"] == \
        snap["counters"]["wal.commits"] == ex.log.commits
    assert flat["counters.gate.exclusive_acquisitions"] == \
        snap["counters"]["gate.exclusive_acquisitions"]
    # per-view collector rides along
    assert flat["view.v.policy"] == "hybrid"
    # the SHOW itself was gated + counted by the time we snapshot again
    assert ex.metrics.counter("statements.show").value >= 1


def test_gate_wait_histograms_populated():
    ex = _executor()
    ex.execute_one("SELECT id, label FROM v WHERE id = 1")
    snap = ex.metrics_snapshot()
    assert snap["histograms"]["gate.shared_wait_seconds"]["count"] >= 1
    assert snap["histograms"]["gate.exclusive_wait_seconds"]["count"] >= 8
    assert snap["counters"]["gate.shared_acquisitions"] >= 1


def test_show_cost_reports_modeled_vs_measured():
    ex = _executor()
    ex.execute_one("UPDATE MODEL ON v")
    res = ex.execute_one("SHOW COST ON v")
    assert res.columns[0] == "view"
    row = dict(zip(res.columns, res.rows[0]))
    assert row["view"] == "v" and row["cost_mode"] == "modeled"
    assert int(row["reorgs"]) >= 1
    assert float(row["S_measured_mean_s"]) > 0    # wall clock, measured
    if int(row["steps"]) and float(row["charge_modeled"]) > 0:
        assert float(row["seconds_per_charge"]) > 0


def test_show_cost_multiview_and_unknown_view():
    ex = Executor()
    ex.execute_one("CREATE TABLE m FROM CORPUS cora_like WITH (scale = 0.05)")
    ex.execute_one("CREATE CLASSIFICATION VIEW mv ON m USING MODEL svm "
                   "WITH (k = 7, policy = hybrid, cost_mode = modeled)")
    for i in range(6):
        ex.execute_one(f"INSERT INTO m VALUES ({i}, {i % 7})")
    ex.execute_one("UPDATE MODEL ON mv")
    res = ex.execute_one("SHOW COST ON mv")
    assert len(res.rows) == 7
    assert [r[1] for r in res.rows] == list(range(7))
    with pytest.raises(SqlError):
        ex.execute_one("SHOW COST ON nosuch")


def test_slow_log_fires_above_threshold_only(caplog):
    ex = _executor()
    with caplog.at_level(logging.WARNING, logger="repro.obs.slowlog"):
        ex.slow_ms = 1e9                   # nothing is this slow
        ex.execute_one("SELECT id, label FROM v WHERE id = 1")
        assert not caplog.records
        ex.slow_ms = 0.0                   # everything is slower than 0
        ex.execute_one("SELECT id, label FROM v WHERE id = 2")
    assert len(caplog.records) == 1
    msg = caplog.records[0].getMessage()
    assert "slow statement" in msg and "statement" in msg and "probe" in msg


def test_pool_read_spans_feed_registry():
    ex = _executor(memory_budget=0.1)
    ex.execute_one("SELECT id, label FROM v WHERE label = 1")  # band scan
    snap = ex.metrics_snapshot()
    st = snap["view.v"]["storage"]
    assert st["hits"] + st["misses"] + st["coalesced"] == st["probes"]
    if st["misses"]:                       # cold reads went through spans
        assert snap["histograms"]["span.pool.read.seconds"]["count"] >= 1


def test_wal_telemetry_counters():
    ex = _executor()
    snap = ex.metrics_snapshot()
    assert snap["wal"]["commits"] == ex.log.commits == snap["epoch"]
    assert snap["counters"]["wal.appends"] == 8
    assert snap["histograms"]["wal.group_size"]["count"] == ex.log.commits


# ---------------------------------------------------------------------------
# server + wire + REPL surfaces
# ---------------------------------------------------------------------------

@pytest.fixture()
def served():
    from repro.rdbms import SqlClient, start_server_thread
    ex = _executor()
    handle = start_server_thread(ex, log_statements=True)
    host, port = handle.address
    client = SqlClient.connect(host, port)
    yield ex, client
    client.close()
    handle.stop()


def test_wire_metrics_roundtrip(served):
    ex, client = served
    client.query("SELECT id, label FROM v WHERE id = 1")
    snap = client.metrics()
    assert snap["epoch"] == ex.log.commits
    assert snap["counters"]["statements"] >= 1
    assert "view.v" in snap and snap["view.v"]["policy"] == "hybrid"
    # JSON round trip: histograms arrive as plain dicts
    assert isinstance(snap["histograms"]["statement.seconds"]["p99"],
                      (int, float))


def test_wire_results_carry_span_phases(served):
    _, client = served
    r = client.query_one("SELECT id, label FROM v WHERE id = 2")
    assert r.elapsed_us is not None and r.elapsed_us > 0
    assert "execute" in r.phases and "gate.wait" in r.phases
    assert client.last_elapsed_us is not None


def test_access_log_line_per_statement(served, caplog):
    _, client = served
    with caplog.at_level(logging.INFO, logger="repro.rdbms.server"):
        client.query("SELECT id, label FROM v WHERE id = 1; "
                     "SELECT id, label FROM v WHERE id = 2")
    lines = [r.getMessage() for r in caplog.records
             if "kind=select" in r.getMessage()]
    assert len(lines) == 2
    assert all("session=" in ln and "elapsed_us=" in ln and "epoch=" in ln
               for ln in lines)


def test_repl_footer_reports_gate_and_execute_split():
    from repro.rdbms.repl import repl
    ex = _executor()
    out = io.StringIO()
    repl(ex, stdin=io.StringIO("SELECT id, label FROM v WHERE id = 1;\n"),
         out=out)
    text = out.getvalue()
    footer = next(ln for ln in text.splitlines()
                  if ln.startswith("-- ") and "gate-wait" in ln)
    assert "ms (gate-wait" in footer and "execute" in footer


def test_telemetry_overhead_is_bounded():
    """The armed registry must not dominate statement cost: a counter inc
    plus a histogram observe is well under a microsecond-scale statement.
    (The real p99 gate runs in CI serve-smoke; this is the unit guard.)"""
    from repro.obs import clock
    reg = MetricsRegistry()
    c = reg.counter("x")
    h = reg.histogram("y")
    t0 = clock()
    for _ in range(10000):
        c.inc()
        h.observe(1e-4)
    per_op = (clock() - t0) / 10000
    assert per_op < 50e-6                  # generous: CI boxes are noisy
