"""Equivalence and exactness tests for the vectorized multi-view engine.

The load-bearing claim: `MulticlassView(vectorized=True)` — one shared
table, stacked models, union-band maintenance — is *observationally
identical* to the seed's k-independent-`HazyEngine` loop: same model
trajectory bit for bit, same predictions, same `class_counts()`, including
across reorganizations (decisions are compared under the deterministic
`cost_mode="modeled"`)."""
import numpy as np

from repro.core import MulticlassView, MultiViewEngine
from repro.data import cora_like, multiclass_example_stream


def _cora_views(k=None, scale=0.5, **kw):
    c = cora_like(scale=scale)
    k = k or c.num_classes
    kw.setdefault("policy", "eager")
    kw.setdefault("cost_mode", "modeled")
    kw.setdefault("p", 2.0)
    kw.setdefault("q", 2.0)
    kw.setdefault("lr", 0.1)
    seed_view = MulticlassView(c.features, k, vectorized=False, **kw)
    vec_view = MulticlassView(c.features, k, vectorized=True, **kw)
    stream = multiclass_example_stream(c, seed=11)
    return c, seed_view, vec_view, stream


def test_vectorized_matches_seed_loop_on_cora():
    """Identical class_counts, predictions, models AND per-view reorg
    schedules vs the seed per-class loop on a Cora-sized workload."""
    c, seed_view, vec_view, stream = _cora_views()
    for i, cls in (next(stream) for _ in range(700)):
        seed_view.insert_example(i, cls)
        vec_view.insert_example(i, cls)
    # the stacked SGD is the same float32 program as k sequential sgd_steps
    Ws = np.stack([m.w for m in seed_view.models])
    bs = np.array([m.b for m in seed_view.models])
    assert np.array_equal(Ws, vec_view.W)
    assert np.array_equal(bs, vec_view.b)
    assert seed_view.class_counts() == vec_view.class_counts()
    sample = range(0, c.features.shape[0], 13)
    assert [seed_view.predict(i) for i in sample] == \
           [vec_view.predict(i) for i in sample]
    # per-entity view membership agrees too
    for i in range(0, c.features.shape[0], 97):
        assert np.array_equal(seed_view.view_labels(i), vec_view.view_labels(i))
    # equivalence must hold THROUGH reorganizations, not around them
    seed_reorgs = [e.skiing.reorgs for e in seed_view.engines]
    assert sum(seed_reorgs) >= 1
    assert seed_reorgs == vec_view.engine.reorg_counts.tolist()
    assert vec_view.check_consistent() and seed_view.check_consistent()


def test_batched_insert_examples_is_exact():
    """The batched fast path produces the same final models and (because
    eager maintenance is exact w.r.t. the current model) the same counts
    as per-example maintenance."""
    c, seed_view, vec_view, stream = _cora_views(k=16)
    inserts = [next(stream) for _ in range(400)]
    for i, cls in inserts:
        seed_view.insert_example(i, cls % 16)
    for j in range(0, len(inserts), 32):
        chunk = inserts[j:j + 32]
        vec_view.insert_examples([i for i, _ in chunk],
                                 [cls % 16 for _, cls in chunk])
    assert seed_view.class_counts() == vec_view.class_counts()
    assert vec_view.check_consistent()


def test_multiview_engine_lazy_matches_eager():
    c = cora_like(scale=0.3)
    k = c.num_classes
    lazy = MulticlassView(c.features, k, policy="lazy", cost_mode="modeled",
                          p=2.0, q=2.0, lr=0.1)
    eager = MulticlassView(c.features, k, policy="eager", cost_mode="modeled",
                           p=2.0, q=2.0, lr=0.1)
    stream = multiclass_example_stream(c, seed=3)
    for t, (i, cls) in enumerate(next(stream) for _ in range(300)):
        lazy.insert_example(i, cls)
        eager.insert_example(i, cls)
        if t % 50 == 17:    # reads force lazy catch-up; views must agree
            assert lazy.class_counts() == eager.class_counts()
    assert lazy.check_consistent() and eager.check_consistent()


def test_multiview_engine_reorganizes_under_drift():
    """A drifting stacked model must trigger per-view reorganizations and
    stay consistent across them (the SKIING choice, per view)."""
    c, _, vec_view, stream = _cora_views(scale=0.2, lr=0.3)
    for i, cls in (next(stream) for _ in range(500)):
        vec_view.insert_example(i, cls)
    eng = vec_view.engine
    assert eng.stats.reorgs >= 1
    assert eng.check_consistent()
    # bands are tracked per view and stay within [0, 1]
    fracs = eng.band_fractions()
    assert np.all((fracs >= 0.0) & (fracs <= 1.0))


def test_multiview_engine_members_and_labels():
    r = np.random.default_rng(0)
    n, d, k = 512, 16, 4
    F = r.normal(size=(n, d)).astype(np.float32)
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
    eng = MultiViewEngine(F, k, p=2.0, q=2.0, cost_mode="modeled")
    W = r.normal(size=(k, d)).astype(np.float32) * 0.2
    b = r.normal(size=k) * 0.05
    eng.apply_models(W, b)
    Z = F @ W.T - b.astype(np.float32)
    truth = np.where(Z >= 0, 1, -1)
    assert np.array_equal(eng.all_members(), (truth == 1).sum(axis=0))
    for v in range(k):
        assert set(eng.members(v).tolist()) == \
               set(np.flatnonzero(truth[:, v] == 1).tolist())
    for i in range(0, n, 31):
        assert np.array_equal(eng.labels_of(i), truth[i])
        for v in range(k):
            assert eng.label(v, i) == truth[i, v]


def test_classification_view_batched_insert_exact():
    """ClassificationView.insert_examples(batched=True): one maintenance
    round per batch, reads still exact w.r.t. the batch-final model."""
    from repro.core import ClassificationView
    from repro.data import forest_like, example_stream
    corpus = forest_like(scale=0.005)
    a = ClassificationView(corpus.features, policy="eager", norm=(2.0, 2.0),
                           lr=0.05)
    bchd = ClassificationView(corpus.features, policy="eager", norm=(2.0, 2.0),
                              lr=0.05)
    stream = list(zip(range(300), example_stream(corpus, seed=5,
                                                 label_noise=0.0)))
    ids = [i for _, (i, _f, _y) in stream]
    ys = [y for _, (_i, _f, y) in stream]
    for i, y in zip(ids, ys):
        a.insert_example(i, y)
    for j in range(0, len(ids), 25):
        bchd.insert_examples(ids[j:j + 25], ys[j:j + 25])
    np.testing.assert_allclose(a.model.w, bchd.model.w, rtol=0, atol=0)
    assert a.model.b == bchd.model.b
    assert a.all_members() == bchd.all_members()
    truth = np.where(corpus.features @ bchd.model.w - bchd.model.b >= 0, 1, -1)
    assert bchd.all_members() == int((truth == 1).sum())
