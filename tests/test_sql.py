"""Relational front-end: parser, planner tiers, WAL replay, and the
SQL-vs-direct equivalence contract.

The core property (ISSUE 4): ANY DML statement stream replayed through the
SQL executor must yield labels, counts, and waters IDENTICAL to direct
engine calls on the same stream — the front-end adds routing, batching and
bookkeeping, never different maintenance. Checked for all three engines
behind the catalog (single-view HazyEngine, k = 16 MultiViewEngine, and
ShardedMultiViewHazy) under eager, lazy, and hybrid policies (sharded is
eager-only by construction).

Everything runs with cost_mode=modeled so SKIING's reorganization schedule
is deterministic (S cancels out of charge vs threshold).
"""
import numpy as np
import pytest

from repro.core import ClassificationView, MulticlassView
from repro.data import multiclass_corpus, synthetic_corpus
from repro.rdbms import (Catalog, Executor, ParseError, PlanError, UpdateLog,
                         parse)
from repro.rdbms import ast_nodes as A


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------

def test_parse_create_statements():
    ct, cv = parse("""
        CREATE TABLE papers FROM CORPUS cora_like WITH (scale = 0.1);
        CREATE CLASSIFICATION VIEW v ON papers USING MODEL svm
            WITH (policy = hybrid, k = 16, buffer_frac = 0.05, p = inf);
    """)
    assert ct == A.CreateTable("papers", "cora_like", {"scale": 0.1})
    assert cv.name == "v" and cv.table == "papers" and cv.model == "svm"
    assert cv.options == {"policy": "hybrid", "k": 16, "buffer_frac": 0.05,
                          "p": float("inf")}
    assert isinstance(cv.options["k"], int)


def test_parse_dml_and_select():
    ins, upd, um, dele, sel, cnt, topk, ex = parse("""
        INSERT INTO t (id, label) VALUES (3, 1), (4, -1);
        UPDATE t SET label = -1 WHERE id = 5;
        UPDATE MODEL ON v;
        DELETE FROM t WHERE id = 9;
        SELECT id, view, label FROM v WHERE id IN (1, 2) AND view = 3;
        SELECT COUNT(*) FROM v WHERE label = 1;
        SELECT id, margin FROM v ORDER BY margin DESC LIMIT 7;
        EXPLAIN SELECT label FROM v WHERE id = 0;
    """)
    assert ins == A.Insert("t", [(3, 1.0), (4, -1.0)])
    assert upd == A.Update("t", 5, -1.0)
    assert um == A.UpdateModel("v")
    assert dele == A.Delete("t", 9)
    assert sel.where.ids == [1, 2] and sel.where.view == 3
    assert cnt.count and cnt.where.label == 1
    assert topk.order_by == "margin" and topk.descending and topk.limit == 7
    assert isinstance(ex, A.Explain) and isinstance(ex.stmt, A.Select)


@pytest.mark.parametrize("bad", [
    "SELECT bogus FROM v",
    "SELECT label FROM v WHERE label = 2",
    "SELECT id FROM v ORDER BY id",
    "UPDATE t SET margin = 1 WHERE id = 0",
    "INSERT INTO t (label, id) VALUES (1, 1)",
    "CREATE VIEW v ON t USING MODEL svm",
    "SELECT label FROM",
])
def test_parse_errors(bad):
    with pytest.raises(ParseError):
        parse(bad)


# ---------------------------------------------------------------------------
# Shared equivalence harness
# ---------------------------------------------------------------------------

GROUP = 8          # WAL group-commit size used throughout


class DirectMirror:
    """Replays the SAME statement stream via direct engine calls, including
    the WAL's group-commit semantics (flush at GROUP records, flush before
    reads / UPDATE MODEL, delete splits the batch)."""

    def __init__(self, insert_batch, delete_fn=None, read_flushes=True):
        self.pending = []
        self.insert_batch = insert_batch        # f(ids, labels)
        self.delete_fn = delete_fn
        self.read_flushes = read_flushes

    def dml(self, entity_id, label, op="insert"):
        self.pending.append((op, entity_id, label))
        if len(self.pending) >= GROUP:
            self.flush()

    def flush(self):
        batch = []
        for op, i, y in self.pending:
            if op == "delete":
                if batch:
                    self.insert_batch([b[0] for b in batch],
                                      [b[1] for b in batch])
                    batch = []
                self.delete_fn(i)
            else:
                batch.append((i, y))
        if batch:
            self.insert_batch([b[0] for b in batch], [b[1] for b in batch])
        self.pending = []


def _single_view_setup(policy):
    c = synthetic_corpus("eqv", 400, 24, seed=2)
    kw = dict(method="svm", policy=policy, norm=(2.0, 2.0), lr=0.1, l2=1e-4,
              alpha=1.0, buffer_frac=0.02 if policy == "hybrid" else 0.0,
              cost_mode="modeled")
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": policy, "p": 2, "q": 2,
                         "buffer_frac": kw["buffer_frac"],
                         "cost_mode": "modeled"})
    direct = ClassificationView(c.features, **kw)
    return c, catalog, direct


@pytest.mark.parametrize("policy", ["eager", "lazy", "hybrid"])
def test_sql_equals_direct_single_view(policy):
    c, catalog, direct = _single_view_setup(policy)
    ex = Executor(catalog, group_commit=GROUP)
    mirror = DirectMirror(
        lambda ids, ys: direct.insert_examples(ids, ys, batched=True),
        delete_fn=None)
    facade = catalog.view("v").facade
    n = c.features.shape[0]
    rng = np.random.default_rng(31)

    for step in range(240):
        u = rng.random()
        if u < 0.60:                                       # INSERT batch
            m = int(rng.integers(1, 5))
            rows, stmts = [], []
            for _ in range(m):
                i = int(rng.integers(0, n))
                y = int(c.labels[i])
                stmts.append(f"({i}, {y})")
                rows.append((i, y))
            ex.execute_one(f"INSERT INTO t (id, label) VALUES "
                           f"{', '.join(stmts)}")
            for i, y in rows:
                mirror.dml(i, float(y))
        elif u < 0.72:                                     # UPDATE = example
            i = int(rng.integers(0, n))
            y = -int(c.labels[i])
            ex.execute_one(f"UPDATE t SET label = {y} WHERE id = {i}")
            mirror.dml(i, float(y), op="update")
        elif u < 0.88:                                     # point SELECT
            i = int(rng.integers(0, n))
            got = ex.execute_one(
                f"SELECT label FROM v WHERE id = {i}").rows[0][0]
            mirror.flush()
            if policy == "hybrid":
                want, _ = direct.engine.hybrid_label(i)
            else:
                want = direct.engine.label(i)
            assert got == want, (step, i)
        elif u < 0.95:                                     # COUNT
            got = ex.execute_one(
                "SELECT count(*) FROM v WHERE label = 1").rows[0][0]
            mirror.flush()
            assert got == direct.engine.all_members(), step
        else:                                              # UPDATE MODEL
            ex.execute_one("UPDATE MODEL ON v")
            mirror.flush()
            direct.engine.apply_model(direct.model)

    ex.execute_one("COMMIT")
    mirror.flush()
    se, de = facade.view.engine, direct.engine
    assert se.all_members() == de.all_members()
    assert np.array_equal(se.labels_sorted, de.labels_sorted)
    assert np.array_equal(se.perm, de.perm)
    assert np.allclose(se.eps_sorted, de.eps_sorted)
    assert se.waters.lw == de.waters.lw and se.waters.hw == de.waters.hw
    assert se.skiing.reorgs == de.skiing.reorgs
    assert (se._pending is None) == (de._pending is None)
    assert se.check_consistent() and de.check_consistent()


def test_sql_equals_direct_single_view_with_delete():
    """DELETE retrains from scratch (footnote 2) — order-preserving around
    the group commit — and must match the same direct calls."""
    c, catalog, direct = _single_view_setup("eager")
    ex = Executor(catalog, group_commit=GROUP)
    direct_log = []

    def direct_insert(ids, ys):
        direct_log.extend(zip(ids, ys))
        direct.insert_examples(ids, ys, batched=True)

    def direct_delete(eid):
        keep = [(i, y) for i, y in direct_log if i != eid]
        direct_log[:] = keep
        direct.examples = [(direct.F[i], y) for i, y in keep]
        direct.retrain_from_scratch()

    mirror = DirectMirror(direct_insert, delete_fn=direct_delete)
    n = c.features.shape[0]
    rng = np.random.default_rng(5)
    for _ in range(60):
        i = int(rng.integers(0, n))
        y = int(c.labels[i])
        ex.execute_one(f"INSERT INTO t (id, label) VALUES ({i}, {y})")
        mirror.dml(i, float(y))
        if rng.random() < 0.1:
            j = int(rng.integers(0, n))
            ex.execute_one(f"DELETE FROM t WHERE id = {j}")
            mirror.dml(j, 0.0, op="delete")
    ex.execute_one("COMMIT")
    mirror.flush()
    se, de = catalog.view("v").facade.view.engine, direct.engine
    assert np.array_equal(se.labels_sorted, de.labels_sorted)
    assert se.all_members() == de.all_members()
    assert se.waters.lw == de.waters.lw and se.waters.hw == de.waters.hw


K = 16             # the issue's multiclass width


@pytest.mark.parametrize("policy", ["eager", "lazy", "hybrid"])
def test_sql_equals_direct_multiclass_k16(policy):
    c = multiclass_corpus("eqk", 360, 24, K, seed=4)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.classes, num_classes=K)
    catalog.create_view("v", "t", "svm",
                        {"policy": policy, "k": K, "p": 2, "q": 2,
                         "cost_mode": "modeled"})
    buffer_frac = 0.01 if policy == "hybrid" else 0.0
    direct = MulticlassView(c.features, K, policy=policy, lr=0.1, l2=1e-4,
                            alpha=1.0, p=2.0, q=2.0, cost_mode="modeled",
                            buffer_frac=buffer_frac, vectorized=True)
    ex = Executor(catalog, group_commit=GROUP)
    mirror = DirectMirror(
        lambda ids, ys: direct.insert_examples(
            [int(i) for i in ids], [int(y) for y in ys]))
    facade = catalog.view("v").facade
    n = c.features.shape[0]
    rng = np.random.default_rng(77)

    for step in range(160):
        u = rng.random()
        if u < 0.62:                                       # INSERT batch
            m = int(rng.integers(1, 5))
            rows = [(int(rng.integers(0, n)),) for _ in range(m)]
            rows = [(i, int(c.classes[i])) for (i,) in rows]
            ex.execute_one(
                "INSERT INTO t (id, class) VALUES "
                + ", ".join(f"({i}, {cl})" for i, cl in rows))
            for i, cl in rows:
                mirror.dml(i, cl)
        elif u < 0.78:                                     # one-view point
            i = int(rng.integers(0, n))
            v = int(rng.integers(0, K))
            got = ex.execute_one(
                f"SELECT label FROM v WHERE id = {i} AND view = {v}"
            ).rows[0][0]
            mirror.flush()
            if policy == "hybrid":
                want, _ = direct.engine.hybrid_label(v, i)
            else:
                want = direct.engine.label(v, i)
            assert got == want, (step, i, v)
        elif u < 0.88:                                     # all-views point
            i = int(rng.integers(0, n))
            got = [r[2] for r in ex.execute_one(
                f"SELECT id, view, label FROM v WHERE id = {i}").rows]
            mirror.flush()
            if policy == "hybrid":
                want = direct.engine.hybrid_labels_of(i)[0]
            else:
                want = direct.engine.labels_of(i)
            assert np.array_equal(got, want), (step, i)
        elif u < 0.95:                                     # COUNT one class
            v = int(rng.integers(0, K))
            got = ex.execute_one(
                f"SELECT count(*) FROM v WHERE class = {v}").rows[0][0]
            mirror.flush()
            assert got == direct.engine.all_members()[v], step
        else:                                              # UPDATE MODEL
            ex.execute_one("UPDATE MODEL ON v")
            mirror.flush()
            direct.engine.apply_models(direct.W, direct.b)

    ex.execute_one("COMMIT")
    mirror.flush()
    se, de = facade.mc.engine, direct.engine
    assert np.array_equal(se.all_members(), de.all_members())
    assert np.array_equal(se.labels_sorted, de.labels_sorted)
    assert np.array_equal(se.perm, de.perm)
    assert np.array_equal(se.lw, de.lw) and np.array_equal(se.hw, de.hw)
    assert np.array_equal(se.pending, de.pending)
    assert np.array_equal(se.reorg_counts, de.reorg_counts)
    assert se.check_consistent() and de.check_consistent()


def test_sql_equals_direct_sharded():
    """Third engine behind the catalog: `ShardedMultiViewHazy` on a (1, 1)
    host mesh (interpret-mode Pallas kernel). The SQL path's stacked SGD +
    kernel rounds must match a hand-driven sharded twin exactly."""
    jax = pytest.importorskip("jax")
    if jax.default_backend() not in ("cpu", "tpu"):
        pytest.skip("needs cpu or tpu")
    from repro.core.sharded import ShardedMultiViewHazy
    from repro.core.waters import holder_M
    from repro.launch.mesh import make_host_mesh

    k, n, d = 4, 256, 16
    c = multiclass_corpus("eqs", n, d, k, seed=9)
    F = np.ascontiguousarray(c.features, np.float32)
    catalog = Catalog()
    catalog.register_table("t", F, truth=c.classes, num_classes=k)
    catalog.create_view("v", "t", "svm",
                        {"engine": "sharded", "k": k, "p": 2, "q": 2,
                         "cap_frac": 0.5})
    facade = catalog.view("v").facade
    ex = Executor(catalog, group_commit=GROUP)

    driver = ShardedMultiViewHazy(mesh=make_host_mesh((1, 1)), n=n, d=d, k=k,
                                  M=holder_M(F, 2.0), p=2.0, cap_frac=0.5)
    state = driver.init_state(F)
    W = np.zeros((k, d), np.float32)
    b = np.zeros(k, np.float64)
    lr, l2 = 0.1, 1e-4
    pending = []

    def flush():
        nonlocal state, W, b
        if not pending:
            return
        for i, cls in pending:
            f = F[i]
            y = np.where(np.arange(k) == cls, 1.0, -1.0)
            z = W @ f - b.astype(np.float32)
            g = np.where(y * z.astype(np.float64) < 1.0, -y, 0.0)
            W = W * (1.0 - lr * l2)
            W -= (lr * g).astype(np.float32)[:, None] * f[None, :]
            b = b - lr * (-g)
        state = driver.apply_models(state, W, b)
        pending.clear()

    rng = np.random.default_rng(123)
    for _ in range(10):
        rows = [(int(rng.integers(0, n)),) for _ in range(GROUP)]
        rows = [(i, int(c.classes[i])) for (i,) in rows]
        ex.execute_one("INSERT INTO t (id, class) VALUES "
                       + ", ".join(f"({i}, {cl})" for i, cl in rows))
        for i, cl in rows:
            pending.append((i, cl))
            if len(pending) >= GROUP:
                flush()
        # point read through SQL vs the direct probe+margin pair
        i = int(rng.integers(0, n))
        got = [r[2] for r in ex.execute_one(
            f"SELECT id, view, label FROM v WHERE id = {i}").rows]
        flush()
        want, _ = driver.hybrid_labels_of(state, W, b, i)
        assert np.array_equal(got, want), i

    ex.execute_one("COMMIT")
    flush()
    assert np.array_equal(facade.counts(), driver.all_members(state))
    assert np.array_equal(np.asarray(facade.state.labels),
                          np.asarray(state.labels))
    assert np.array_equal(np.asarray(facade.state.gids),
                          np.asarray(state.gids))
    assert np.array_equal(facade.driver.lw, driver.lw)
    assert np.array_equal(facade.driver.hw, driver.hw)
    assert facade.driver.skiing.reorgs == driver.skiing.reorgs


# ---------------------------------------------------------------------------
# Hybrid point SELECTs: tier counters (acceptance criterion)
# ---------------------------------------------------------------------------

def test_hybrid_point_selects_touch_F_only_on_probe_miss():
    c = synthetic_corpus("tier", 500, 24, seed=6)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": "hybrid", "p": 2, "q": 2,
                         "buffer_frac": 0.02, "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=GROUP)
    facade = catalog.view("v").facade
    n = c.features.shape[0]
    rng = np.random.default_rng(8)
    for _ in range(12):
        rows = [(int(rng.integers(0, n)),) for _ in range(GROUP)]
        ex.execute_one("INSERT INTO t (id, label) VALUES " + ", ".join(
            f"({i}, {int(c.labels[i])})" for (i,) in rows))

    before = dict(facade.tier_hits)
    disk_before = facade.disk_touches
    reads = 200
    for _ in range(reads):
        i = int(rng.integers(0, n))
        ex.execute_one(f"SELECT label FROM v WHERE id = {i}")
    hits = {t: facade.tier_hits[t] - before[t] for t in facade.tier_hits}
    # every read resolved by the §3.5.2 tier chain, none by plain map reads
    assert hits["map"] == 0
    assert hits["water"] + hits["buffer"] + hits["disk"] == reads
    # THE acceptance check: the feature table was touched exactly once per
    # probe miss ("disk" tier) and never otherwise
    assert facade.disk_touches - disk_before == hits["disk"]
    assert hits["water"] > 0          # the waters tier did real work
    # labels stay exact w.r.t. the current model
    m = facade.view.model
    truth = np.where(c.features @ m.w - m.b >= 0, 1, -1)
    for i in range(0, n, 17):
        got = ex.execute_one(
            f"SELECT label FROM v WHERE id = {i}").rows[0][0]
        assert got == truth[i]


def test_explain_point_select_reports_actual_tier():
    c = synthetic_corpus("expl", 400, 16, seed=12)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": "hybrid", "p": 2, "q": 2,
                         "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=4)
    rng = np.random.default_rng(3)
    n = c.features.shape[0]
    for _ in range(10):
        i = int(rng.integers(0, n))
        ex.execute_one(
            f"INSERT INTO t (id, label) VALUES ({i}, {int(c.labels[i])})")
    before = dict(catalog.view("v").facade.tier_hits)
    res = ex.execute_one("EXPLAIN SELECT label FROM v WHERE id = 7")
    assert res.columns[0] == "step"
    kinds = [r[0] for r in res.rows]
    assert kinds == ["point", "probe(actual)"]
    est_row, actual_row = res.rows
    assert est_row[1].startswith("probe(")        # planned tier chain
    assert actual_row[1] in ("water", "buffer", "disk")
    # the dry-run probe is tier-counted like any §3.5.2 probe
    after = catalog.view("v").facade.tier_hits
    assert sum(after.values()) == sum(before.values()) + 1
    assert after[actual_row[1]] == before[actual_row[1]] + 1
    # non-point EXPLAINs price the band partition
    res = ex.execute_one("EXPLAIN SELECT id FROM v WHERE label = 1")
    assert res.rows[0][0] == "scan"
    assert res.rows[0][1] == "band-partition"
    assert res.rows[0][2] >= 0


# ---------------------------------------------------------------------------
# Scans, top-k, WAL replay
# ---------------------------------------------------------------------------

def _warm_executor(policy="hybrid", seed=21):
    c = synthetic_corpus("scan", 400, 16, seed=seed)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": policy, "p": 2, "q": 2,
                         "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=GROUP)
    rng = np.random.default_rng(seed)
    n = c.features.shape[0]
    for _ in range(8):
        rows = [int(rng.integers(0, n)) for _ in range(GROUP)]
        ex.execute_one("INSERT INTO t (id, label) VALUES " + ", ".join(
            f"({i}, {int(c.labels[i])})" for i in rows))
    ex.execute_one("COMMIT")
    return c, catalog, ex


def test_band_scan_matches_members_and_count():
    c, catalog, ex = _warm_executor()
    eng = catalog.view("v").facade.view.engine
    got = sorted(r[0] for r in ex.execute_one(
        "SELECT id FROM v WHERE label = 1"))
    assert got == sorted(int(x) for x in eng.members())
    cnt = ex.execute_one("SELECT count(*) FROM v WHERE label = 1").rows[0][0]
    assert cnt == len(got) == eng.all_members()
    neg = ex.execute_one("SELECT count(*) FROM v WHERE label = -1").rows[0][0]
    assert cnt + neg == c.features.shape[0]


def test_topk_margin_matches_bruteforce():
    c, catalog, ex = _warm_executor()
    facade = catalog.view("v").facade
    m = facade.view.model
    z = np.asarray(c.features @ m.w - m.b, np.float64)
    for desc in (True, False):
        order = "DESC" if desc else "ASC"
        rows = ex.execute_one(
            f"SELECT id, margin FROM v ORDER BY margin {order} LIMIT 9").rows
        got = np.array([r[1] for r in rows])
        want = np.sort(z)[::-1][:9] if desc else np.sort(z)[:9]
        assert np.allclose(got, want), order
    # the plan prices candidates, not the full table
    res = ex.execute_one(
        "EXPLAIN SELECT id, margin FROM v ORDER BY margin DESC LIMIT 9")
    assert res.rows[0][0] == "topk"
    assert res.rows[0][2] <= c.features.shape[0]


def test_topk_margin_exact_under_pending_lazy_model():
    """ORDER BY margin must widen the Eq. 2 candidate slack by the PENDING
    model's drift: a lazy flush right before the read leaves the engine
    waters stale, and the stale slack can exclude true top-k rows."""
    c = synthetic_corpus("lzk", 400, 16, seed=25)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": "lazy", "p": 2, "q": 2,
                         "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=64)   # stays pending until the read
    rng = np.random.default_rng(26)
    n = c.features.shape[0]
    facade = catalog.view("v").facade
    touched_beyond_limit = False
    for _ in range(4):
        rows = [int(rng.integers(0, n)) for _ in range(20)]
        ex.execute_one("INSERT INTO t (id, label) VALUES " + ", ".join(
            f"({i}, {int(c.labels[i])})" for i in rows))
        ex.execute_one("COMMIT")
        # pin a freshly clustered state: waters (0, 0), stored eps = this
        # model's margins — any later drift exists ONLY in the pending model
        facade.view.engine.reorganize()
        rows = [int(rng.integers(0, n)) for _ in range(20)]
        ex.execute_one("INSERT INTO t (id, label) VALUES " + ", ".join(
            f"({i}, {-int(c.labels[i])})" for i in rows))
        # the SELECT flushes the queued group -> apply_model defers with
        # engine waters NOT updated, then top-k runs against the pending
        # model: only the prospective Eq. 2 slack keeps it exact
        got = [r[1] for r in ex.execute_one(
            "SELECT id, margin FROM v ORDER BY margin DESC LIMIT 6").rows]
        _, _, touched = facade.top_margins(0, 6, True)
        touched_beyond_limit |= touched > 6
        m = facade.view.model
        z = np.asarray(c.features @ m.w - m.b, np.float64)
        assert np.allclose(got, np.sort(z)[::-1][:6])
    assert touched_beyond_limit     # the pending drift really widened slack


def test_delete_rejected_before_wal_on_multiview():
    """DELETE on a table whose view cannot retrain must fail BEFORE the
    record enters the WAL — queued DML survives and later commits."""
    k = 4
    mc = multiclass_corpus("del", 300, 16, k, seed=27)
    catalog = Catalog()
    catalog.register_table("t", mc.features, truth=mc.classes, num_classes=k)
    catalog.create_view("v", "t", "svm", {"k": k, "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=64)
    ex.execute_one("INSERT INTO t (id, class) VALUES (1, 2), (3, 0)")
    with pytest.raises(PlanError):
        ex.execute_one("DELETE FROM t WHERE id = 1")
    with pytest.raises(PlanError):               # EXPLAIN surfaces it too
        ex.execute_one("EXPLAIN DELETE FROM t WHERE id = 1")
    # nothing was lost: both queued inserts commit as one round
    assert len(ex.log.pending["t"]) == 2
    ex.execute_one("COMMIT")
    assert catalog.view("v").facade.engine.stats.rounds == 1


def test_point_select_conjoined_label_predicate_filters():
    c, catalog, ex = _warm_executor(seed=28)
    eng = catalog.view("v").facade.view.engine
    pos = int(eng.members()[0])
    hit = ex.execute_one(
        f"SELECT id, label FROM v WHERE id = {pos} AND label = 1").rows
    miss = ex.execute_one(
        f"SELECT id, label FROM v WHERE id = {pos} AND label = -1").rows
    assert hit == [(pos, 1)] and miss == []


def test_bare_count_star_is_table_cardinality():
    c, catalog, ex = _warm_executor(seed=30)
    n = c.features.shape[0]
    assert ex.execute_one("SELECT count(*) FROM v").rows == [(n,)]
    res = ex.execute_one("EXPLAIN SELECT count(*) FROM v")
    assert res.rows[0][1] == "table-cardinality"
    pos = ex.execute_one("SELECT count(*) FROM v WHERE label = 1").rows[0][0]
    assert 0 < pos < n


def test_class_scan_honors_conjoined_label_polarity():
    """class = c selects the one-vs-all view; a conjoined label = -1 must
    return that view's NON-members (and agree with the count branch)."""
    k = 3
    mc = multiclass_corpus("pol", 240, 16, k, seed=35)
    catalog = Catalog()
    catalog.register_table("t", mc.features, truth=mc.classes, num_classes=k)
    catalog.create_view("v", "t", "svm", {"k": k, "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=8)
    rng = np.random.default_rng(36)
    for _ in range(6):
        rows = [int(rng.integers(0, 240)) for _ in range(8)]
        ex.execute_one("INSERT INTO t (id, class) VALUES " + ", ".join(
            f"({i}, {int(mc.classes[i])})" for i in rows))
    pos = ex.execute_one("SELECT id FROM v WHERE class = 1").rows
    neg = ex.execute_one("SELECT id FROM v WHERE class = 1 AND label = -1").rows
    assert len(pos) + len(neg) == 240
    assert not (set(r[0] for r in pos) & set(r[0] for r in neg))
    cnt_neg = ex.execute_one(
        "SELECT count(*) FROM v WHERE class = 1 AND label = -1").rows[0][0]
    assert cnt_neg == len(neg)


def test_logistic_rejected_on_multiview_engines():
    mc = multiclass_corpus("logi", 240, 16, 3, seed=37)
    catalog = Catalog()
    catalog.register_table("t", mc.features, truth=mc.classes, num_classes=3)
    with pytest.raises(PlanError):       # would silently train hinge SVM
        catalog.create_view("v", "t", "logistic", {"k": 3})
    c2 = synthetic_corpus("logi1", 240, 16, seed=38)
    catalog.register_table("b", c2.features, truth=c2.labels)
    catalog.create_view("w", "b", "logistic", {})    # k = 1 hazy: fine
    assert catalog.view("w").facade.view.method == "logistic"


def test_point_select_limit_caps_probes():
    c, catalog, ex = _warm_executor(seed=33)
    facade = catalog.view("v").facade
    before = sum(facade.tier_hits.values())
    ids = ", ".join(str(i) for i in range(40))
    res = ex.execute_one(
        f"SELECT id, label FROM v WHERE id IN ({ids}) LIMIT 3")
    assert len(res.rows) == 3
    assert len(res.tiers_used) == 3          # probed 3 ids, not 40
    assert sum(facade.tier_hits.values()) - before == 3


def test_wal_replay_reproduces_engine_state(tmp_path):
    wal_file = str(tmp_path / "log.jsonl")
    c = synthetic_corpus("replay", 300, 16, seed=14)

    def fresh_catalog():
        cat = Catalog()
        cat.register_table("t", c.features, truth=c.labels)
        cat.create_view("v", "t", "svm",
                        {"policy": "lazy", "p": 2, "q": 2,
                         "cost_mode": "modeled"})
        return cat

    catalog = fresh_catalog()
    ex = Executor(catalog, group_commit=5, wal_path=wal_file)
    rng = np.random.default_rng(15)
    n = c.features.shape[0]
    for _ in range(37):
        i = int(rng.integers(0, n))
        ex.execute_one(
            f"INSERT INTO t (id, label) VALUES ({i}, {int(c.labels[i])})")
    ex.execute_one("COMMIT")
    ex.log.close()

    # recovery: load the JSONL history, replay into a fresh catalog — commit
    # boundaries come from the markers, so the engine trajectory is identical
    history = UpdateLog.load(wal_file)
    assert any(r.op == "commit" for r in history)
    catalog2 = fresh_catalog()
    UpdateLog.replay_into(history, catalog2)
    e1 = catalog.view("v").facade.view.engine
    e2 = catalog2.view("v").facade.view.engine
    assert e1.all_members() == e2.all_members()
    assert np.array_equal(e1.labels_sorted, e2.labels_sorted)
    assert e1.waters.lw == e2.waters.lw and e1.waters.hw == e2.waters.hw
    assert e1.skiing.reorgs == e2.skiing.reorgs


def test_group_commit_amortizes_rounds():
    """G inserts -> ONE engine round per commit, not G rounds."""
    c = synthetic_corpus("amort", 300, 16, seed=18)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": "eager", "p": 2, "q": 2,
                         "cost_mode": "modeled"})
    ex = Executor(catalog, group_commit=16)
    eng = catalog.view("v").facade.view.engine
    for j in range(32):
        ex.execute_one(f"INSERT INTO t (id, label) VALUES "
                       f"({j}, {int(c.labels[j])})")
    assert ex.log.commits == 2
    assert eng.stats.rounds == 2          # one apply_model per group commit


def test_plan_errors():
    _c, _catalog, ex = _warm_executor(seed=22)
    with pytest.raises(PlanError):
        ex.execute_one("SELECT label FROM nope WHERE id = 1")
    with pytest.raises(PlanError):
        ex.execute_one("SELECT label FROM v WHERE id = 99999")
    with pytest.raises(PlanError):
        ex.execute_one("CREATE CLASSIFICATION VIEW v2 ON t USING MODEL svm "
                       "WITH (bogus = 1)")
    # k > 1 point label reads must disambiguate the view
    cat2 = Catalog()
    k = 3
    mc = multiclass_corpus("amb", 300, 16, k, seed=19)
    cat2.register_table("m", mc.features, truth=mc.classes, num_classes=k)
    cat2.create_view("w", "m", "svm", {"k": k, "cost_mode": "modeled"})
    ex2 = Executor(cat2, group_commit=4)
    with pytest.raises(PlanError):
        ex2.execute_one("SELECT id, label FROM w WHERE id = 1")
    # ...but view=, the view column, or class all work
    assert ex2.execute_one("SELECT id, view, label FROM w WHERE id = 1").rows
    assert ex2.execute_one(
        "SELECT label FROM w WHERE id = 1 AND view = 2").rows
    assert ex2.execute_one("SELECT id, class FROM w WHERE id = 1").rows


def test_prepared_point_select_equals_direct_and_caches_route():
    """PREPARE/EXECUTE: identical rows to the equivalent SELECT, the plan
    route cached after the first EXECUTE (repeats skip parse+plan), and
    the guards still fire (arity, unknown name, id range)."""
    c, catalog, ex = _warm_executor(seed=44)
    n = c.features.shape[0]
    res = ex.execute_one("PREPARE pt AS SELECT label FROM v WHERE id = ?")
    assert res.rows == [("pt", 1)]
    assert ex.prepared["pt"].plan is None        # planned lazily
    first = ex.execute_one("EXECUTE pt (3)")
    cached = ex.prepared["pt"].plan
    assert cached is not None and cached.kind == "point"
    rng = np.random.default_rng(45)
    for i in rng.integers(0, n, 25):
        got = ex.execute_one(f"EXECUTE pt ({int(i)})").rows
        want = ex.execute_one(f"SELECT label FROM v WHERE id = {int(i)}").rows
        assert got == want, i
    assert ex.prepared["pt"].plan is cached      # route reused, not re-planned
    # read-your-writes still holds on the cached route
    j = int(rng.integers(0, n))
    ex.execute_one(f"INSERT INTO t (id, label) VALUES ({j}, {int(c.labels[j])})")
    got = ex.execute_one(f"EXECUTE pt ({j})").rows[0][0]
    assert got == int(np.sign(0.5 + np.sign(
        c.features[j] @ catalog.view("v").facade.view.model.w
        - catalog.view("v").facade.view.model.b)))
    # programmatic zero-parse path agrees
    assert ex.execute_prepared("pt", [j]).rows == [(got,)]
    from repro.rdbms import SqlError
    with pytest.raises(SqlError):
        ex.execute_one("EXECUTE pt (1, 2)")      # wrong arity
    with pytest.raises(SqlError):
        ex.execute_one("EXECUTE nope (1)")       # unknown name
    with pytest.raises(PlanError):
        ex.execute_one(f"EXECUTE pt ({n + 5})")  # cached route keeps the guard
    with pytest.raises(ParseError):
        ex.execute_one("SELECT label FROM v WHERE id = ?")   # ? needs PREPARE
    with pytest.raises(SqlError):
        ex.execute_one("PREPARE pt AS SELECT label FROM v WHERE id = ?")


def test_prepared_non_point_statements_bind_params():
    c, catalog, ex = _warm_executor(seed=46)
    ex.execute_one("PREPARE cnt AS SELECT count(*) FROM v WHERE label = ?")
    pos = ex.execute_one("EXECUTE cnt (1)").rows[0][0]
    neg = ex.execute_one("EXECUTE cnt (-1)").rows[0][0]
    assert pos + neg == c.features.shape[0]
    from repro.rdbms import SqlError
    with pytest.raises(SqlError):
        ex.execute_one("EXECUTE cnt (2)")        # label must bind to ±1
    ex.execute_one("PREPARE upd AS UPDATE t SET label = ? WHERE id = ?")
    ex.execute_one("EXECUTE upd (1, 5)")
    ex.execute_one("COMMIT")                     # flushes through the WAL
    assert any(r.op == "update" and r.entity_id == 5 for r in ex.log.history)


def test_memory_budget_view_tier_counters_reconcile():
    """SQL acceptance for the storage tier: a hybrid view WITH
    memory_budget answers point SELECTs through water/buffer/pool/disk,
    cold feature reads == the pool's miss count, and SHOW STORAGE renders
    the pool's residency."""
    c = synthetic_corpus("stor", 500, 24, seed=47)
    catalog = Catalog()
    catalog.register_table("t", c.features, truth=c.labels)
    catalog.create_view("v", "t", "svm",
                        {"policy": "hybrid", "p": 2, "q": 2,
                         "buffer_frac": 0.02, "cost_mode": "modeled",
                         "memory_budget": 0.1, "page_bytes": 1024})
    ex = Executor(catalog, group_commit=GROUP)
    facade = catalog.view("v").facade
    n = c.features.shape[0]
    rng = np.random.default_rng(48)
    for _ in range(12):
        rows = [(int(rng.integers(0, n)),) for _ in range(GROUP)]
        ex.execute_one("INSERT INTO t (id, label) VALUES " + ", ".join(
            f"({i}, {int(c.labels[i])})" for (i,) in rows))
    st0 = facade.storage_stats()
    assert st0 is not None and st0["budget_bytes"] == int(0.1 * c.features.nbytes)
    before = dict(facade.tier_hits)
    disk_before = facade.disk_touches
    misses_before = st0["misses"]
    reads = 200
    for _ in range(reads):
        i = int(rng.integers(0, n))
        ex.execute_one(f"SELECT label FROM v WHERE id = {i}")
    hits = {t: facade.tier_hits[t] - before[t] for t in facade.tier_hits}
    assert hits["map"] == 0
    assert (hits["water"] + hits["buffer"] + hits["pool"]
            + hits["disk"]) == reads
    # cold reads are exactly the disk tier; pool hits stayed in memory
    st1 = facade.storage_stats()
    assert facade.disk_touches - disk_before == hits["disk"]
    assert st1["misses"] - misses_before == hits["disk"]
    # the planner advertises the pool in the probe chain
    res = ex.execute_one("EXPLAIN SELECT label FROM v WHERE id = 0")
    assert res.rows[0][1] == "probe(water->buffer->pool->disk)"
    assert res.rows[1][1] in ("water", "buffer", "pool", "disk")
    # SHOW STORAGE renders this view's pool, in-RAM views say so
    catalog.create_view("w", "t", "svm", {"cost_mode": "modeled"})
    show = ex.execute_one("SHOW STORAGE")
    by_name = {r[0]: r for r in show.rows}
    assert by_name["v"][2] == st1["budget_bytes"]
    assert by_name["w"][2] == "in-ram"
    # labels stay exact w.r.t. the current model through the pool
    m = facade.view.model
    truth = np.where(c.features @ m.w - m.b >= 0, 1, -1)
    for i in range(0, n, 17):
        got = ex.execute_one(
            f"SELECT label FROM v WHERE id = {i}").rows[0][0]
        assert got == truth[i]


def test_repl_run_script(capsys):
    from repro.rdbms.repl import run_script
    ex = run_script("""
        CREATE TABLE t FROM CORPUS synthetic WITH (scale = 0.08);
        CREATE CLASSIFICATION VIEW v ON t USING MODEL svm
            WITH (policy = hybrid, cost_mode = modeled);
        INSERT INTO t (id, label) VALUES (0, 1), (1, -1), (2, 1);
        SELECT count(*) FROM v WHERE label = 1;
        SHOW TABLES;
    """)
    out = capsys.readouterr().out
    assert "count" in out and "(1 rows)" in out
    assert "t" in ex.catalog.tables and "v" in ex.catalog.views


# ---------------------------------------------------------------------------
# ISSUE 6: epoch-stamped results + read-your-writes at the executor level
# ---------------------------------------------------------------------------

def test_results_carry_commit_epoch_and_reads_flush_pending():
    """Every Result reports the committed WAL batch index it observed
    (the snapshot version); a read over a table with pending DML flushes
    the group first, so its epoch is the POST-flush index and the
    session's own writes are always visible to its next read."""
    c, catalog, ex = _warm_executor(seed=44)
    epoch0 = ex.log.commits
    assert ex.epoch == epoch0

    # a pending (sub-group) insert: DML reports the epoch after its append
    res = ex.execute_one("INSERT INTO t (id, label) VALUES "
                         f"(5, {int(c.labels[5])})")
    assert res.epoch == epoch0 and ex.log.has_pending("t")

    # the next read flushes first — read-your-writes — and pins AFTER
    r1 = ex.execute_one("SELECT label FROM v WHERE id = 5")
    assert r1.epoch == epoch0 + 1
    assert not ex.log.has_pending("t")

    # reads with nothing pending do not advance anything
    r2 = ex.execute_one("SELECT label FROM v WHERE id = 7")
    assert r2.epoch == ex.log.commits == epoch0 + 1

    # the nested dispatch (EXECUTE -> SELECT) runs inside ONE guard and
    # stamps the same pinned epoch
    ex.execute_one("PREPARE e6 AS SELECT label FROM v WHERE id = ?")
    r3 = ex.execute_one("EXECUTE e6 (7)")
    assert r3.epoch == epoch0 + 1
    assert r3.rows == r2.rows
