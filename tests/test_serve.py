"""Concurrent SQL server: wire protocol, sessions, and the epoch gate.

The contracts under test (ISSUE 6):

  * wire frames round-trip (numpy scalars included) and oversized /
    desynced frames fail fast instead of hanging a reader;
  * the epoch gate really is snapshot isolation by scheduling — while any
    shared reader is pinned at epoch E, no commit can advance the epoch
    to E+1, and a waiting writer blocks new readers (no starvation);
  * one connection == one session: read-your-writes over the wire, a
    private prepared-statement namespace, and statement errors that keep
    the session alive;
  * concurrency changes scheduling, NEVER results: a mixed read/write
    swarm leaves the engines in a state byte-identical to the same WAL
    replayed serially, with the same commit boundaries;
  * `start_server_thread` raises when it cannot bind (the benchmark and
    the CI serve job gate on this).
"""
import logging
import socket
import threading
import time

import numpy as np
import pytest

from repro.data import multiclass_corpus
from repro.rdbms import (Catalog, EpochGate, Executor, ServerError, Session,
                         SqlClient, UpdateLog, start_server_thread)
from repro.rdbms.wire import (MAX_FRAME, WireError, decode_payload,
                              encode_frame, frame_length)


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_wire_roundtrip_coerces_numpy_scalars():
    obj = {"op": "query", "rows": [[np.int64(3), np.float32(0.5)]],
           "arr": np.arange(3)}
    frame = encode_frame(obj)
    assert frame_length(frame[:4]) == len(frame) - 4
    back = decode_payload(frame[4:])
    assert back["rows"] == [[3, 0.5]] and back["arr"] == [0, 1, 2]


def test_wire_rejects_oversized_and_desynced_frames():
    with pytest.raises(WireError):
        frame_length((MAX_FRAME + 1).to_bytes(4, "big"))
    with pytest.raises(TypeError):
        encode_frame({"bad": object()})


# ---------------------------------------------------------------------------
# epoch gate: snapshot isolation by scheduling
# ---------------------------------------------------------------------------

def test_gate_writer_waits_for_pinned_readers():
    gate = EpochGate()
    entered = threading.Event()
    done = []

    def writer():
        with gate.write():
            entered.set()
            done.append(True)

    with gate.read():
        t = threading.Thread(target=writer, daemon=True)
        t.start()
        assert not entered.wait(0.15)       # blocked behind the pinned read
        assert not done
    t.join(5)
    assert done                             # released the instant we unpin


def test_gate_waiting_writer_blocks_new_readers():
    gate = EpochGate()
    writer_in = threading.Event()
    reader_in = threading.Event()
    release = threading.Event()

    def slow_reader():
        with gate.read():
            reader_in.set()
            release.wait(5)

    def writer():
        with gate.write():
            writer_in.set()

    r = threading.Thread(target=slow_reader, daemon=True)
    r.start()
    assert reader_in.wait(5)
    w = threading.Thread(target=writer, daemon=True)
    w.start()
    time.sleep(0.05)                        # writer now queued
    late = threading.Event()

    def late_reader():
        with gate.read():
            late.set()

    lr = threading.Thread(target=late_reader, daemon=True)
    lr.start()
    assert not late.wait(0.15)              # queued behind the writer
    release.set()
    assert writer_in.wait(5) and late.wait(5)
    for t in (r, w, lr):
        t.join(5)


def test_reader_pinned_epoch_cannot_advance_midstatement():
    """While a shared reader holds the gate, `log.commits` is frozen: a
    full group's worth of INSERTs lands only after the reader unpins."""
    ex = _executor(group_commit=4)
    committed = threading.Event()

    def writer():
        for i in range(4):                  # exactly one group commit
            ex.execute_one(f"INSERT INTO t (id, class) VALUES "
                           f"({i}, {int(_CORPUS.classes[i])})")
        committed.set()

    with ex.gate.read():
        epoch0 = ex.log.commits
        t = threading.Thread(target=writer, daemon=True)
        t.start()
        assert not committed.wait(0.2)      # the commit is gated out
        assert ex.log.commits == epoch0     # snapshot never moved
    t.join(5)
    assert committed.is_set() and ex.log.commits == epoch0 + 1


# ---------------------------------------------------------------------------
# executor sessions (no sockets): read-your-writes + private PREPAREs
# ---------------------------------------------------------------------------

_CORPUS = multiclass_corpus("serve_t", 300, 16, 4, seed=11)


def _executor(group_commit=64):
    catalog = Catalog()
    catalog.register_table("t", _CORPUS.features, truth=_CORPUS.classes,
                           num_classes=_CORPUS.num_classes)
    catalog.create_view("v", "t", "svm",
                        {"k": _CORPUS.num_classes, "policy": "hybrid",
                         "cost_mode": "modeled"})
    return Executor(catalog, group_commit=group_commit)


def test_session_insert_then_select_sees_own_commit():
    ex = _executor(group_commit=64)         # group far from full: the
    s = Session(ex)                         # flush must come from the read
    i, c = 7, int(_CORPUS.classes[7])
    s.execute(f"INSERT INTO t (id, class) VALUES ({i}, {c})")
    assert ex.log.has_pending("t")
    rows = s.execute_one(f"SELECT id FROM v WHERE class = {c}").rows
    assert [i] in [[r[0]] for r in rows]    # own write is visible
    assert not ex.log.has_pending("t")      # the read flushed the group
    assert ex.log.commits == 1


def test_point_read_carries_the_pinned_epoch():
    ex = _executor(group_commit=2)
    s = Session(ex)
    for j in range(4):
        s.execute(f"INSERT INTO t (id, class) VALUES "
                  f"({j}, {int(_CORPUS.classes[j])})")
    assert ex.log.commits == 2
    res = s.execute_one("SELECT label FROM v WHERE id = 1 AND view = 2")
    assert res.epoch == 2                   # snapshot version, user-visible


def test_sessions_have_private_prepared_namespaces():
    ex = _executor()
    s1, s2 = Session(ex), Session(ex)
    s1.execute("PREPARE pt AS SELECT label FROM v WHERE id = ? AND view = ?")
    s2.execute("PREPARE pt AS SELECT id FROM v WHERE class = ?")
    r1 = s1.execute_prepared("pt", [3, 1])
    r2 = s2.execute_prepared("pt", [2])
    assert tuple(r1.columns) == ("label",) and tuple(r2.columns) == ("id",)
    assert "pt" not in ex.prepared          # the REPL namespace is untouched
    assert s1.session_id != s2.session_id


# ---------------------------------------------------------------------------
# over the wire
# ---------------------------------------------------------------------------

def test_server_ddl_dml_select_roundtrip():
    handle = start_server_thread()
    host, port = handle.address
    try:
        with SqlClient.connect(host, port) as c:
            c.run("CREATE TABLE papers FROM CORPUS synthetic "
                    "WITH (scale = 0.08); "
                    "CREATE CLASSIFICATION VIEW topics ON papers "
                    "USING MODEL svm WITH (policy = hybrid)")
            epoch0 = c.ping()
            c.run("INSERT INTO papers (id, label) VALUES (3, 1)")
            res = c.run_one("SELECT id, label FROM topics WHERE id = 3")
            assert res.rows and res.rows[0][0] == 3
            assert res.epoch == epoch0 + 1  # read-your-writes flushed
            assert c.ping() == epoch0 + 1
    finally:
        handle.stop()


def test_statement_error_keeps_the_session_alive():
    handle = start_server_thread(_executor())
    host, port = handle.address
    try:
        with SqlClient.connect(host, port) as c:
            with pytest.raises(ServerError):
                c.run("SELECT label FROM nope WHERE id = 1")
            sid = c.session_id
            res = c.run_one("SELECT label FROM v WHERE id = 1 AND view = 0")
            assert res.rows and c.session_id == sid   # same session survived
    finally:
        handle.stop()


def test_statement_error_carries_type_and_logs_server_side(caplog):
    """A planner error crosses the wire WITH its class name (the client
    re-raises typed, str() leads with the type) and leaves a server-side
    log line naming the session — debugging is blind without either."""
    handle = start_server_thread(_executor())
    host, port = handle.address
    try:
        with SqlClient.connect(host, port) as c:
            with caplog.at_level(logging.WARNING,
                                 logger="repro.rdbms.server"):
                with pytest.raises(ServerError) as ei:
                    c.run("SELECT label FROM nope WHERE id = 1")
            assert ei.value.error_type == "PlanError"
            assert str(ei.value).startswith("PlanError: ")
            logged = [r for r in caplog.records
                      if "statement failed" in r.getMessage()]
            assert logged, caplog.records
            assert "PlanError" in logged[0].getMessage()
            assert str(c.session_id) in logged[0].getMessage()
    finally:
        handle.stop()


def test_wire_sessions_have_private_prepared_namespaces():
    handle = start_server_thread(_executor())
    host, port = handle.address
    try:
        with SqlClient.connect(host, port) as c1, \
                SqlClient.connect(host, port) as c2:
            c1.prepare("pt", "SELECT label FROM v WHERE id = ? AND view = ?")
            c2.prepare("pt", "SELECT id FROM v WHERE class = ?")
            assert c1.run_prepared("pt", [3, 1]).columns == ["label"]
            assert c2.run_prepared("pt", [2]).columns == ["id"]
            with pytest.raises(ServerError):
                c1.run_prepared("pt", [2])       # c2's arity never leaked into c1
    finally:
        handle.stop()


def test_bind_failure_raises():
    blocker = socket.socket()
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    port = blocker.getsockname()[1]
    try:
        with pytest.raises(RuntimeError, match="bind"):
            start_server_thread(host="127.0.0.1", port=port)
    finally:
        blocker.close()


# ---------------------------------------------------------------------------
# the acceptance shape: concurrent swarm == serial WAL replay
# ---------------------------------------------------------------------------

def test_concurrent_swarm_equals_serial_replay():
    ex = _executor(group_commit=8)
    handle = start_server_thread(ex)
    host, port = handle.address
    n, k = _CORPUS.features.shape[0], _CORPUS.num_classes
    errors = []

    def worker(idx):
        rng = np.random.default_rng(500 + idx)
        try:
            with SqlClient.connect(host, port) as c:
                c.prepare("pt",
                          "SELECT label FROM v WHERE id = ? AND view = ?")
                for _ in range(30):
                    i = int(rng.integers(0, n))
                    if rng.random() < 0.7:
                        c.run_prepared("pt", [i, int(rng.integers(0, k))])
                    else:
                        c.run(f"INSERT INTO t (id, class) VALUES "
                                f"({i}, {int(_CORPUS.classes[i])})")
        except Exception as e:              # noqa: BLE001
            errors.append((idx, e))

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60)
    assert not errors, errors
    ex.execute_one("COMMIT")                # commit-terminate the history
    handle.stop()

    serial = _executor(group_commit=len(ex.log.history) + 1)
    UpdateLog.replay_into(list(ex.log.history), serial.catalog)
    f_c = ex.catalog.view("v").facade
    f_s = serial.catalog.view("v").facade
    assert np.array_equal(f_c.counts(), f_s.counts())
    for v in range(k):
        assert np.array_equal(np.sort(f_c.members(v)),
                              np.sort(f_s.members(v))), v
