"""End-to-end behaviour tests for the paper's system (ClassificationView)."""
import numpy as np
import pytest

from repro.core import ClassificationView, MulticlassView
from repro.data import dblife_like, example_stream, forest_like


def test_view_lifecycle_eager():
    corpus = forest_like(scale=0.005)
    view = ClassificationView(corpus.features, policy="eager", norm=(2.0, 2.0),
                              lr=0.05)
    stream = example_stream(corpus, seed=0, label_noise=0.0)
    for _, (i, _f, y) in zip(range(400), stream):
        view.insert_example(i, y)
    # reads are exact w.r.t. the current model
    truth = np.where(view.F @ view.model.w - view.model.b >= 0, 1, -1)
    assert view.all_members() == int(np.sum(truth == 1))
    for i in range(0, len(truth), 311):
        assert view.label(i) == truth[i]
    # members() returns exactly the positive ids
    mem = set(view.members().tolist())
    assert mem == set(np.nonzero(truth == 1)[0].tolist())


def test_view_policies_agree():
    corpus = forest_like(scale=0.005)
    stream = list(zip(range(300), example_stream(corpus, seed=1, label_noise=0.0)))
    views = {p: ClassificationView(corpus.features, policy=p, norm=(2.0, 2.0),
                                   lr=0.05) for p in ("eager", "lazy", "hybrid")}
    views["naive"] = ClassificationView(corpus.features, policy="eager",
                                        engine="naive", lr=0.05)
    for _, (i, _f, y) in stream:
        for v in views.values():
            v.insert_example(i, y)
    counts = {p: v.all_members() for p, v in views.items()}
    assert len(set(counts.values())) == 1, counts
    for i in range(0, corpus.features.shape[0], 499):
        labs = {p: v.label(i) for p, v in views.items()}
        assert len(set(labs.values())) == 1, (i, labs)


def test_view_retrain_from_scratch_matches():
    """Footnote 2: retraining replays the example log deterministically."""
    corpus = forest_like(scale=0.005)
    view = ClassificationView(corpus.features, policy="eager", norm=(2.0, 2.0),
                              lr=0.05)
    stream = example_stream(corpus, seed=2, label_noise=0.0)
    for _, (i, _f, y) in zip(range(150), stream):
        view.insert_example(i, y)
    w_before, b_before = view.model.w.copy(), view.model.b
    count_before = view.all_members()
    view.retrain_from_scratch()
    np.testing.assert_allclose(view.model.w, w_before, rtol=1e-6)
    assert view.model.b == pytest.approx(b_before)
    assert view.all_members() == count_before


def test_view_with_feature_fn_refresh():
    """The feature function is a backbone stand-in; refresh_features
    re-embeds + reclusters (paper: feature change => full reorganization)."""
    corpus = forest_like(scale=0.003)
    scale = {"v": 1.0}

    def feature_fn(X):
        return np.asarray(X, np.float32) * scale["v"]

    view = ClassificationView(corpus.features, feature_fn=feature_fn,
                              policy="eager", norm=(2.0, 2.0), lr=0.05)
    stream = example_stream(corpus, seed=3, label_noise=0.0)
    for _, (i, _f, y) in zip(range(100), stream):
        view.insert_example(i, y)
    scale["v"] = 2.0  # backbone changed
    view.refresh_features()
    truth = np.where(view.F @ view.model.w - view.model.b >= 0, 1, -1)
    assert view.all_members() == int(np.sum(truth == 1))


def test_multiclass_one_vs_all():
    r = np.random.default_rng(0)
    k, n, d = 4, 2000, 16
    centers = r.normal(size=(k, d)).astype(np.float32) * 3
    cls = r.integers(0, k, n)
    F = (centers[cls] + r.normal(size=(n, d)).astype(np.float32))
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
    mv = MulticlassView(F, k, policy="eager", lr=0.1, p=2.0, q=2.0)
    for i in r.integers(0, n, 600):
        mv.insert_example(int(i), int(cls[i]))
    pred = np.array([mv.predict(int(i)) for i in range(0, n, 7)])
    acc = float(np.mean(pred == cls[::7]))
    assert acc > 0.7, acc
    counts = mv.class_counts()
    assert len(counts) == k and all(c >= 0 for c in counts)


def test_skiing_reorganizes_under_drift():
    """A drifting model must trigger reorganizations (the SKIING choice),
    and the view must stay consistent across them."""
    corpus = dblife_like(scale=0.01)
    view = ClassificationView(corpus.features, policy="eager",
                              norm=(np.inf, 1.0), lr=0.3, cost_mode="modeled")
    stream = example_stream(corpus, seed=4, label_noise=0.2)
    for _, (i, _f, y) in zip(range(600), stream):
        view.insert_example(i, y)
    eng = view.engine
    assert eng.skiing.reorgs >= 1
    assert eng.check_consistent()
