"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.eps_affine.ops import eps_affine
from repro.kernels.eps_affine.ref import eps_affine_ref
from repro.kernels.band_reclassify.ops import (band_reclassify,
                                               multiview_band_reclassify)
from repro.kernels.band_reclassify.ref import multiview_band_reclassify_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

R = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,d", [(256, 54), (1000, 128), (513, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_eps_affine_sweep(n, d, dtype):
    F = jnp.asarray(R.normal(size=(n, d)), dtype)
    w = jnp.asarray(R.normal(size=d), jnp.float32)
    b = jnp.float32(R.normal())
    eps, lab, cnt = eps_affine(F, w, b, block_n=256, interpret=True)
    eps_r, lab_r, cnt_r = eps_affine_ref(F, w, b)
    np.testing.assert_allclose(np.asarray(eps), np.asarray(eps_r), **_tol(dtype))
    # labels may differ only where eps ~ 0 (dtype rounding at the boundary)
    disagree = np.asarray(lab) != np.asarray(lab_r)
    assert np.all(np.abs(np.asarray(eps_r)[disagree]) < 1e-2)
    assert abs(int(cnt) - int(cnt_r)) <= int(disagree.sum())


@pytest.mark.parametrize("n,d,start,end", [
    (2048, 64, 300, 700), (2048, 64, 0, 1), (2048, 64, 1500, 2048),
    (4096, 200, 100, 4000),
])
def test_band_reclassify_sweep(n, d, start, end):
    F = jnp.asarray(np.sort(R.normal(size=(n, d)), axis=0), jnp.float32)
    labels = jnp.asarray(R.integers(0, 2, n) * 2 - 1, jnp.int8)
    w = jnp.asarray(R.normal(size=d), jnp.float32)
    b = 0.1
    cap = 4096 if end - start > 1024 else 1024
    out = np.asarray(band_reclassify(F, labels, w, b, start, end,
                                     cap=min(cap, n), block_n=256,
                                     interpret=True))
    # oracle: rows in [aligned window ∩ band] relabeled, others untouched
    block_n = 256
    sb = min(max(0, start // block_n), max(0, (n - min(cap, n)) // block_n))
    w0 = sb * block_n
    width = int(np.clip(end - w0, 0, min(cap, n)))
    expect = np.asarray(labels).copy()
    z = np.asarray(F[w0:w0 + width], np.float32) @ np.asarray(w) - b
    expect[w0:w0 + width] = np.where(z >= 0, 1, -1)
    assert np.array_equal(out, expect)


@pytest.mark.parametrize("k,n,d", [(4, 2048, 64), (7, 2048, 128), (16, 4096, 32)])
def test_multiview_band_reclassify_sweep(k, n, d):
    """Multi-view kernel == per-view dynamic-slice oracle on one shared
    table, with independent per-view windows (incl. empty and clamped)."""
    F = jnp.asarray(R.normal(size=(n, d)), jnp.float32)
    labels = jnp.asarray(R.integers(0, 2, (k, n)) * 2 - 1, jnp.int8)
    W = jnp.asarray(R.normal(size=(k, d)), jnp.float32)
    b = jnp.asarray(R.normal(size=k), jnp.float32)
    starts = jnp.asarray(R.integers(0, n, k), jnp.int32)
    ends = jnp.minimum(starts + jnp.asarray(R.integers(0, 1500, k), jnp.int32), n)
    cap, block_n = 2048, 256
    out = multiview_band_reclassify(F, labels, W, b, starts, ends,
                                    cap=cap, block_n=block_n, interpret=True)
    start_blocks = jnp.clip(starts // block_n, 0, max(0, (n - cap) // block_n))
    widths = jnp.clip(ends - start_blocks * block_n, 0, cap)
    ref = multiview_band_reclassify_ref(F, labels, W, b, start_blocks, widths,
                                        cap=cap, block_n=block_n)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    # numpy cross-check: per view, window rows relabeled, others untouched
    for v in range(k):
        w0 = int(start_blocks[v]) * block_n
        wd = int(widths[v])
        expect = np.asarray(labels[v]).copy()
        z = np.asarray(F[w0:w0 + wd]) @ np.asarray(W[v]) - float(b[v])
        expect[w0:w0 + wd] = np.where(z >= 0, 1, -1)
        assert np.array_equal(np.asarray(out[v]), expect), v


def test_multiview_band_reclassify_overflow_flag():
    """A band wider than the kernel capacity is truncated — rows past the
    capacity keep STALE labels — and the per-view overflow flag must say so
    (the SKIING driver reorganizes on it instead of shipping those labels)."""
    k, n, d, cap, block_n = 3, 2048, 32, 512, 256
    F = jnp.asarray(R.normal(size=(n, d)), jnp.float32)
    labels = jnp.asarray(R.integers(0, 2, (k, n)) * 2 - 1, jnp.int8)
    W = jnp.asarray(R.normal(size=(k, d)), jnp.float32)
    b = jnp.asarray(R.normal(size=k), jnp.float32)
    # view 0: band wider than cap; view 1: exactly cap from an aligned
    # start (no overflow); view 2: empty band
    starts = jnp.asarray([256, 256, 0], jnp.int32)
    ends = jnp.asarray([256 + cap + 1, 256 + cap, 0], jnp.int32)
    out, overflow = multiview_band_reclassify(
        F, labels, W, b, starts, ends, cap=cap, block_n=block_n,
        interpret=True, with_overflow=True)
    assert np.array_equal(np.asarray(overflow), [True, False, False])
    # overflowed view: the cap-window rows WERE relabeled, the rest stale
    z0 = np.asarray(F[256:256 + cap]) @ np.asarray(W[0]) - float(b[0])
    expect0 = np.asarray(labels[0]).copy()
    expect0[256:256 + cap] = np.where(z0 >= 0, 1, -1)
    assert np.array_equal(np.asarray(out[0]), expect0)
    assert np.array_equal(np.asarray(out[2]), np.asarray(labels[2]))
    # default call keeps the legacy single-return signature
    out2 = multiview_band_reclassify(F, labels, W, b, starts, ends,
                                     cap=cap, block_n=block_n, interpret=True)
    assert np.array_equal(np.asarray(out2), np.asarray(out))


def test_multiview_band_reclassify_matches_single_view():
    """k=1 multi-view launch == the original single-view kernel."""
    n, d = 2048, 64
    F = jnp.asarray(np.sort(R.normal(size=(n, d)), axis=0), jnp.float32)
    labels = jnp.asarray(R.integers(0, 2, n) * 2 - 1, jnp.int8)
    w = jnp.asarray(R.normal(size=d), jnp.float32)
    single = band_reclassify(F, labels, w, 0.1, 300, 900,
                             cap=1024, block_n=256, interpret=True)
    multi = multiview_band_reclassify(F, labels[None, :], w[None, :],
                                      jnp.asarray([0.1], jnp.float32),
                                      jnp.asarray([300], jnp.int32),
                                      jnp.asarray([900], jnp.int32),
                                      cap=1024, block_n=256, interpret=True)
    assert np.array_equal(np.asarray(single), np.asarray(multi[0]))


@pytest.mark.parametrize("b,s,nq,nkv,hd,bq", [
    (1, 128, 4, 4, 32, 64),     # MHA
    (2, 256, 8, 2, 32, 128),    # GQA 4:1
    (1, 512, 6, 1, 64, 128),    # MQA-ish, 6 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, nq, nkv, hd, bq, dtype):
    q = jnp.asarray(R.normal(size=(b, s, nq, hd)), dtype)
    k = jnp.asarray(R.normal(size=(b, s, nkv, hd)), dtype)
    v = jnp.asarray(R.normal(size=(b, s, nkv, hd)), dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bq, interpret=True)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,S,nq,nkv,hd,idx", [
    (2, 1024, 8, 2, 32, 700), (1, 512, 4, 4, 64, 0), (2, 2048, 16, 8, 32, 2047),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, S, nq, nkv, hd, idx, dtype):
    q = jnp.asarray(R.normal(size=(b, 1, nq, hd)), dtype)
    K = jnp.asarray(R.normal(size=(b, S, nkv, hd)), dtype)
    V = jnp.asarray(R.normal(size=(b, S, nkv, hd)), dtype)
    out = decode_attention(q, K, V, idx, block_s=256, interpret=True)
    group = nq // nkv
    ref = decode_attention_ref(q[:, 0].reshape(b, nkv, group, hd), K, V,
                               idx).reshape(b, 1, nq, hd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_matches_model_attention():
    """The pure-jnp chunked attention used in models == flash kernel."""
    from repro.configs import smoke_config
    from repro.models import layers as L
    from repro.models.params import init_params
    cfg = smoke_config("granite-3-2b")
    p = init_params(L.attention_params(cfg), 0)
    x = jnp.asarray(R.normal(size=(2, 128, cfg.d_model)), jnp.float32)
    pos = jnp.arange(128)[None, :]
    y_model = L.causal_attention(p, cfg, x, pos, chunk=64)
    q, k, v = L.project_qkv(p, cfg, x, pos)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    wo = L._pad_wo(p["wo"], cfg.padded_heads)
    y_kernel = jnp.einsum("bshk,hkd->bsd", out, wo)
    np.testing.assert_allclose(np.asarray(y_model, np.float32),
                               np.asarray(y_kernel, np.float32),
                               rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("b,s,H,K,chunk", [
    (2, 128, 3, 16, 32), (1, 64, 2, 32, 64), (2, 96, 1, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_sweep(b, s, H, K, chunk, dtype):
    """WKV6 Pallas kernel vs the exact sequential recurrence oracle.

    Decays drawn from the trained-RWKV regime (per-token log-decay
    -0.01..-1), where the factored intra-chunk form is exact (see
    models/rwkv6.py docstring for the boundary)."""
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref
    q = jnp.asarray(R.normal(size=(b, s, H, K)), dtype)
    k = jnp.asarray(R.normal(size=(b, s, H, K)), dtype)
    v = jnp.asarray(R.normal(size=(b, s, H, K)), dtype)
    la = -jnp.exp(jnp.asarray(R.normal(size=(b, s, H, K)) * 0.5 - 2.0,
                              jnp.float32)).astype(dtype)
    u = jnp.asarray(R.normal(size=(H, K)), jnp.float32)
    out = wkv6(q, k, v, la, u, chunk=chunk, interpret=True)
    tr = lambda t: t.astype(jnp.float32).transpose(0, 2, 1, 3)
    ref = wkv6_ref(tr(q), tr(k), tr(v), tr(la), u).transpose(0, 2, 1, 3)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
          dict(rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


def test_wkv6_kernel_matches_model_path():
    """Kernel == the model's wkv_chunked (deployed training path)."""
    from repro.kernels.wkv6.ops import wkv6
    from repro.models.rwkv6 import wkv_chunked
    b, s, H, K = 2, 64, 2, 16
    r = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    k = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    v = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    la = -jnp.exp(jnp.asarray(R.normal(size=(b, s, H, K)) * 0.5 - 1.0, jnp.float32))
    u = jnp.asarray(R.normal(size=(H, K)), jnp.float32)
    out_k = wkv6(r, k, v, la, u, chunk=16, interpret=True)
    s0 = jnp.zeros((b, H, K, K), jnp.float32)
    out_m, _ = wkv_chunked(r, k, v, la, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=3e-4, atol=3e-4)
