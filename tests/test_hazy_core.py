"""Unit tests for the paper's machinery: waters, skiing, engine behaviour."""

import numpy as np
import pytest

from repro.core import (HazyEngine, NaiveEngine, LinearModel, Skiing, Waters,
                        alpha_star, eps_bounds, holder_M, opt_cost,
                        skiing_schedule, sgd_step, zero_model, vector_norm)
from repro.data import forest_like, dblife_like, example_stream


def test_alpha_star():
    # positive root of x^2 + sigma x - 1
    for sigma in [0.0, 0.1, 0.5, 1.0]:
        a = alpha_star(sigma)
        assert a > 0
        assert abs(a * a + sigma * a - 1.0) < 1e-12
    assert abs(alpha_star(0.0) - 1.0) < 1e-12  # paper: sigma->0 => alpha->1


def test_holder_M():
    F = np.array([[3.0, -4.0], [1.0, 1.0]], np.float32)
    assert holder_M(F, 1.0) == pytest.approx(7.0)     # max l1 row norm
    assert holder_M(F, 2.0) == pytest.approx(5.0)     # max l2
    assert holder_M(F, np.inf) == pytest.approx(4.0)  # max |entry|


def test_eps_bounds_lemma():
    """Lemma 3.1: |delta_w . f| <= M ||delta_w||_p for all rows f."""
    r = np.random.default_rng(0)
    F = r.normal(size=(200, 16)).astype(np.float32)
    for (p, q) in [(2.0, 2.0), (np.inf, 1.0), (1.0, np.inf)]:
        M = holder_M(F, q)
        stored = LinearModel(r.normal(size=16).astype(np.float32), 0.3)
        cur = LinearModel(stored.w + 0.05 * r.normal(size=16).astype(np.float32),
                          stored.b + 0.01)
        lo, hi = eps_bounds(cur, stored, M, p)
        eps_stored = F @ stored.w - stored.b
        eps_cur = F @ cur.w - cur.b
        # above-high-water rows must be positive under the current model
        assert np.all(eps_cur[eps_stored >= hi] >= 0)
        assert np.all(eps_cur[eps_stored <= lo] < 0)


def test_waters_monotone():
    w = Waters(p=2.0, M=1.0)
    stored = zero_model(4)
    m1 = LinearModel(np.ones(4, np.float32) * 0.1, 0.0)
    lw1, hw1 = w.update(m1, stored)
    m2 = LinearModel(np.ones(4, np.float32) * 0.05, 0.0)  # model moved back
    lw2, hw2 = w.update(m2, stored)
    assert lw2 <= lw1 and hw2 >= hw1 * 0  # lw never rises, hw never falls
    assert hw2 == hw1  # smaller delta cannot shrink the band (Eq. 2)


def test_skiing_triggers():
    sk = Skiing(S=1.0, alpha=1.0)
    assert not sk.should_reorganize()
    for _ in range(9):
        sk.record_incremental(0.1)
    assert not sk.should_reorganize()
    sk.record_incremental(0.11)
    assert sk.should_reorganize()
    sk.record_reorg(2.0)
    assert sk.a == 0 and sk.S == 2.0 and sk.reorgs == 1


def test_skiing_vs_opt_adversarial():
    """On the paper's own adversarial costs the ratio approaches 1+alpha+sigma."""
    S = 1.0
    costs = lambda s, i: 0.25 if s == 0 else 0.0  # reorganizing once fixes it
    sched, total = skiing_schedule(costs, 40, S, alpha=1.0)
    opt = opt_cost(costs, 40, S)
    assert total <= (1 + 1.0 + 0.1) * opt + 2 * S


def test_engine_consistency_and_band():
    corpus = forest_like(scale=0.01)
    stream = example_stream(corpus, seed=1, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    eng = HazyEngine(corpus.features, p=2.0, q=2.0, policy="eager")
    for _, f, y in [next(stream) for _ in range(500)]:
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        eng.apply_model(model)
    assert eng.check_consistent()
    assert 0.0 <= eng.band_fraction() <= 1.0


def test_engine_matches_naive():
    corpus = dblife_like(scale=0.02)
    stream = example_stream(corpus, seed=2, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    hazy = HazyEngine(corpus.features, p=np.inf, q=1.0, policy="eager")
    naive = NaiveEngine(corpus.features, policy="eager")
    for _, f, y in [next(stream) for _ in range(200)]:
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        hazy.apply_model(model)
        naive.apply_model(model)
    assert hazy.all_members() == naive.all_members()
    for i in range(0, corpus.features.shape[0], 997):
        assert hazy.label(i) == naive.label(i)


def test_lazy_policy_exact_on_read():
    corpus = forest_like(scale=0.01)
    stream = example_stream(corpus, seed=3, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    lazy = HazyEngine(corpus.features, p=2.0, q=2.0, policy="lazy")
    eager = HazyEngine(corpus.features, p=2.0, q=2.0, policy="eager")
    for k, (_, f, y) in enumerate(next(stream) for _ in range(300)):
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        lazy.apply_model(model)
        eager.apply_model(model)
        if k % 50 == 17:
            assert lazy.all_members() == eager.all_members()
    assert lazy.check_consistent() and eager.check_consistent()


def test_hybrid_label_agrees():
    corpus = forest_like(scale=0.01)
    stream = example_stream(corpus, seed=4, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    eng = HazyEngine(corpus.features, p=2.0, q=2.0, policy="eager",
                     buffer_frac=0.05)
    for _, f, y in [next(stream) for _ in range(200)]:
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        eng.apply_model(model)
    r = np.random.default_rng(0)
    for i in r.integers(0, corpus.features.shape[0], 500):
        lab, how = eng.hybrid_label(int(i))
        assert lab == eng.label(int(i))
        assert how in ("water", "buffer", "disk")


def test_vector_norms():
    x = np.array([3.0, -4.0], np.float32)
    assert vector_norm(x, 1.0) == pytest.approx(7.0)
    assert vector_norm(x, 2.0) == pytest.approx(5.0)
    assert vector_norm(x, np.inf) == pytest.approx(4.0)
