"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, output shapes + finiteness; decode steps for
all decoder-bearing archs (spec deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import build
from repro.models.steps import (init_cache, init_train_state,
                                make_decode_step, make_train_step)

R = np.random.default_rng(0)
B, S = 2, 32


def _batch(cfg):
    batch = {
        "tokens": jnp.asarray(R.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "targets": jnp.asarray(R.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            R.normal(size=(B, cfg.num_image_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            R.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    logits, aux = jax.jit(mdl.forward)(state["params"], _batch(cfg))
    exp_s = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step(arch):
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    step = jax.jit(make_train_step(mdl))
    state, m = step(state, _batch(cfg))
    assert np.isfinite(float(m["loss"])) and float(m["loss"]) > 0
    assert np.isfinite(float(m["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_steps(arch):
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    cache = init_cache(mdl, B, 64)
    dec = jax.jit(make_decode_step(mdl))
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(4):
        tok, cache = dec(state["params"], cache, tok, jnp.asarray(i, jnp.int32))
        assert tok.shape == (B, 1)
        assert int(tok.min()) >= 0 and int(tok.max()) < cfg.padded_vocab()


def test_microbatched_train_matches_plain():
    """Gradient accumulation must match the single-batch step (same math)."""
    import dataclasses
    cfg = smoke_config("granite-3-2b")
    batch = _batch(cfg)
    mdl1 = build(cfg)
    mdl2 = build(dataclasses.replace(cfg, microbatches=2))
    s1 = init_train_state(mdl1)
    s2 = init_train_state(mdl2)
    s1, m1 = jax.jit(make_train_step(mdl1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(mdl2))(s2, batch)
    # losses are means over the same tokens; grads averaged over microbatches
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    g1, g2 = float(m1["grad_norm"]), float(m2["grad_norm"])
    assert abs(g1 - g2) / max(g1, 1e-6) < 0.05


def test_rwkv_chunked_matches_sequential():
    """wkv_chunked (training path) == exact sequential recurrence."""
    from repro.models.rwkv6 import wkv_chunked
    b, s, H, K = 2, 64, 2, 8
    r = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    k = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    v = jnp.asarray(R.normal(size=(b, s, H, K)), jnp.float32)
    la = -jnp.exp(jnp.asarray(R.normal(size=(b, s, H, K)) * 0.5 - 1.0, jnp.float32))
    u = jnp.asarray(R.normal(size=(H, K)), jnp.float32)
    s0 = jnp.zeros((b, H, K, K), jnp.float32)
    out_c, S_c = wkv_chunked(r, k, v, la, u, s0, chunk=16)

    # sequential oracle
    S = np.zeros((b, H, K, K), np.float32)
    outs = np.zeros((b, s, H, K), np.float32)
    rn, kn, vn, ln, un = (np.asarray(t) for t in (r, k, v, la, u))
    for t in range(s):
        for bi in range(b):
            for h in range(H):
                wkv = S[bi, h] + np.outer(un[h] * kn[bi, t, h], vn[bi, t, h])
                outs[bi, t, h] = rn[bi, t, h] @ wkv
                S[bi, h] = (np.exp(ln[bi, t, h])[:, None] * S[bi, h]
                            + np.outer(kn[bi, t, h], vn[bi, t, h]))
    np.testing.assert_allclose(np.asarray(out_c), outs, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), S, rtol=2e-4, atol=2e-4)


def test_mamba_chunked_matches_sequential():
    from repro.configs import smoke_config as sc
    from repro.models.mamba import mamba, mamba_decode, mamba_params, mamba_state_specs
    from repro.models.params import init_params
    cfg = sc("jamba-v0.1-52b")
    p = init_params(mamba_params(cfg), 0)
    x = jnp.asarray(R.normal(size=(2, 32, cfg.d_model)) * 0.1, jnp.float32)
    y_train = mamba(p, cfg, x, chunk=8)
    # decode one token at a time must reproduce the training output
    state = init_params(mamba_state_specs(cfg, 2), 0)
    outs = []
    for t in range(32):
        y_t, state = mamba_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train, np.float32),
                               np.asarray(y_dec, np.float32), rtol=2e-2, atol=2e-2)


def test_head_padding_exactness():
    """Zero-padded q heads must not change attention output (class-B archs)."""
    import dataclasses
    cfg = smoke_config("granite-3-2b")
    cfg5 = dataclasses.replace(cfg, num_heads=5, num_kv_heads=5, head_dim=16,
                               head_pad_to=0)
    cfg5p = dataclasses.replace(cfg5, head_pad_to=8)
    from repro.models import layers as L
    from repro.models.params import init_params
    p = init_params(L.attention_params(cfg5), 0)
    x = jnp.asarray(R.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    pos = jnp.arange(16)[None, :]
    y0 = L.causal_attention(p, cfg5, x, pos)
    y1 = L.causal_attention(p, cfg5p, x, pos)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5, atol=1e-5)
