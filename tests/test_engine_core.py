"""Single-source + host/device parity tests for the functional core.

PR 3's load-bearing claims:

  * every algorithm rule (Lemma 3.1 partition, Eq. 2 waters update, SKIING
    charge rule) exists exactly ONCE, in `core/engine.py`, and the three
    stateful shells (hazy / multiview / sharded) import it rather than
    re-deriving it — asserted structurally below;
  * the pure `EngineState` steps are the executable specification of the
    shells: the same random insert stream driven through the NumPy
    `MultiViewEngine` shell, the numpy functional core and the *jitted*
    functional core produces identical labels, counts, waters, pending
    masks and reorg schedules under every policy (eager, lazy, hybrid) —
    the hypothesis trajectory test below.
"""
import functools
import inspect

import numpy as np
import pytest

import repro.core.engine as E
import repro.core.hazy as hazy_mod
import repro.core.multiview as mv_mod
import repro.core.sharded as sh_mod
import repro.core.skiing as sk_mod
import repro.core.waters as w_mod
from repro.core import MultiViewEngine

try:                    # property version runs when hypothesis is available;
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # the fixed-case sweep below always runs
    HAVE_HYPOTHESIS = False

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

N, D, K = 256, 16, 3


# ---------------------------------------------------------------------------
# Single-source regressions: the shells must IMPORT the rules, not re-derive
# ---------------------------------------------------------------------------

def test_single_source_band_partition():
    """One Lemma 3.1 partition: hazy/multiview/sharded all bind the very
    function objects from engine.py (both the sorted-row and point-probe
    forms), and none of them re-derives the partition with a raw
    searchsorted against the waters."""
    assert hazy_mod.band_partition is E.band_partition
    assert mv_mod.band_partition is E.band_partition
    assert sh_mod.band_partition is E.band_partition
    assert hazy_mod.probe_partition is E.probe_partition
    assert mv_mod.probe_partition is E.probe_partition
    assert sh_mod.probe_partition is E.probe_partition
    assert sh_mod.covering_windows is E.covering_windows
    for mod in (hazy_mod, mv_mod, sh_mod):
        src = inspect.getsource(mod).replace(" ", "")
        assert "fromrepro.core.engineimport" in src
        assert "searchsorted(eps" not in src          # no re-derived partition
        assert "searchsorted(self.eps" not in src


def test_single_source_waters_and_skiing():
    """One Eq. 2 waters update and one SKIING charge rule: the shells and
    the scalar Waters/Skiing wrappers all delegate to engine.py."""
    assert mv_mod.waters_update is E.waters_update
    assert sh_mod.waters_update is E.waters_update
    assert w_mod.waters_update is E.waters_update
    assert mv_mod.skiing_charge is E.skiing_charge
    assert mv_mod.skiing_due is E.skiing_due
    assert sk_mod.skiing_charge is E.skiing_charge
    assert sk_mod.skiing_due is E.skiing_due
    assert "waters_update" in inspect.getsource(w_mod.Waters.update)
    assert "skiing_due" in inspect.getsource(sk_mod.Skiing.should_reorganize)
    assert "skiing_charge" in inspect.getsource(sk_mod.Skiing.record_incremental)


def test_covering_windows_cover_band():
    """The shared-order covering window is the tightest contiguous superset
    of the Lemma 3.1 band (the sharded kernel's window form)."""
    r = np.random.default_rng(0)
    eps = r.normal(size=(4, 64)).astype(np.float32)
    lw = -np.abs(r.normal(size=4))
    hw = np.abs(r.normal(size=4))
    hw[3] = lw[3]                                   # force one empty band
    start, end, width = E.covering_windows(eps, lw, hw)
    for v in range(4):
        members = np.flatnonzero(E.band_mask(eps[v], lw[v], hw[v]))
        assert width[v] == members.size
        if members.size:
            assert start[v] == members.min() and end[v] == members.max() + 1
        else:
            assert start[v] == 0 and end[v] == 0


# ---------------------------------------------------------------------------
# Host/device parity: shell == numpy core == jitted core, per policy
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _jitted(policy: str, buffer_cap: int):
    """Jitted Layer 2 steps; M/alpha are traced so examples share compiles."""
    def mk(M, alpha):
        return E.EngineParams(M=M, p=2.0, alpha=alpha, buffer_cap=buffer_cap)

    @jax.jit
    def apply(state, W, b, M, alpha):
        return E.apply_model(state, W, b, mk(M, alpha), policy=policy, xp=jnp)

    @jax.jit
    def cu(state, touch, M, alpha):
        return E.catch_up(state, touch, mk(M, alpha), xp=jnp)

    @jax.jit
    def probe(state, eid, M, alpha):
        return E.hybrid_probe(state, eid, mk(M, alpha), xp=jnp)

    return apply, cu, probe


def _entity_order(labels, perm):
    labels, perm = np.asarray(labels), np.asarray(perm)
    out = np.empty_like(labels)
    for v in range(labels.shape[0]):
        out[v, perm[v]] = labels[v]
    return out


def _parity_trajectory(seed, policy, rounds):
    r = np.random.default_rng(seed)
    F = r.normal(size=(N, D)).astype(np.float32)
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
    bf = 0.06 if policy == "hybrid" else 0.0
    shell = MultiViewEngine(F, K, p=2.0, q=2.0, alpha=1.0, policy=policy,
                            cost_mode="modeled", buffer_frac=bf)
    params = E.make_params(F, p=2.0, q=2.0, alpha=1.0, buffer_frac=bf)
    st_np = E.init_state(F, K, params)
    st_j = jax.tree_util.tree_map(jnp.asarray, st_np)
    j_apply, j_cu, j_probe = _jitted(policy, params.buffer_cap)
    M, alpha = params.M, params.alpha
    ones = np.ones(K, bool)
    W = np.zeros((K, D), np.float32)
    b = np.zeros(K, np.float64)
    reorg_np = np.zeros(K, np.int64)
    reorg_j = np.zeros(K, np.int64)

    for t in range(rounds):
        W = (W + r.normal(size=(K, D)) * 0.05).astype(np.float32)
        b = b + r.normal(size=K) * 0.02
        shell.apply_models(W, b)
        st_np, inf_n = E.apply_model(st_np, W, b, params, policy=policy)
        st_j, inf_j = j_apply(st_j, jnp.asarray(W), jnp.asarray(b), M, alpha)
        reorg_np += np.asarray(inf_n["reorged"])
        reorg_j += np.asarray(inf_j["reorged"])
        if t % 7 == 3:                       # All-Members read on all sides
            counts = shell.all_members()
            st_np, cn = E.catch_up(st_np, ones, params)
            st_j, cj = j_cu(st_j, jnp.asarray(ones), M, alpha)
            reorg_np += np.asarray(cn["reorged"])
            reorg_j += np.asarray(cj["reorged"])
            assert np.array_equal(counts, st_np.pos_count)
            assert np.array_equal(counts, np.asarray(st_j.pos_count))
        if policy == "hybrid" and t % 5 == 2:
            for e in r.integers(0, N, 3):    # Fig. 8 probes on all sides
                labs, hows = shell.hybrid_labels_of(int(e))
                st_np, ln, tn = E.hybrid_probe(st_np, int(e), params)
                st_j, lj, tj = j_probe(st_j, jnp.int32(int(e)), M, alpha)
                assert np.array_equal(labs, ln) and np.array_equal(hows, tn)
                assert np.array_equal(labs, np.asarray(lj))
                assert np.array_equal(hows, np.asarray(tj))

    counts = shell.all_members()             # final catch-up everywhere
    st_np, cn = E.catch_up(st_np, ones, params)
    st_j, cj = j_cu(st_j, jnp.asarray(ones), M, alpha)
    reorg_np += np.asarray(cn["reorged"])
    reorg_j += np.asarray(cj["reorged"])

    ent_shell = _entity_order(shell.labels_sorted, shell.perm)
    assert np.array_equal(ent_shell, _entity_order(st_np.labels, st_np.perm))
    assert np.array_equal(ent_shell, _entity_order(st_j.labels, st_j.perm))
    assert np.array_equal(counts, st_np.pos_count)
    assert np.array_equal(counts, np.asarray(st_j.pos_count))
    assert np.array_equal(shell.pending, st_np.pending)
    assert np.array_equal(shell.pending, np.asarray(st_j.pending))
    # waters: bitwise vs the numpy core, tight allclose vs the f32 jit core
    np.testing.assert_array_equal(shell.lw, st_np.lw)
    np.testing.assert_array_equal(shell.hw, st_np.hw)
    np.testing.assert_allclose(np.asarray(st_j.lw), shell.lw,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_j.hw), shell.hw,
                               rtol=1e-5, atol=1e-6)
    # identical reorg schedules on all three execution paths
    assert np.array_equal(shell.reorg_counts, reorg_np)
    assert np.array_equal(shell.reorg_counts, reorg_j)
    assert shell.check_consistent()
    return shell


@pytest.mark.parametrize("seed,policy,rounds", [
    (11, "eager", 24), (12, "eager", 16),
    (21, "lazy", 24), (22, "lazy", 17),
    (31, "hybrid", 24), (32, "hybrid", 18),
])
def test_shell_core_jit_parity(seed, policy, rounds):
    """Fixed-case sweep (always runs): same stream through the NumPy shell,
    the numpy functional core and the jitted functional core."""
    shell = _parity_trajectory(seed, policy, rounds)
    assert shell.stats.rounds == rounds


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10_000),
           policy=st.sampled_from(["eager", "lazy", "hybrid"]),
           rounds=st.integers(12, 28))
    def test_shell_core_jit_parity_property(seed, policy, rounds):
        _parity_trajectory(seed, policy, rounds)
