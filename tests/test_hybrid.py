"""§3.5.2 hybrid read tier + per-view laziness: oracle tests and regressions.

Covers the four PR-2 bug classes with dedicated tests:
  * `HazyEngine.hybrid_label` probing stale waters under a pending lazy model
  * `ClassificationView.refresh_features` dropping ctor params (q, touch_ns)
  * exact-water-mark boundary disagreement between the hybrid probe and the
    band search (both engines)
  * `MultiViewEngine.band_fractions` skipping lazy catch-up
plus the hybrid-read oracle (reads always agree with a from-scratch
sign(F @ w − b) under every policy) and per-view pending isolation.
"""
import numpy as np
import pytest

from repro.core import (ClassificationView, HazyEngine, LinearModel,
                        MulticlassView, MultiViewEngine, holder_M, sgd_step,
                        zero_model)
from repro.core.hazy import hot_buffer_window
from repro.core.engine import HYBRID_TIERS
from repro.data import cora_like, forest_like, example_stream, \
    multiclass_example_stream


def _oracle(F, w, b):
    return np.where(F @ w - b >= 0, 1, -1)


# ---------------------------------------------------------------------------
# Bug 1: hybrid_label must be exact with a pending (lazy/hybrid) model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lazy", "hybrid"])
def test_hazy_hybrid_label_exact_with_pending_model(policy):
    corpus = forest_like(scale=0.01)
    stream = example_stream(corpus, seed=11, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    eng = HazyEngine(corpus.features, p=2.0, q=2.0, policy=policy,
                     buffer_frac=0.05)
    for _, f, y in [next(stream) for _ in range(200)]:
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        eng.apply_model(model)   # no reads: the model stays pending
    truth = _oracle(eng.F, model.w, model.b)
    for i in range(0, corpus.features.shape[0], 7):
        lab, how = eng.hybrid_label(i)
        assert lab == truth[i], (i, how)
    # the probe used the waters update, not a full catch-up: under pure
    # lazy the relabel must still be deferred
    if policy == "lazy":
        assert eng._pending is not None
    assert eng.all_members() == int((truth == 1).sum())


# ---------------------------------------------------------------------------
# Bug 2: refresh_features must preserve the ctor parameters
# ---------------------------------------------------------------------------

def test_refresh_features_preserves_ctor_params():
    r = np.random.default_rng(0)
    F1 = r.normal(size=(64, 8)).astype(np.float32)
    F2 = 2.0 * r.normal(size=(64, 8)).astype(np.float32)
    view = ClassificationView(F1, policy="lazy", norm=(2.0, 2.0), alpha=1.3,
                              cost_mode="modeled", touch_ns=123.0)
    assert view.engine.M == holder_M(F1, 2.0)
    view.insert_example(3, 1.0)
    view.refresh_features(entities=F2)
    assert view.engine.M == holder_M(F2, 2.0)       # q survived (was q=1.0)
    assert view.engine.touch_ns == 123.0
    assert view.engine.policy == "lazy"
    assert view.engine.cost_mode == "modeled"
    assert view.engine.skiing.alpha == 1.3
    # NaiveEngine branch: touch_ns survived (was dropped entirely)
    nview = ClassificationView(F1, engine="naive", policy="lazy",
                               touch_ns=55.0)
    nview.insert_example(1, -1.0)
    nview.refresh_features(entities=F2)
    assert nview.engine.touch_ns == 55.0
    assert nview.engine.policy == "lazy"


# ---------------------------------------------------------------------------
# Bug 3: entities with eps exactly AT a water mark — probe and band search
# must partition identically ([lw, hw) reclassified; e >= hw / e < lw
# short-circuited)
# ---------------------------------------------------------------------------

def test_exact_water_boundary_single_view():
    # 1-D features with exact f32 values; q=2 => M = 2
    F = np.array([[2.0], [1.0], [0.5], [-1.0], [-2.0]], np.float32)
    eng = HazyEngine(F, p=2.0, q=2.0, policy="eager")
    eng.model = LinearModel(np.array([1.0], np.float32), 0.0)
    eng.reorganize()                     # stored = (w=1, b=0); eps = f values

    # db = +1, dw = 0 -> lw = 0, hw = 1: entity f=1 sits exactly at hw
    eng.apply_model(LinearModel(np.array([1.0], np.float32), 1.0))
    assert (eng.waters.lw, eng.waters.hw) == (0.0, 1.0)
    truth = _oracle(F, eng.model.w, eng.model.b)
    lab, how = eng.hybrid_label(1)       # eps_stored == hw == 1
    assert how == "water" and lab == 1 == truth[1]
    assert eng.label(1) == truth[1]
    for i in range(F.shape[0]):
        lab, _ = eng.hybrid_label(i)
        assert lab == truth[i] == eng.label(i), i
    assert eng.check_consistent()

    # db = −1 -> lw = −1, hw = 0: entity f=−1 sits exactly at lw, and its
    # true label under the new model is +1 (z == 0) — it must be
    # reclassified by BOTH paths, never short-circuited to −1
    eng2 = HazyEngine(F, p=2.0, q=2.0, policy="eager")
    eng2.model = LinearModel(np.array([1.0], np.float32), 0.0)
    eng2.reorganize()
    eng2.apply_model(LinearModel(np.array([1.0], np.float32), -1.0))
    assert (eng2.waters.lw, eng2.waters.hw) == (-1.0, 0.0)
    truth = _oracle(F, eng2.model.w, eng2.model.b)
    assert truth[3] == 1                 # z = −1 + 1 = 0 -> +1
    lab, how = eng2.hybrid_label(3)
    assert lab == 1 and how != "water"
    assert eng2.label(3) == 1
    lab, how = eng2.hybrid_label(4)      # f=−2 < lw: certainly negative
    assert lab == -1 and how == "water" and truth[4] == -1
    assert eng2.check_consistent()


def test_exact_water_boundary_multiview():
    F = np.array([[2.0], [1.0], [0.5], [-1.0], [-2.0]], np.float32)
    k = 2
    eng = MultiViewEngine(F, k, p=2.0, q=2.0, cost_mode="modeled")
    W = np.ones((k, 1), np.float32)
    b = np.zeros(k)
    eng.W, eng.b = W.copy(), b.copy()
    eng._reorganize_views(np.ones(k, bool))   # stored = (1, 0) per view
    # view 0: db=+1 (hw=1, entity f=1 at hw); view 1: db=−1 (lw=−1, f=−1 at lw)
    eng.apply_models(W, np.array([1.0, -1.0]))
    assert (eng.lw[0], eng.hw[0]) == (0.0, 1.0)
    assert (eng.lw[1], eng.hw[1]) == (-1.0, 0.0)
    Z = F @ eng.W.T - eng.b.astype(np.float32)
    truth = np.where(Z >= 0, 1, -1)
    lab, how = eng.hybrid_label(0, 1)         # eps_stored == hw for view 0
    assert lab == 1 == truth[1, 0] and how == "water"
    lab, how = eng.hybrid_label(1, 3)         # eps_stored == lw for view 1
    assert lab == 1 == truth[3, 1] and how != "water"   # z == 0 -> +1
    for i in range(F.shape[0]):
        labs, hows = eng.hybrid_labels_of(i)
        assert np.array_equal(labs, truth[i]), i
        for v in range(k):
            assert eng.hybrid_label(v, i)[0] == truth[i, v]
            assert eng.label(v, i) == truth[i, v]
    assert eng.check_consistent()


# ---------------------------------------------------------------------------
# Bug 4: band_fractions under lazy must reflect the caught-up view state
# ---------------------------------------------------------------------------

def test_band_fractions_catches_up_lazy_views():
    c = cora_like(scale=0.2)
    k = c.num_classes
    a = MultiViewEngine(c.features, k, p=2.0, q=2.0, policy="lazy",
                        cost_mode="modeled")
    bb = MultiViewEngine(c.features, k, p=2.0, q=2.0, policy="lazy",
                         cost_mode="modeled")
    r = np.random.default_rng(5)
    W = r.normal(size=(k, c.features.shape[1])).astype(np.float32) * 0.1
    bias = r.normal(size=k) * 0.01
    a.apply_models(W, bias)
    bb.apply_models(W, bias)
    assert a.pending.all()
    fracs = a.band_fractions()
    assert not a.pending.any()           # the read caught the views up
    bb.all_members()                     # explicit catch-up on the twin
    assert np.array_equal(fracs, bb.band_fractions())


# ---------------------------------------------------------------------------
# Per-view laziness: a read of view v leaves the other k−1 views pending
# ---------------------------------------------------------------------------

def test_per_view_pending_isolation():
    c = cora_like(scale=0.2)
    k = c.num_classes
    eng = MultiViewEngine(c.features, k, p=2.0, q=2.0, policy="lazy",
                          cost_mode="modeled")
    r = np.random.default_rng(7)
    W = r.normal(size=(k, c.features.shape[1])).astype(np.float32) * 0.1
    bias = r.normal(size=k) * 0.01
    eng.apply_models(W, bias)
    assert eng.pending.all()
    truth = np.where(c.features @ W.T - bias.astype(np.float32) >= 0, 1, -1)
    before = eng.labels_sorted.copy()
    assert eng.label(2, 5) == truth[5, 2]          # hot view caught up...
    assert not eng.pending[2]
    others = [v for v in range(k) if v != 2]
    assert eng.pending[others].all()               # ...cold views defer
    for v in others:                               # their state is untouched
        assert np.array_equal(eng.labels_sorted[v], before[v])
    mem = eng.members(4)
    assert not eng.pending[4] and eng.pending[[v for v in others if v != 4]].all()
    assert set(mem.tolist()) == set(np.flatnonzero(truth[:, 4] == 1).tolist())
    counts = eng.all_members()                     # touches every view
    assert not eng.pending.any()
    assert np.array_equal(counts, (truth == 1).sum(axis=0))
    # §3.4 waste was charged exactly to the views that caught up
    assert np.all(eng.lazy_waste >= 0.0)


# ---------------------------------------------------------------------------
# Hybrid-read oracle: random update streams, both engines, every policy —
# hybrid reads match sign(F @ w − b) for EVERY entity, and no read ever
# observes a pre-catch-up label
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["eager", "lazy", "hybrid"])
def test_hybrid_read_oracle_multiview(policy):
    c = cora_like(scale=0.15)
    k = c.num_classes
    view = MulticlassView(c.features, k, policy=policy, buffer_frac=0.08,
                          p=2.0, q=2.0, lr=0.1, cost_mode="modeled")
    eng = view.engine
    stream = multiclass_example_stream(c, seed=23)
    r = np.random.default_rng(29)
    for t, (i, cls) in enumerate(next(stream) for _ in range(240)):
        view.insert_example(i, cls)
        if t % 40 == 11:
            truth = np.where(c.features @ view.W.T
                             - view.b.astype(np.float32) >= 0, 1, -1)
            for e in range(c.features.shape[0]):
                labs, hows = eng.hybrid_labels_of(e)
                assert np.array_equal(labs, truth[e]), (t, e)
                assert set(np.unique(hows)) <= {0, 1, 2}
            for e in r.integers(0, c.features.shape[0], 40):
                v = int(r.integers(0, k))
                assert eng.hybrid_label(v, int(e))[0] == truth[e, v]
                assert eng.label(v, int(e)) == truth[e, v]
                assert view.predict_via_views(int(e)) == view.predict(int(e))
    assert eng.check_consistent()
    if policy == "hybrid":
        assert eng.hybrid_hits.sum() > 0


@pytest.mark.parametrize("policy", ["eager", "lazy", "hybrid"])
def test_hybrid_read_oracle_single_view(policy):
    corpus = forest_like(scale=0.008)
    stream = example_stream(corpus, seed=31, label_noise=0.0)
    model = zero_model(corpus.features.shape[1])
    eng = HazyEngine(corpus.features, p=2.0, q=2.0, policy=policy,
                     buffer_frac=0.05)
    for t, (_, f, y) in enumerate(next(stream) for _ in range(200)):
        model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
        eng.apply_model(model)
        if t % 50 == 13:
            truth = _oracle(eng.F, model.w, model.b)
            for i in range(corpus.features.shape[0]):
                assert eng.hybrid_label(i)[0] == truth[i], (t, i)
    assert eng.check_consistent()


# ---------------------------------------------------------------------------
# Plumbing: ClassificationView keeps hybrid hybrid; MulticlassView policy +
# predict_via_views on the legacy path; the shared buffer helper
# ---------------------------------------------------------------------------

def test_classification_view_hybrid_not_rewritten():
    corpus = forest_like(scale=0.005)
    view = ClassificationView(corpus.features, policy="hybrid",
                              norm=(2.0, 2.0), lr=0.05, buffer_frac=0.05)
    assert view.engine.policy == "hybrid"          # no silent eager rewrite
    stream = example_stream(corpus, seed=41, label_noise=0.0)
    for _, (i, _f, y) in zip(range(150), stream):
        view.insert_example(i, y)
    truth = _oracle(view.F, view.model.w, view.model.b)
    for i in range(0, len(truth), 101):
        assert view.label(i) == truth[i]
    assert view.all_members() == int((truth == 1).sum())


def test_predict_via_views_legacy_loop_matches_predict():
    c = cora_like(scale=0.12)
    k = c.num_classes
    view = MulticlassView(c.features, k, policy="hybrid", buffer_frac=0.05,
                          p=2.0, q=2.0, lr=0.1, vectorized=False)
    stream = multiclass_example_stream(c, seed=43)
    for i, cls in (next(stream) for _ in range(150)):
        view.insert_example(i, cls)
    for e in range(0, c.features.shape[0], 17):
        assert view.predict_via_views(e) == view.predict(e)


def test_hot_buffer_window_shared_helper():
    eps = np.array([-3.0, -1.0, -0.5, 0.25, 2.0, 4.0], np.float32)
    lo, hi = hot_buffer_window(eps, 2)
    assert (lo, hi) == (2, 4)                      # straddles the boundary
    assert hot_buffer_window(eps, 100) == (0, 6)   # capped at n
    assert hot_buffer_window(eps, 0) == (3, 4)     # min capacity 1
    assert len(HYBRID_TIERS) == 3
