"""The invariant lint suite, tested against its fixture corpus.

Contracts (ISSUE 7):

  * each rule fires at EXACTLY the file:line it should on the known-bad
    fixtures — and nowhere else in that fixture;
  * the known-good fixture and the REAL tree produce zero findings
    (the CLI exits 0 — this is the CI `static-analysis` gate);
  * the deliberately inverted pool -> commit acquisition is caught by
    BOTH the static pass (LCK001) and the runtime lock witness
    (LockOrderError), and the witness reports the gate's non-reentrancy
    instead of deadlocking on it.
"""
import importlib.util
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import run, witness
from repro.rdbms.concurrency import EpochGate

FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"


def _findings(name):
    return [(f.line, f.rule) for f in run([FIXTURES / name])]


def _load(name):
    spec = importlib.util.spec_from_file_location(
        f"analysis_fixture_{name}", FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# static passes on the fixture corpus: exact file:line + RULE-ID
# ---------------------------------------------------------------------------

def test_lock_inversion_static():
    assert _findings("bad_lock_inversion.py") == [(30, "LCK001")]


def test_lock_bare_acquire():
    assert _findings("bad_lock_bare_acquire.py") == [(13, "LCK002")]


def test_lock_blocking_under_pool():
    assert _findings("bad_lock_blocking.py") == [(14, "LCK003"),
                                                 (15, "LCK003")]


def test_lock_read_under_pool():
    """LCK004: a cold `read_page` inlined under the pool lock (direct at
    line 31) and a `read_pages` reachable through `_admit_all` from
    inside the lock (via-callee, at warm's call line 40)."""
    assert _findings("bad_lock_read_under_pool.py") == [(31, "LCK004"),
                                                        (40, "LCK004")]


def test_band_rederivation():
    found = _findings("bad_band_rederived.py")
    assert set(found) == {(6, "SRC001"), (7, "SRC001"), (12, "SRC001")}
    assert found.count((6, "SRC001")) == 2      # mask = two comparisons


def test_skiing_rederivation():
    assert _findings("bad_skiing_rederived.py") == [(11, "SRC002"),
                                                    (12, "SRC002")]


def test_purity_np_sideeffects_mutation():
    assert _findings("bad_purity_np.py") == [(8, "PUR001"), (9, "PUR002"),
                                             (10, "PUR003"), (11, "PUR002")]


def test_state_mutation_in_shell():
    assert _findings("bad_state_mutation.py") == [(5, "PUR004"),
                                                  (6, "PUR004")]


def test_raw_timing():
    """TEL001: every raw clock call — attribute form AND bare imported
    name — and ONLY those (the `clock = time.perf_counter` alias and
    `time.sleep` in the same fixture stay quiet)."""
    assert _findings("bad_raw_timing.py") == [(8, "TEL001"), (10, "TEL001"),
                                              (15, "TEL001"),
                                              (17, "TEL001")]


def test_freshness_forked_semantics():
    """FRS001: raw DAG-edge walks (lines 7-8), a hand-delivered inbox
    batch (10), and forged freshness stamps / out-of-band SUSPEND
    (11-13) — each pinned, nothing else in the fixture."""
    assert _findings("bad_freshness.py") == [
        (7, "FRS001"), (8, "FRS001"), (10, "FRS001"),
        (11, "FRS001"), (12, "FRS001"), (13, "FRS001")]


def test_good_fixture_is_quiet():
    assert _findings("good_clean.py") == []


def test_real_tree_is_quiet():
    assert run() == []


# ---------------------------------------------------------------------------
# the CLI contract: file:line: RULE-ID lines, exit status
# ---------------------------------------------------------------------------

def _cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True)


def test_cli_exits_nonzero_with_findings():
    proc = _cli(str(FIXTURES / "bad_lock_inversion.py"))
    assert proc.returncode == 1
    assert "bad_lock_inversion.py:30: LCK001" in proc.stdout


def test_cli_exits_zero_on_the_tree():
    proc = _cli()
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert proc.stdout.strip() == ""


# ---------------------------------------------------------------------------
# runtime witness: the same order, asserted live
# ---------------------------------------------------------------------------

def test_witness_catches_the_inverted_fixture_live():
    with witness.enabled():
        bad = _load("bad_lock_inversion")
        pool = bad.BufferPool()             # locks constructed -> wrapped
        with pytest.raises(witness.LockOrderError, match="inversion"):
            pool.evict_and_commit()


def test_witness_allows_the_declared_order_and_rlock_reentry():
    with witness.enabled():
        good = _load("good_clean")
        eng = good.Engine()
        assert eng.commit() == 1            # wal_commit -> pool, downward
        assert eng.log.append() == 1        # append -> flush, same RLock


def test_witness_reports_gate_reentry_instead_of_deadlocking():
    gate = EpochGate()
    with witness.enabled():
        with gate.read():
            with pytest.raises(witness.LockOrderError, match="reentrant"):
                with gate.write():
                    pass                    # pragma: no cover


def test_witness_catches_read_under_pool_lock_live():
    """`assert_unlocked` — the live twin of LCK004: a REAL EntityStore
    cold read under a witnessed pool lock raises instead of silently
    re-serializing every probe."""
    import threading

    import numpy as np

    from repro.storage import EntityStore

    F = np.ones((8, 4), np.float32)
    with witness.enabled():
        store = EntityStore.from_array(F, page_bytes=64)
        lock = witness.wrap(threading.RLock(), "pool")
        with lock:
            with pytest.raises(witness.LockOrderError, match="read_page"):
                store.read_page(0)
            with pytest.raises(witness.LockOrderError, match="read_pages"):
                store.read_pages([0, 1])
        # off the lock the same reads are legal
        assert store.read_page(0).shape[0] > 0
    store.close()


def test_witness_off_means_raw_locks():
    """wrap() hands back the raw lock when disabled — the production
    path carries zero wrapper overhead."""
    import threading
    prev = witness.WITNESS.enabled
    witness.WITNESS.enabled = False
    try:
        lock = threading.RLock()
        assert witness.wrap(lock, "pool") is lock
    finally:
        witness.WITNESS.enabled = prev
    with pytest.raises(ValueError):
        witness.wrap(threading.RLock(), "not-a-lock")
