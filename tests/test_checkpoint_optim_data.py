"""Checkpoint roundtrips, optimizer math, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                   "c": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _tree()
    save_checkpoint(str(tmp_path), state, 7)
    assert latest_step(str(tmp_path)) == 7
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = restore_checkpoint(str(tmp_path), abstract)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_checkpointer_and_gc(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(_tree(), s)
    ck.wait()
    ck.close()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]  # gc keeps last 2


def test_checkpoint_restart_equivalence(tmp_path):
    """Fault-tolerance contract: train k steps, checkpoint, 'crash', restore,
    continue — must equal an uninterrupted run bit-for-bit."""
    from repro.configs import smoke_config
    from repro.models import build
    from repro.models.steps import init_train_state, make_train_step
    from repro.data import TokenStream

    cfg = smoke_config("tinyllama-1.1b")
    mdl = build(cfg)
    step_fn = jax.jit(make_train_step(mdl))
    ds = TokenStream(vocab_size=cfg.vocab_size, batch=2, seq_len=16, seed=0)

    def run(n, state):
        for i in range(int(state["step"]), n):
            b = ds.batch_at(i)
            state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        return state

    full = run(6, init_train_state(mdl))

    half = run(3, init_train_state(mdl))
    save_checkpoint(str(tmp_path), half, 3)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), half)
    restored, _ = restore_checkpoint(str(tmp_path), abstract)
    resumed = run(6, restored)

    for a, b in zip(jax.tree_util.tree_leaves(full["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_adamw_matches_reference():
    params = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, 0.1], jnp.float32)}
    opt = adamw_init(params)
    new_p, opt = adamw_update(params, grads, opt, lr=0.1, b1=0.9, b2=0.95,
                              eps=1e-8, weight_decay=0.0)
    # closed-form first step: m_hat = g, v_hat = g^2 -> step = g/(|g|+eps) = sign
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray([0.9, -2.1]), rtol=1e-5)
    assert int(opt["count"]) == 1


def test_clip_and_schedule():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(300.0))
    assert np.linalg.norm(np.asarray(clipped["a"])) == pytest.approx(1.0, rel=1e-4)
    assert float(warmup_cosine(jnp.asarray(0), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == pytest.approx(0.1)
    assert float(warmup_cosine(jnp.asarray(100), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) == pytest.approx(0.1, rel=1e-2)


def test_tokenstream_determinism_and_sharding():
    from repro.data import TokenStream
    a = TokenStream(vocab_size=100, batch=4, seq_len=8, seed=1, shard=0, num_shards=2)
    b = TokenStream(vocab_size=100, batch=4, seq_len=8, seed=1, shard=0, num_shards=2)
    np.testing.assert_array_equal(a.batch_at(5)["tokens"], b.batch_at(5)["tokens"])
    c = TokenStream(vocab_size=100, batch=4, seq_len=8, seed=1, shard=1, num_shards=2)
    assert not np.array_equal(a.batch_at(5)["tokens"], c.batch_at(5)["tokens"])
    # targets are next-token shifted
    got = a.batch_at(3)
    assert got["tokens"].shape == (4, 8) and got["targets"].shape == (4, 8)


def test_corpora_stats():
    from repro.data import dblife_like, forest_like
    fc = forest_like(scale=0.005)
    assert fc.features.shape[1] == 54
    np.testing.assert_allclose(np.linalg.norm(fc.features, axis=1), 1.0, rtol=1e-4)
    db = dblife_like(scale=0.02)
    assert np.all(np.abs(np.sum(np.abs(db.features), axis=1) - 1.0) < 1e-4)
    nnz = np.mean(np.count_nonzero(db.features, axis=1))
    assert 5 <= nnz <= 16  # ~7 words + topic columns
