"""Benchmark driver — one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV. Scale with BENCH_SCALE (default
0.1 of the paper's corpus sizes, so the suite finishes on one CPU core).
"""
from __future__ import annotations

import sys
import time
import traceback


MODULES = [
    "benchmarks.eager_update",      # Fig. 4(A)
    "benchmarks.lazy_all_members",  # Fig. 4(B)
    "benchmarks.single_entity",     # Fig. 5
    "benchmarks.hybrid_buffer",     # Fig. 6(B)
    "benchmarks.learning",          # Fig. 10
    "benchmarks.scalability",       # Fig. 11(A)
    "benchmarks.sensitivity",       # Fig. 12
    "benchmarks.waters",            # Fig. 13
    "benchmarks.kernel_bench",      # framework kernels
]


def main() -> None:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for mod_name in MODULES:
        if only and only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            print(f"# {mod_name} FAILED", file=sys.stderr)
            traceback.print_exc()
            print(f"{mod_name}_FAILED,0,error")


if __name__ == "__main__":
    main()
