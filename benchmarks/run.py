"""Benchmark driver — one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV. Scale with BENCH_SCALE (default
0.1 of the paper's corpus sizes, so the suite finishes on one CPU core).

Exits non-zero if any module fails (CI gates on this); the failure still
leaves a ``<module>_FAILED`` CSV row for postmortem parsing.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

# allow `python benchmarks/run.py` from anywhere (sys.path[0] is benchmarks/)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


MODULES = [
    "benchmarks.eager_update",      # Fig. 4(A)
    "benchmarks.lazy_all_members",  # Fig. 4(B)
    "benchmarks.single_entity",     # Fig. 5
    "benchmarks.hybrid_buffer",     # Fig. 6(B)
    "benchmarks.learning",          # Fig. 10
    "benchmarks.scalability",       # Fig. 11(A)
    "benchmarks.sensitivity",       # Fig. 12
    "benchmarks.waters",            # Fig. 13
    "benchmarks.multiclass",        # App. B.5.4 / C.3 (multi-view engine)
    "benchmarks.hybrid",            # §3.5.2 hybrid tier on the multi-view engine
    "benchmarks.storage",           # memory-budgeted buffer pool behind the probe
    "benchmarks.scale",             # paper-scale CS/FC on the multi-view engine
    "benchmarks.sql_serve",         # relational front-end overhead vs direct
    "benchmarks.serve_concurrent",  # concurrent wire-protocol serving swarm
    "benchmarks.fleet_lag",         # freshness scheduler: TARGET_LAG fleet
    "benchmarks.kernel_bench",      # framework kernels
]


def _selected(only: str, mod_name: str) -> bool:
    """Exact short-name match wins (``run.py hybrid`` must not also run
    ``hybrid_buffer``); otherwise substring, as before."""
    if only is None:
        return True
    shorts = {m.rsplit(".", 1)[-1] for m in MODULES}
    if only in shorts or only in MODULES:
        return only in (mod_name, mod_name.rsplit(".", 1)[-1])
    return only in mod_name


def main() -> int:
    print("name,us_per_call,derived")
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failed = []
    for mod_name in MODULES:
        if not _selected(only, mod_name):
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            print(f"# {mod_name} FAILED", file=sys.stderr)
            traceback.print_exc()
            print(f"{mod_name}_FAILED,0,error")
            failed.append(mod_name)
    if failed:
        print(f"# failed modules: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
