"""Paper Fig. 12: (A) feature-dimension sensitivity of lazy All Members
(random features of App. B.5.3 scale d up); (B) multiclass eager updates
vs number of classes (one-vs-all, App. C.3)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BottouSGD, emit
from repro.core import HazyEngine, MulticlassView, NaiveEngine, RandomFeatures
from repro.data import forest_like


def feature_sensitivity():
    c = forest_like(scale=0.02, seed=9)
    for D in (64, 256, 1024):
        rf = RandomFeatures(54, D, sigma=1.0, seed=0)
        F = rf(c.features)
        F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
        for kind in ("hazy", "naive"):
            sgd = BottouSGD()
            from repro.core import zero_model
            from repro.data import example_stream
            stream = example_stream(c, seed=3, label_noise=0.0)
            model = zero_model(D)
            for _, f, y in (next(stream) for _ in range(3000)):
                model = sgd.step(model, rf(f[None])[0] /
                                 max(np.linalg.norm(rf(f[None])[0]), 1e-9), y)
            eng = (HazyEngine(F, p=2.0, q=2.0, policy="lazy")
                   if kind == "hazy" else NaiveEngine(F, policy="lazy"))
            eng.apply_model(model)
            if kind == "hazy":
                eng.reorganize()
            n_reads = 30
            t0 = time.perf_counter()
            for _ in range(n_reads):
                eng.all_members()
            dt = time.perf_counter() - t0
            emit(f"fig12a_features_{kind}_d{D}", dt / n_reads * 1e6,
                 f"scans/s={n_reads/dt:.1f}")


def multiclass():
    r = np.random.default_rng(0)
    n, d = 20_000, 54
    for k in (2, 4, 8):
        centers = r.normal(size=(k, d)).astype(np.float32) * 3
        cls = r.integers(0, k, n)
        F = centers[cls] + r.normal(size=(n, d)).astype(np.float32)
        F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)
        for engine in ("hazy", "naive"):
            mv = MulticlassView(F, k, engine=engine, policy="eager", lr=0.05,
                                p=2.0, q=2.0)
            # warm
            for i in r.integers(0, n, 500):
                mv.insert_example(int(i), int(cls[i]))
            updates = r.integers(0, n, 100)
            t0 = time.perf_counter()
            for i in updates:
                mv.insert_example(int(i), int(cls[i]))
            dt = time.perf_counter() - t0
            emit(f"fig12b_multiclass_{engine}_k{k}", dt / len(updates) * 1e6,
                 f"updates/s={len(updates)/dt:.0f}")


def main():
    feature_sensitivity()
    multiclass()


if __name__ == "__main__":
    main()
