"""Paper-scale benchmark of the vectorized multi-view engine (ROADMAP item).

Runs the workloads the paper sizes its corpora at — citeseer_like at full
scale (721k rows through the hashing trick, k = 16 one-vs-all views over
ONE shared table) and forest_like (582k × 54 dense) — and reports
tuples/sec for the three paths that matter at scale:

  * insert       — batched training inserts (`insert_examples`) through the
                   eager engine: SGD on the stacked models + ONE union-band
                   maintenance round per batch;
  * all_members  — the (k,) positive-count probe on the maintained views;
  * hybrid reads — §3.5.2 `hybrid_labels_of` single-entity reads on a
                   hybrid-policy twin driven by the same stream (waters
                   short-circuit -> hot buffer -> one shared F-row touch).

Writes machine-readable ``BENCH_scale.json``. BENCH_SCALE scales the row
counts (1.0 = paper scale; the CI smoke uses 0.02); BENCH_SCALE_HASH_DIM
sizes the hashed feature space of the text corpus.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.core import MulticlassView
from repro.core.engine import HYBRID_TIERS
from repro.data import citeseer_like, forest_like

K = int(os.environ.get("BENCH_SCALE_K", "16"))
BATCH = int(os.environ.get("BENCH_SCALE_BATCH", "64"))
ROUNDS = int(os.environ.get("BENCH_SCALE_ROUNDS", "30"))
HASH_DIM = int(os.environ.get("BENCH_SCALE_HASH_DIM", "1024"))
READS = int(os.environ.get("BENCH_SCALE_READS", "2000"))


def _stream(n: int, cls: np.ndarray, seed: int):
    r = np.random.default_rng(seed)
    ids = r.integers(0, n, ROUNDS * BATCH)
    return [(int(i), int(cls[i])) for i in ids]


def _bench_corpus(corpus, pq) -> dict:
    n, d = corpus.features.shape
    p, q = pq
    r = np.random.default_rng(5)
    cls = r.integers(0, K, n)            # k-way one-vs-all labeling
    inserts = _stream(n, cls, seed=7)
    kw = dict(p=p, q=q, lr=0.05, cost_mode="measured")

    eager = MulticlassView(corpus.features, K, policy="eager", **kw)
    t0 = time.perf_counter()
    for j in range(0, len(inserts), BATCH):
        chunk = inserts[j:j + BATCH]
        eager.insert_examples([i for i, _ in chunk], [c for _, c in chunk])
    insert_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    calls = 0
    while time.perf_counter() - t0 < 0.5:
        counts = eager.class_counts()
        calls += 1
    members_s = (time.perf_counter() - t0) / calls
    # exactness gate: maintained counts == from-scratch relabel counts
    truth = (corpus.features @ eager.W.T
             - eager.b.astype(np.float32) >= 0).sum(axis=0)
    assert np.array_equal(counts, truth), (counts, truth.tolist())

    hybrid = MulticlassView(corpus.features, K, policy="hybrid",
                            buffer_frac=0.01, **kw)
    for j in range(0, len(inserts), BATCH):
        chunk = inserts[j:j + BATCH]
        hybrid.insert_examples([i for i, _ in chunk], [c for _, c in chunk])
    read_ids = np.random.default_rng(9).integers(0, n, READS)
    eng = hybrid.engine
    t0 = time.perf_counter()
    for i in read_ids:
        eng.hybrid_labels_of(int(i))
    read_s = time.perf_counter() - t0
    hits = eng.hybrid_hits.astype(float)
    frac = hits / max(1.0, hits.sum())

    name = corpus.name
    emit(f"scale_insert_{name}_k{K}_n{n}",
         insert_s / len(inserts) * 1e6,
         f"{len(inserts) / insert_s:.0f}/s")
    emit(f"scale_all_members_{name}_k{K}_n{n}", members_s * 1e6,
         f"{1.0 / members_s:.0f}/s")
    emit(f"scale_hybrid_read_{name}_k{K}_n{n}", read_s / READS * 1e6,
         f"{READS / read_s:.0f}/s")
    return {
        "n": n, "d": d, "k": K,
        "insert": {"total": len(inserts), "seconds": insert_s,
                   "tuples_per_sec": len(inserts) / insert_s,
                   "reorgs": int(eager.engine.stats.reorgs)},
        "all_members": {"seconds_per_call": members_s,
                        "calls_per_sec": 1.0 / members_s},
        "hybrid_read": {"reads": int(READS), "seconds": read_s,
                        "tuples_per_sec": READS / read_s,
                        "tier_fractions": {t: float(f) for t, f
                                           in zip(HYBRID_TIERS, frac)}},
    }


def main() -> None:
    cs = citeseer_like(scale=BENCH_SCALE, hash_dim=HASH_DIM)
    fc = forest_like(scale=BENCH_SCALE)
    payload = {
        "scale": BENCH_SCALE,
        "batch": BATCH, "rounds": ROUNDS,
        "corpora": {
            "CS": _bench_corpus(cs, (np.inf, 1.0)),
            "FC": _bench_corpus(fc, (2.0, 2.0)),
        },
    }
    with open("BENCH_scale.json", "w") as f:
        json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
