"""Paper Fig. 11(A): eager-update throughput vs corpus size (scalability).
Sizes scale the FC clone 1x/2x/4x (the paper's 1/2/4 GB synthetic sweep)."""
from __future__ import annotations

import time


from benchmarks.common import BottouSGD, emit, warm_model
from repro.core import HazyEngine, NaiveEngine
from repro.data import forest_like


def main():
    base = 0.05
    for mult in (1, 2, 4):
        c = forest_like(scale=base * mult, seed=7)
        sgd = BottouSGD()
        model, stream = warm_model(c, sgd, n=6000)
        for kind in ("hazy", "naive"):
            eng = (HazyEngine(c.features, p=2.0, q=2.0, policy="eager")
                   if kind == "hazy" else NaiveEngine(c.features, policy="eager"))
            m = model.copy()
            loc = BottouSGD()
            loc.t = sgd.t
            eng.apply_model(m)
            if kind == "hazy":
                eng.reorganize()
            ups = [next(stream) for _ in range(200)]
            t0 = time.perf_counter()
            for _, f, y in ups:
                m = loc.step(m, f, y)
                eng.apply_model(m)
            dt = time.perf_counter() - t0
            emit(f"fig11a_scalability_{kind}_n{c.features.shape[0]}",
                 dt / len(ups) * 1e6, f"updates/s={len(ups)/dt:.0f}")


if __name__ == "__main__":
    main()
