"""Multiclass one-vs-all maintenance (paper App. B.5.4 / C.3) at k = 16 on
the scaled-down Cora workload: the seed's per-class Python loop (k
independent engines, k feature-table copies) vs the vectorized multi-view
engine (one shared table, stacked models), per-example and batched.

Emits the usual CSV rows plus machine-readable ``BENCH_multiclass.json``
(written to the working directory) so CI can gate on the speedup."""
from __future__ import annotations

import json
import os
import time


from benchmarks.common import BENCH_SCALE, emit
from repro.core import MulticlassView
from repro.data import cora_like, multiclass_example_stream

K = int(os.environ.get("BENCH_MULTICLASS_K", "16"))
BATCH = int(os.environ.get("BENCH_MULTICLASS_BATCH", "32"))


def _workload():
    # BENCH_SCALE defaults to 0.1 of the paper corpora; Cora is already
    # tiny, so the default maps to the full 2708 papers.
    corpus = cora_like(scale=BENCH_SCALE / 0.1)
    n_updates = max(128, int(2000 * (BENCH_SCALE / 0.1)))
    stream = multiclass_example_stream(corpus, seed=7)
    inserts = [next(stream) for _ in range(n_updates)]
    # relabel into K classes so k is a free experimental knob (the paper
    # uses Cora's 7 topics; we stress more views per table)
    inserts = [(i, c % K) for i, c in inserts]
    return corpus, inserts


def _run(view: MulticlassView, inserts, batch: int | None) -> float:
    t0 = time.perf_counter()
    if batch is None:
        for i, c in inserts:
            view.insert_example(i, c)
    else:
        for j in range(0, len(inserts), batch):
            chunk = inserts[j:j + batch]
            view.insert_examples([i for i, _ in chunk], [c for _, c in chunk])
    return (time.perf_counter() - t0) / len(inserts) * 1e6   # us / insert


def main() -> None:
    corpus, inserts = _workload()
    kw = dict(policy="eager", lr=0.1, p=2.0, q=2.0, cost_mode="modeled")

    seed_view = MulticlassView(corpus.features, K, vectorized=False, **kw)
    us_seed = _run(seed_view, inserts, batch=None)

    vec_view = MulticlassView(corpus.features, K, vectorized=True, **kw)
    us_vec = _run(vec_view, inserts, batch=None)

    bat_view = MulticlassView(corpus.features, K, vectorized=True, **kw)
    us_bat = _run(bat_view, inserts, batch=BATCH)

    # identical final models => identical view contents (exactness check)
    assert seed_view.class_counts() == bat_view.class_counts(), \
        (seed_view.class_counts(), bat_view.class_counts())
    assert bat_view.check_consistent()

    n = corpus.features.shape[0]
    emit(f"multiclass_seed_loop_k{K}_n{n}", us_seed)
    emit(f"multiclass_vectorized_k{K}_n{n}", us_vec,
         f"{us_seed / us_vec:.1f}x")
    emit(f"multiclass_vectorized_batch{BATCH}_k{K}_n{n}", us_bat,
         f"{us_seed / us_bat:.1f}x")

    payload = {
        "workload": {"corpus": corpus.name, "n": n,
                     "d": int(corpus.features.shape[1]), "k": K,
                     "updates": len(inserts), "batch": BATCH},
        "us_per_insert": {"seed_loop": us_seed, "vectorized": us_vec,
                          "vectorized_batched": us_bat},
        "speedup": {"vectorized": us_seed / us_vec,
                    "vectorized_batched": us_seed / us_bat},
    }
    with open("BENCH_multiclass.json", "w") as f:
        json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
