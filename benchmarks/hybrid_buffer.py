"""Paper Fig. 6(B): Single-Entity read rate vs hybrid buffer size, for
models with ~1%/10%/50% of tuples between the waters (S1/S10/S50).

The S-bands are constructed by perturbing the warm model until the water
band covers the requested fraction (the paper's construction)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BottouSGD, corpus, emit, warm_model
from repro.core import HazyEngine, LinearModel


def _open_band(eng: HazyEngine, model: LinearModel, frac: float) -> LinearModel:
    """Perturb the model until band_fraction ~ frac (growing steps)."""
    r = np.random.default_rng(0)
    m = model
    d = m.w.shape[0]
    step = 1e-3 * (np.linalg.norm(m.w) + 1.0)
    for _ in range(400):
        if eng.band_fraction() >= frac:
            break
        m = LinearModel((m.w + r.normal(size=d).astype(np.float32) * step), m.b)
        eng.waters.update(m, eng.stored)   # widen waters only — no reorg
        eng.model = m
        step *= 1.3
    # relabel the band so reads stay exact
    eng._incremental_step()
    return m


def main():
    name = "FC"
    c, (p, q) = corpus(name)
    n = c.features.shape[0]
    n_reads = 5000
    r = np.random.default_rng(1)
    ids = r.integers(0, n, n_reads)
    for frac, tag in [(0.01, "S1"), (0.10, "S10"), (0.50, "S50")]:
        for buf in [0.005, 0.01, 0.05, 0.10, 0.20, 0.50]:
            sgd = BottouSGD()
            model, _ = warm_model(c, sgd, n=3000)
            eng = HazyEngine(c.features, p=p, q=q, policy="eager",
                             buffer_frac=buf)
            eng.apply_model(model)
            eng.reorganize()
            model = _open_band(eng, model, frac)
            t0 = time.perf_counter()
            hits = {"water": 0, "buffer": 0, "disk": 0}
            for i in ids:
                _, how = eng.hybrid_label(int(i))
                hits[how] += 1
            dt = time.perf_counter() - t0
            emit(f"fig6b_{tag}_buf{int(buf*100)}pct", dt / n_reads * 1e6,
                 f"reads/s={n_reads/dt:.0f};band={eng.band_fraction():.3f};"
                 f"water={hits['water']};buffer={hits['buffer']};disk={hits['disk']}")


if __name__ == "__main__":
    main()
