"""Benchmark-regression gate: fresh BENCH_*.json vs committed baselines.

CI runs the bench smokes, then this script compares the freshly produced
artifacts in the repo root against the baselines committed under
``benchmarks/baselines/``. The tolerances live in ONE table below:

  * throughput — fail when fresh < baseline * (1 - 0.30)  (>30% slower)
  * latency    — fail when fresh > baseline * (1 + 0.30)  (same bound,
                 expressed for lower-is-better metrics)
  * hit_rate   — fail when fresh < baseline - 0.05        (5 percentage
                 points; guards the hybrid non-disk fraction)

Wall-clock metrics are hardware-sensitive in two ways, and the gate
handles both explicitly:

  * different workload — every file's comparison is guarded by its
    workload signature (corpus size / k / scale): a scale mismatch SKIPs
    the file with a warning to regenerate the baselines (``--update``
    copies the fresh artifacts over them, and records the calibration).
  * different machine speed — a deterministic numpy probe (matmul +
    stable argsort, the shape of the benches) is timed when seeding AND
    when gating; throughput/latency metrics are normalized by the speed
    ratio (clamped to [1/4, 4] so a pathological probe can never wash
    out a real regression). Ratio metrics (overheads, hit rates) need no
    normalization and carry the tightest signal.

Usage:
  python benchmarks/check_regress.py              # gate all files
  python benchmarks/check_regress.py BENCH_serve.json   # gate only these
  python benchmarks/check_regress.py --update     # re-seed the baselines

Positional args select a subset of the gated files — CI jobs that produce
disjoint artifacts (bench-smoke vs serve-smoke) each gate exactly what
they ran, and a missing artifact in the OTHER job's set is not an error.
With ``--update`` a selection re-seeds only those files, but the shared
machine-speed calibration is always re-recorded — partial re-seeds on a
different machine skew the other baselines, so prefer full ``--update``
runs from one box.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import time

import numpy as np

# --------------------------------------------------------------------------
# THE tolerance table (the whole contract of the gate)
# --------------------------------------------------------------------------
TOLERANCES = {
    "throughput": 0.30,      # max fractional drop for higher-is-better
    "latency": 0.30,         # max fractional rise for lower-is-better
    "latency_smoke": 0.60,   # micro-latencies (~tens of ms timed at the CI
                             # smoke scale): run-to-run jitter approaches
                             # the standard bound, so only the 2x-class
                             # regressions that matter are actionable
    "latency_serve": 0.30,   # served p99 (full wire round trip under the
                             # epoch gate): the tail is the contention
                             # signal the serve gate exists for, so it
                             # keeps the standard bound (ISSUE 6)
    "ratio_up": 0.30,        # within-run ratios, higher-is-better — both
    "ratio_down": 0.30,      # sides timed in ONE process, so machine
                             # noise cancels and NO speed normalization
                             # applies (lower-is-better variant below)
    "hit_rate": 0.05,        # max absolute drop (percentage points / 100)
}

# (file, dotted path — "*" fans out over dict keys, kind)
CHECKS = [
    ("BENCH_multiclass.json", "us_per_insert.vectorized_batched",
     "latency_smoke"),
    # NOT gated: speedup.vectorized_batched — a ratio of two separately
    # timed runs (the k-engine seed loop vs the batched engine) whose
    # numerator swings ~2x with machine load at smoke scale.
    ("BENCH_hybrid.json", "hybrid_non_disk_fraction", "hit_rate"),
    # read-path regression is gated via the WITHIN-RUN ratio vs lazy (the
    # two read paths are timed back-to-back in one process, so machine
    # noise cancels); the absolute read_path_us at smoke scale is ~30 ms
    # of timed work and flaps past any honest tolerance.
    ("BENCH_hybrid.json", "read_path_speedup_vs_lazy", "ratio_up"),
    ("BENCH_scale.json", "corpora.*.insert.tuples_per_sec", "throughput"),
    # NOT gated: corpora.*.hybrid_read.tuples_per_sec — ~25 ms of timed
    # micro-reads at smoke scale, observed 2-3x bimodal across identical
    # runs; the insert throughput above times seconds of maintenance and
    # is the stable scale signal.
    ("BENCH_sql.json", "paths.insert.sql_rows_per_s", "throughput"),
    ("BENCH_sql.json", "paths.insert.overhead_x", "ratio_down"),
    ("BENCH_sql.json", "paths.prepared_point.overhead_x", "ratio_down"),
    ("BENCH_storage.json", "corpora.cora_like.budgets.*.non_disk_fraction",
     "hit_rate"),
    ("BENCH_storage.json", "corpora.FC.budgets.*.non_disk_fraction",
     "hit_rate"),
    # cold-scan-after-update (ISSUE 8): the synchronous-baseline p50 is
    # dominated by the deterministic emulated submission latency (stable);
    # the readahead-path p99 carries coalesced-wait tails (smoke bound).
    # speedup is a within-run ratio (both scans timed in one process) and
    # the readahead hit rate is the eps-order-locality signal itself.
    ("BENCH_storage.json", "corpora.cora_like.cold_scan.sync_p50_us",
     "latency"),
    ("BENCH_storage.json", "corpora.cora_like.cold_scan.p99_us",
     "latency_smoke"),
    ("BENCH_storage.json", "corpora.cora_like.cold_scan.speedup",
     "ratio_up"),
    ("BENCH_storage.json", "corpora.cora_like.cold_scan.readahead_hit_rate",
     "hit_rate"),
    # NOT gated: the per-budget read_us micro-latencies. At the CI smoke
    # scale they time ~20 ms of work and jitter ±40% run-to-run, far past
    # any honest tolerance; the read-path latency signal is carried by
    # BENCH_hybrid.json:policies.hybrid.read_path_us, where maintenance
    # amortizes the measurement.
    ("BENCH_serve.json", "latency_ms.p50", "latency_smoke"),
    ("BENCH_serve.json", "latency_ms.p99", "latency_serve"),
    ("BENCH_serve.json", "qps", "throughput"),
    # freshness fleet (ISSUE 10): the staleness <= lag contract is a HARD
    # assert inside the bench itself (workload-pinned, so no tolerance
    # games here); the gate watches the refresh machinery's speed.
    ("BENCH_fleet.json", "refresh.slices_per_sec", "throughput"),
    ("BENCH_fleet.json", "refresh.p99_slice_ms", "latency_smoke"),
]

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")
CALIBRATION_FILE = "CALIBRATION.json"
FILES = sorted({f for f, _, _ in CHECKS})


def calibrate(reps: int = 5) -> float:
    """Machine-speed probe: median seconds for a deterministic numpy
    workload shaped like the benches (f32 matmul + stable argsort). The
    ratio baseline/fresh normalizes wall-clock metrics across machines
    and across load spikes on one machine."""
    rng = np.random.default_rng(0)
    F = rng.normal(size=(4096, 64)).astype(np.float32)
    W = rng.normal(size=(16, 64)).astype(np.float32)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(8):
            Z = F @ W.T
            np.argsort(Z[:, 0], kind="stable")
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _walk(doc, path):
    """Resolve a dotted path; '*' fans out. Yields (concrete_path, value)."""
    def rec(node, parts, prefix):
        if not parts:
            yield ".".join(prefix), node
            return
        head, rest = parts[0], parts[1:]
        if head == "*":
            if isinstance(node, dict):
                for k in sorted(node):
                    yield from rec(node[k], rest, prefix + [k])
        elif isinstance(node, dict) and head in node:
            yield from rec(node[head], rest, prefix + [head])
    yield from rec(doc, path.split("."), [])


def _signature(doc):
    """Workload signature guarding hardware/scale comparability."""
    w = doc.get("workload", {})
    return (w.get("n"), w.get("k"), w.get("updates"), w.get("reads"),
            doc.get("scale"))


def _check_one(kind, fresh, base, speed):
    """`speed` = baseline_probe_s / fresh_probe_s (< 1 when this machine
    is currently slower than the one the baselines were seeded on)."""
    tol = TOLERANCES[kind]
    if kind == "throughput":
        adj = fresh / speed
        ok = adj >= base * (1.0 - tol)
        bound = f"adj {adj:.4g} >= {base * (1.0 - tol):.4g}"
    elif kind.startswith("latency"):
        adj = fresh * speed
        ok = adj <= base * (1.0 + tol)
        bound = f"adj {adj:.4g} <= {base * (1.0 + tol):.4g}"
    elif kind == "ratio_up":                        # within-run ratio
        ok = fresh >= base * (1.0 - tol)
        bound = f">= {base * (1.0 - tol):.4g}"
    elif kind == "ratio_down":                      # within-run ratio
        ok = fresh <= base * (1.0 + tol)
        bound = f"<= {base * (1.0 + tol):.4g}"
    else:                                           # hit_rate: no wall clock
        ok = fresh >= base - tol
        bound = f">= {base - tol:.4g}"
    return ok, bound


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    update = "--update" in argv
    selected = [a for a in argv if not a.startswith("--")]
    unknown = [f for f in selected if f not in FILES]
    if unknown:
        print(f"ERROR: not gated file(s): {', '.join(unknown)} "
              f"(known: {', '.join(FILES)})")
        return 2
    files = selected or FILES
    fresh_dir = "."
    if update:
        os.makedirs(BASELINE_DIR, exist_ok=True)
        for f in files:
            src = os.path.join(fresh_dir, f)
            if os.path.exists(src):
                shutil.copy(src, os.path.join(BASELINE_DIR, f))
                print(f"seeded baseline {f}")
            else:
                print(f"WARNING: no fresh {f} to seed from")
        probe_s = calibrate()
        with open(os.path.join(BASELINE_DIR, CALIBRATION_FILE), "w") as fh:
            json.dump({"probe_seconds": probe_s}, fh, indent=2)
        print(f"seeded {CALIBRATION_FILE} (probe {probe_s * 1e3:.2f} ms)")
        return 0

    cal_path = os.path.join(BASELINE_DIR, CALIBRATION_FILE)
    speed = 1.0
    if os.path.exists(cal_path):
        with open(cal_path) as fh:
            base_probe = json.load(fh)["probe_seconds"]
        fresh_probe = calibrate()
        # clamp: a pathological probe must never wash out a real regression
        speed = min(4.0, max(0.25, base_probe / fresh_probe))
        print(f"machine-speed probe: baseline {base_probe * 1e3:.2f} ms, "
              f"now {fresh_probe * 1e3:.2f} ms -> speed x{speed:.2f} "
              f"(wall-clock metrics normalized by this)")
    else:
        print(f"WARNING: no {CALIBRATION_FILE} in baselines; wall-clock "
              f"metrics compared unnormalized")

    failures, skipped, compared = [], [], 0
    docs = {}
    for f in files:
        fresh_path = os.path.join(fresh_dir, f)
        base_path = os.path.join(BASELINE_DIR, f)
        if not os.path.exists(base_path):
            print(f"SKIP {f}: no committed baseline "
                  f"(seed with --update)")
            skipped.append(f)
            continue
        if not os.path.exists(fresh_path):
            failures.append(f"{f}: fresh artifact missing — did the "
                            f"benchmark run?")
            continue
        with open(fresh_path) as fh:
            fresh_doc = json.load(fh)
        with open(base_path) as fh:
            base_doc = json.load(fh)
        if _signature(fresh_doc) != _signature(base_doc):
            print(f"SKIP {f}: workload signature changed "
                  f"{_signature(base_doc)} -> {_signature(fresh_doc)}; "
                  f"regenerate baselines with --update")
            skipped.append(f)
            continue
        docs[f] = (fresh_doc, base_doc)

    for f, path, kind in CHECKS:
        if f not in docs:
            continue
        fresh_doc, base_doc = docs[f]
        base_vals = dict(_walk(base_doc, path))
        fresh_vals = dict(_walk(fresh_doc, path))
        if not base_vals:
            # a check that resolves to NOTHING would otherwise pass while
            # guarding nothing (typo'd path, or a renamed metric re-seeded
            # into the baselines) — that's a gate defect, fail loudly
            failures.append(f"{f}:{path}: check resolved no metrics in the "
                            f"baseline — fix the CHECKS path or re-seed")
            continue
        for cpath, base in base_vals.items():
            if cpath not in fresh_vals:
                failures.append(f"{f}:{cpath}: metric missing from fresh run")
                continue
            fresh = fresh_vals[cpath]
            ok, bound = _check_one(kind, fresh, base, speed)
            compared += 1
            status = "ok  " if ok else "FAIL"
            print(f"{status} {f}:{cpath} [{kind}] fresh={fresh:.4g} "
                  f"baseline={base:.4g} ({bound})")
            if not ok:
                failures.append(f"{f}:{cpath}: {kind} {fresh:.4g} vs "
                                f"baseline {base:.4g} (bound {bound})")

    print(f"\n{compared} metrics compared, {len(skipped)} files skipped, "
          f"{len(failures)} failures")
    if failures:
        print("\nREGRESSIONS:")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
