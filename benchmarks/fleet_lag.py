"""Freshness-scheduler fleet: N views with mixed TARGET_LAGs on one
table, refreshed by the background scheduler while a live insert stream
commits (ISSUE 10 — the delayed-view-semantics shape: Snowflake dynamic
tables' lag-driven refresh over the paper's incremental maintenance).

Fleet (one base table, five views):

  * ``chain_a -> chain_b -> chain_c`` — a derived cascade: ``chain_a``
    declares ``target_lag = downstream`` (as fresh as its consumers
    need), ``chain_b`` a numeric mid lag, ``chain_c`` the leaf lag; the
    scheduler must refresh the chain in topological order;
  * ``solo`` — an independent root view at the tightest lag (the
    scheduler's priority term must keep it fresh even while the cascade
    is catching up);
  * ``ctrl`` — an immediate control view (maintained at commit time,
    exactly the pre-scheduler path).

The stream is paced so several lag windows elapse; a sampler thread
records per-view staleness from ``schedule_snapshot`` (the same ledger
``SHOW SCHEDULE`` renders) while a ticker drives refresh slices.

Acceptance (raises -> run.py exits non-zero -> CI goes red):
  * every scheduled view's MEASURED max staleness stays <= its effective
    lag (the delayed-view contract);
  * after a final freshness barrier every scheduled view's labels are
    bit-identical to an immediate replay of the same stream at the same
    commit boundaries (scheduling moves work in time, never changes it).

Reported into ``BENCH_fleet.json`` and gated by ``check_regress.py``:
refresh slices/sec (throughput) and the p99 refresh-slice latency
(latency_smoke); per-view compliance ratios ride along unguarded (the
hard <= 1.0 assert lives here, where the workload is pinned).
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.data import synthetic_corpus
from repro.rdbms import Catalog, Executor
from repro.scheduler import FreshnessScheduler
from repro.scheduler import refresh as fr

DURATION = float(os.environ.get("BENCH_FLEET_SECONDS", "2.5"))
GROUP = int(os.environ.get("BENCH_FLEET_GROUP", "8"))
LAGS = {"chain_a": "downstream", "chain_b": "1 s", "chain_c": "2 s",
        "solo": "500 ms", "ctrl": None}


def _build(corpus) -> Catalog:
    catalog = Catalog()
    catalog.register_table("t", corpus.features, truth=corpus.labels)
    base = {"policy": "eager", "cost_mode": "modeled"}
    for name, parent in (("chain_a", "t"), ("chain_b", "chain_a"),
                         ("chain_c", "chain_b"), ("solo", "t"),
                         ("ctrl", "t")):
        opts = dict(base)
        if LAGS[name]:
            opts["target_lag"] = LAGS[name]
        catalog.create_view(name, parent, "svm", opts)
    return catalog


def _stream_plan(corpus, seed=17):
    """The full insert stream, pre-drawn: the paced loop is pure serving."""
    n = corpus.features.shape[0]
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, n, size=4096)
    return [(int(i), int(corpus.labels[i])) for i in ids]


def _labels(catalog, name):
    vd = catalog.view(name)
    n = vd.facade.view.F.shape[0]
    return np.array([vd.facade.label(i) for i in range(n)], np.int8)


def main() -> None:
    corpus = synthetic_corpus("fleet", max(240, int(24000 * BENCH_SCALE)),
                              24, seed=13)
    catalog = _build(corpus)
    ex = Executor(catalog, group_commit=GROUP)
    plan = _stream_plan(corpus)

    slice_s: list = []
    peaks: dict = {}
    errors: list = []
    done = threading.Event()

    def ticker():
        sched = FreshnessScheduler(ex, interval=0.005)
        try:
            while not done.is_set():
                t0 = time.perf_counter()
                refreshed = sched.tick()
                if refreshed:
                    slice_s.append(time.perf_counter() - t0)
                else:
                    done.wait(0.005)
        except Exception as e:               # noqa: BLE001 — re-raised below
            errors.append(e)

    worker = threading.Thread(target=ticker, daemon=True)
    worker.start()

    # a FIXED update count paced over ~DURATION: the workload signature
    # (and the replay below) must not depend on wall-clock jitter
    plan = plan[:400]
    sent = len(plan)
    pace = DURATION / len(plan)
    t_wall = time.perf_counter()
    for i, y in plan:
        ex.execute_one(f"INSERT INTO t (id, label) VALUES ({i}, {y})")
        for row in fr.schedule_snapshot(catalog):
            if row["effective_lag"] is not None:
                peaks[row["view"]] = max(peaks.get(row["view"], 0.0),
                                         row["staleness_s"])
        time.sleep(pace)
    wall = time.perf_counter() - t_wall
    done.set()
    worker.join(timeout=60)
    if errors:
        raise RuntimeError(f"refresher thread failed: {errors[0]!r}") \
            from errors[0]
    ex.execute_one("COMMIT")
    ex.refresh_views()                       # final freshness barrier

    # -- acceptance 1: measured staleness <= effective lag, per view -----
    ratios = {}
    for row in fr.schedule_snapshot(catalog):
        lag = row["effective_lag"]
        if lag is None:
            continue
        ratio = peaks.get(row["view"], 0.0) / lag
        ratios[row["view"]] = ratio
        assert ratio <= 1.0, (
            f"view {row['view']!r} blew its lag: peak staleness "
            f"{peaks.get(row['view'], 0.0):.3f}s vs lag {lag:.3f}s")

    # -- acceptance 2: the scheduler only moved work in time -------------
    replay_cat = _build(corpus)
    for vd in replay_cat.topo_order():       # same DAG, all immediate
        if vd.options.target_lag is not None:
            replay_cat.alter_view_options(vd.name, {"target_lag": None})
    replay = Executor(replay_cat, group_commit=GROUP)
    for i, y in plan[:sent]:
        replay.execute_one(f"INSERT INTO t (id, label) VALUES ({i}, {y})")
    replay.execute_one("COMMIT")
    replay.refresh_views()                   # same barrier (feature pulls)
    for name in LAGS:
        a, b = _labels(catalog, name), _labels(replay_cat, name)
        assert np.array_equal(a, b), f"view {name!r} diverged from replay"

    snap = {r["view"]: r for r in fr.schedule_snapshot(catalog)}
    slices = len(slice_s)
    payload = {
        "workload": {"corpus": corpus.name, "n": corpus.features.shape[0],
                     "d": int(corpus.features.shape[1]),
                     "k": len(LAGS), "updates": sent, "reads": 0,
                     "duration_s": round(wall, 3), "group_commit": GROUP},
        "scale": BENCH_SCALE,
        "views": {
            name: {
                "target_lag": LAGS[name],
                "effective_lag_s": snap[name]["effective_lag"],
                "max_staleness_s": round(peaks.get(name, 0.0), 4),
                "staleness_over_lag": round(ratios.get(name, 0.0), 4),
                "refreshes": snap[name]["refreshes"],
                "rows_applied": snap[name]["rows_applied"],
            } for name in LAGS},
        "compliance": {"worst_ratio": round(max(ratios.values()), 4),
                       "views_within_lag": len(ratios)},
        "refresh": {
            "slices": slices,
            "slices_per_sec": round(slices / wall, 3) if wall else 0.0,
            "p50_slice_ms": round(float(np.percentile(
                np.asarray(slice_s) * 1e3, 50)), 3) if slice_s else 0.0,
            "p99_slice_ms": round(float(np.percentile(
                np.asarray(slice_s) * 1e3, 99)), 3) if slice_s else 0.0,
        },
    }
    with open("BENCH_fleet.json", "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)

    for name in sorted(ratios):
        emit(f"fleet_staleness_over_lag_{name}",
             ratios[name] * 100.0, "ratio x100")
    emit("fleet_refresh_slices_per_sec",
         payload["refresh"]["slices_per_sec"], "slices/s")
    emit("fleet_refresh_p99_slice_ms",
         payload["refresh"]["p99_slice_ms"], "ms")


if __name__ == "__main__":
    main()
