"""Storage-tier sweep: memory_budget vs hit rate / read-path latency.

The §3.5.2/Fig. 8 claim this measures: with the entity table on disk
(mmap'd `EntityStore`) and only a FRACTION of it allowed in memory
(`BufferPool` budget), hybrid point reads still answer almost entirely
from the in-memory tiers, because (a) the waters short-circuit resolves
most probes with no row access at all and (b) reorganization re-warms the
pool along the eps clustering order, so the band rows — the only rows
probes can miss on — are exactly the resident ones.

Two corpora, per the paper's experimental families:
  * cora_like  — the multiclass corpus (k one-vs-all views over ONE
                 table, `MultiViewEngine`), swept over
                 memory_budget ∈ {5%, 10%, 25%, 100%} of the table bytes;
  * FC         — the paper-scale forest corpus family (binary, k = 1
                 `HazyEngine`), same sweep.

Each budgeted run is compared against an all-in-RAM twin on the SAME
insert/read stream (read latency ratio), and against an eager all-in-RAM
twin for label exactness — the acceptance bar: at the 10% budget on
cora_like, >= 90% of probes answer from waters/buffer/pool (<= 10% cold
disk reads) and labels are BIT-IDENTICAL to the eager path.

The cold-scan-after-update workload (ISSUE 8) measures the async read
path itself: a band scan in boundary-outward eps order over a fully cold
pool at the 10% budget, on a request-latency disk model (`_LatencyStore`:
one submission latency per read CALL — batched `read_pages` amortize it).
Synchronous baseline vs `Prefetcher` readahead; acceptance: >= 2x
end-to-end speedup, labels still bit-identical. Emits
``BENCH_storage.json`` (gated by benchmarks/check_regress.py).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, corpus as bench_corpus, emit
from repro.core import MulticlassView, sgd_step, zero_model
from repro.core.engine import PROBE_TIERS
from repro.core.hazy import HazyEngine
from repro.data import cora_like, example_stream, multiclass_example_stream
from repro.storage import BufferPool, EntityStore, Prefetcher

BATCH = int(os.environ.get("BENCH_STORAGE_BATCH", "16"))
READS_PER_ROUND = int(os.environ.get("BENCH_STORAGE_READS", "12"))
BUFFER_FRAC = float(os.environ.get("BENCH_STORAGE_BUFFER", "0.05"))
BUDGETS = (0.05, 0.10, 0.25, 1.00)
ACCEPT_BUDGET = 0.10          # the ISSUE 5 acceptance point
ACCEPT_NON_DISK = 0.90
# cold-scan workload (ISSUE 8): per-I/O-request submission latency of the
# emulated disk, and the required readahead speedup at the 10% budget
SUBMIT_US = float(os.environ.get("BENCH_STORAGE_SUBMIT_US", "120"))
ACCEPT_COLD_SPEEDUP = 2.0
COLD_PAGE_BYTES = 256         # 1 row/page on cora (d=64): misses dominate


def _pool(F, frac):
    return BufferPool(EntityStore.from_array(F), max(1, int(frac * F.nbytes)))


# ---------------------------------------------------------------------------
# cora_like sweep: k one-vs-all views on MultiViewEngine
# ---------------------------------------------------------------------------

def _cora_workload():
    c = cora_like(scale=BENCH_SCALE / 0.1)
    n = c.features.shape[0]
    n_updates = max(160, int(2000 * (BENCH_SCALE / 0.1)))
    stream = multiclass_example_stream(c, seed=13)
    inserts = [next(stream) for _ in range(n_updates)]
    r = np.random.default_rng(17)
    rounds = [(inserts[j:j + BATCH], r.integers(0, n, READS_PER_ROUND))
              for j in range(0, len(inserts), BATCH)]
    return c, rounds


def _run_cora(c, rounds, policy, pool=None):
    view = MulticlassView(c.features, c.num_classes, policy=policy,
                          buffer_frac=BUFFER_FRAC, p=2.0, q=2.0, lr=0.1,
                          cost_mode="measured", store=pool)
    eng = view.engine
    read_s, n_reads = 0.0, 0
    for chunk, reads in rounds:
        view.insert_examples([i for i, _ in chunk], [cl for _, cl in chunk])
        t0 = time.perf_counter()
        for i in reads:
            eng.hybrid_labels_of(int(i)) if policy == "hybrid" \
                else eng.labels_of(int(i))
        read_s += time.perf_counter() - t0
        n_reads += len(reads)
    return view, read_s, n_reads


def _sweep_cora():
    c, rounds = _cora_workload()
    n, k = c.features.shape[0], c.num_classes
    base_view, base_read_s, n_reads = _run_cora(c, rounds, "hybrid")
    eager_view, _, _ = _run_cora(c, rounds, "eager")
    base_read_us = base_read_s / n_reads * 1e6
    out = {"n": n, "d": int(c.features.shape[1]), "k": k,
           "table_bytes": int(c.features.nbytes),
           "reads": n_reads, "buffer_frac": BUFFER_FRAC,
           "baseline_in_ram": {"read_us": base_read_us},
           "budgets": {}}
    accept = None
    for frac in BUDGETS:
        pool = _pool(c.features, frac)
        view, read_s, _ = _run_cora(c, rounds, "hybrid", pool=pool)
        eng = view.engine
        hits = eng.hybrid_hits.copy()        # snapshot before verification
        stats = pool.stats()
        total = float(max(1, hits.sum()))
        fr = {t: float(h) / total for t, h in zip(PROBE_TIERS, hits)}
        non_disk = 1.0 - fr["disk"]
        # exactness: bit-identical to the eager all-in-RAM path
        identical = True
        for i in range(n):
            labs, _ = eng.hybrid_labels_of(i)
            if not np.array_equal(labs, eager_view.engine.labels_of(i)):
                identical = False
                break
        read_us = read_s / n_reads * 1e6
        out["budgets"][f"{frac:.2f}"] = {
            "budget_bytes": stats["budget_bytes"],
            "read_us": read_us,
            "read_us_vs_in_ram": read_us / max(base_read_us, 1e-9),
            "tier_fractions": fr,
            "non_disk_fraction": non_disk,
            "hit_rate": stats["hit_rate"],
            "evictions": stats["evictions"],
            "cold_page_reads": stats["misses"],
            "labels_bit_identical_to_eager": identical,
        }
        emit(f"storage_cora_budget{int(frac * 100)}_k{k}_n{n}", read_us,
             f"non_disk={non_disk:.3f};hit_rate={stats['hit_rate']:.3f};"
             f"evictions={stats['evictions']}")
        assert identical, f"budget {frac}: labels diverged from eager"
        if frac == ACCEPT_BUDGET:
            accept = non_disk
    return out, accept


# ---------------------------------------------------------------------------
# cold-scan-after-update workload (ISSUE 8): band scan at 10% budget on a
# request-latency disk model, synchronous vs eps-order readahead
# ---------------------------------------------------------------------------

class _LatencyStore:
    """Disk model for the cold-scan workload: every read CALL pays one
    I/O submission latency (`SUBMIT_US` — seek + syscall, the part of a
    real device a warm mmap page cache hides), then the real copy.
    `read_pages` pays it ONCE for the whole batch (one scatter-gather
    submission), which is exactly the physical effect the async read
    path exploits: the Prefetcher turns N per-miss requests into N/batch
    batched ones. `time.sleep` releases the GIL, so the emulated I/O
    genuinely overlaps the scan thread like real I/O would."""

    def __init__(self, store, submit_us):
        self._inner = store
        self._submit_s = submit_us * 1e-6
        self.requests = 0                    # I/O submissions issued

    def read_page(self, pid):
        self.requests += 1
        time.sleep(self._submit_s)
        return self._inner.read_page(pid)

    def read_pages(self, pids):
        self.requests += 1
        time.sleep(self._submit_s)
        return self._inner.read_pages(pids)

    def __getattr__(self, name):             # geometry/directory delegate
        return getattr(self._inner, name)


def _cold_scan():
    """Drive updates into a hybrid view at the 10% budget, drop the pool
    cache, then scan the band (boundary-outward eps order — band first)
    entirely cold: once synchronously (every miss = one I/O request),
    once with the Prefetcher streaming the next chunk while the current
    one is served. Reports per-touch p50/p99, end-to-end speedup and the
    readahead hit rate; labels are verified bit-identical to eager."""
    c, rounds = _cora_workload()
    n = c.features.shape[0]
    store = _LatencyStore(
        EntityStore.from_array(c.features, page_bytes=COLD_PAGE_BYTES),
        SUBMIT_US)
    budget = max(store.page_bytes, int(ACCEPT_BUDGET * c.features.nbytes))
    pool = BufferPool(store, budget)
    view, _, _ = _run_cora(c, rounds, "hybrid", pool=pool)
    eager_view, _, _ = _run_cora(c, rounds, "eager")
    eng = view.engine
    schedule = eng._eps_order                # boundary-outward: band first
    budget_pages = max(2, pool.budget_bytes // store.page_bytes)
    # chunk = half the budget in entities: chunk t stays resident while
    # the worker streams chunk t+1 (evict=True sweeps the older chunks)
    chunk = max(8, (budget_pages // 2) * store.rows_per_page)
    chunks = [schedule[j:j + chunk] for j in range(0, n, chunk)]

    def scan(prefetch: bool):
        pool.close()                         # drop cache: fully cold
        pre = Prefetcher(pool, batch_pages=max(1, budget_pages // 2)) \
            if prefetch else None
        before_req = store.requests
        lat = np.empty(n, np.float64)
        t0 = time.perf_counter()             # includes the enqueue cost
        pos = 0
        for t, ids in enumerate(chunks):
            if pre is not None:
                if t == 0:
                    pre.enqueue(ids, evict=True)
                if t + 1 < len(chunks):
                    pre.enqueue(chunks[t + 1], evict=True)
            for i in ids:
                ts = time.perf_counter()
                pool.touch(int(i))
                lat[pos] = (time.perf_counter() - ts) * 1e6
                pos += 1
        total = time.perf_counter() - t0
        if pre is not None:
            pre.drain(30)
            pre.close()
        return total, lat[:pos], store.requests - before_req

    sync_s, sync_lat, sync_req = scan(prefetch=False)
    ra_s, ra_lat, ra_req = scan(prefetch=True)
    stats = pool.stats()                     # readahead counters: ON only
    speedup = sync_s / max(ra_s, 1e-9)
    # exactness (untimed): the budgeted hybrid view vs the eager twin
    identical = True
    for i in range(n):
        labs, _ = eng.hybrid_labels_of(i)
        if not np.array_equal(labs, eager_view.engine.labels_of(i)):
            identical = False
            break
    out = {
        "n": n, "page_bytes": COLD_PAGE_BYTES, "submit_us": SUBMIT_US,
        "budget_bytes": pool.budget_bytes, "scan_entities": n,
        "sync_s": sync_s, "readahead_s": ra_s, "speedup": speedup,
        "sync_p50_us": float(np.percentile(sync_lat, 50)),
        "sync_p99_us": float(np.percentile(sync_lat, 99)),
        "p50_us": float(np.percentile(ra_lat, 50)),
        "p99_us": float(np.percentile(ra_lat, 99)),
        "io_requests_sync": sync_req,
        "io_requests_readahead": ra_req,
        "readahead_hit_rate": stats["readahead_hit_rate"],
        "coalesced": stats["coalesced"],
        "labels_bit_identical_to_eager": identical,
    }
    emit(f"storage_cold_scan_n{n}", out["p50_us"],
         f"speedup={speedup:.2f};hit={stats['readahead_hit_rate']:.3f};"
         f"req={sync_req}->{ra_req}")
    assert identical, "cold scan: labels diverged from eager"
    assert speedup >= ACCEPT_COLD_SPEEDUP, \
        f"cold-scan readahead speedup {speedup:.2f} < {ACCEPT_COLD_SPEEDUP}"
    return out


# ---------------------------------------------------------------------------
# FC sweep: the paper-scale binary corpus family on HazyEngine (k = 1)
# ---------------------------------------------------------------------------

def _sweep_fc():
    c, _pq = bench_corpus("FC")
    n = c.features.shape[0]
    n_updates = max(160, int(1200 * (BENCH_SCALE / 0.1)))
    stream = example_stream(c, seed=31, label_noise=0.0)
    updates = [next(stream) for _ in range(n_updates)]
    r = np.random.default_rng(37)
    read_ids = r.integers(0, n, max(200, n_updates))
    out = {"n": n, "d": int(c.features.shape[1]), "k": 1,
           "table_bytes": int(c.features.nbytes), "budgets": {}}

    def run(pool):
        eng = HazyEngine(c.features, p=2.0, q=2.0, policy="hybrid",
                         buffer_frac=BUFFER_FRAC, store=pool)
        model = zero_model(c.features.shape[1])
        for j, (_, f, y) in enumerate(updates):
            model = sgd_step(model, f, y, lr=0.05, l2=1e-3)
            if (j + 1) % BATCH == 0 or j + 1 == len(updates):
                eng.apply_model(model)
        t0 = time.perf_counter()
        tiers = np.zeros(len(PROBE_TIERS), np.int64)
        names = list(PROBE_TIERS)
        for i in read_ids:
            _, how = eng.hybrid_label(int(i))
            tiers[names.index(how)] += 1
        return eng, model, tiers, time.perf_counter() - t0

    _, _, _, base_s = run(None)
    base_read_us = base_s / len(read_ids) * 1e6
    out["baseline_in_ram"] = {"read_us": base_read_us}
    for frac in BUDGETS:
        pool = _pool(c.features, frac)
        eng, model, tiers, dt = run(pool)
        stats = pool.stats()
        total = float(max(1, tiers.sum()))
        fr = {t: float(h) / total for t, h in zip(PROBE_TIERS, tiers)}
        non_disk = 1.0 - fr["disk"]
        truth = np.where(c.features @ model.w - model.b >= 0, 1, -1)
        sample = np.arange(0, n, max(1, n // 500))
        identical = all(eng.hybrid_label(int(i))[0] == truth[i]
                        for i in sample)
        read_us = dt / len(read_ids) * 1e6
        out["budgets"][f"{frac:.2f}"] = {
            "budget_bytes": stats["budget_bytes"],
            "read_us": read_us,
            "read_us_vs_in_ram": read_us / max(base_read_us, 1e-9),
            "tier_fractions": fr,
            "non_disk_fraction": non_disk,
            "hit_rate": stats["hit_rate"],
            "evictions": stats["evictions"],
            "cold_page_reads": stats["misses"],
            "labels_bit_identical_to_eager": identical,
        }
        emit(f"storage_fc_budget{int(frac * 100)}_n{n}", read_us,
             f"non_disk={non_disk:.3f};hit_rate={stats['hit_rate']:.3f}")
        assert identical, f"FC budget {frac}: labels diverged"
    return out


def main() -> None:
    cora, accept_non_disk = _sweep_cora()
    cora["cold_scan"] = _cold_scan()
    fc = _sweep_fc()
    payload = {
        "workload": {"n": cora["n"], "k": cora["k"], "scale": BENCH_SCALE,
                     "batch": BATCH, "reads_per_round": READS_PER_ROUND,
                     "budgets": list(BUDGETS)},
        "corpora": {"cora_like": cora, "FC": fc},
        "acceptance": {"budget": ACCEPT_BUDGET,
                       "non_disk_fraction": accept_non_disk,
                       "required": ACCEPT_NON_DISK},
    }
    with open("BENCH_storage.json", "w") as f:
        json.dump(payload, f, indent=2)
    # ISSUE 5 acceptance: at 10% of the table in memory, >= 90% of hybrid
    # point reads answer without a cold disk read
    assert accept_non_disk is not None and accept_non_disk >= ACCEPT_NON_DISK, \
        f"non-disk fraction {accept_non_disk} < {ACCEPT_NON_DISK} at 10% budget"


if __name__ == "__main__":
    main()
