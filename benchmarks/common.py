"""Shared benchmark substrate mirroring the paper's experimental setup (§4):

  * corpora: synthetic FC / DB / CS clones (Figure 3 statistics), scaled by
    BENCH_SCALE so the full suite runs in CI time;
  * warm model: 12k SGD examples (paper: "the experiment begins with a
    partially trained (warm) model (after 12k training examples)");
  * SGD: Bottou-style decaying rate, hinge loss (linear SVM — §4 setup);
  * norms: (p,q) = (2,2) for dense/l2 corpora, (inf,1) for text/l1 (§3.2).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Tuple

import numpy as np

from repro.core import LinearModel, zero_model
from repro.data import (citeseer_like, dblife_like, example_stream,
                        forest_like, Corpus)

BENCH_SCALE = float(os.environ.get("BENCH_SCALE", "0.1"))
WARM_EXAMPLES = int(os.environ.get("BENCH_WARM", "12000"))

_CORPORA = {}


def corpus(name: str) -> Tuple[Corpus, Tuple[float, float]]:
    """Returns (corpus, (p, q)). Cached across benchmarks."""
    if name not in _CORPORA:
        if name == "FC":
            _CORPORA[name] = (forest_like(scale=BENCH_SCALE), (2.0, 2.0))
        elif name == "DB":
            _CORPORA[name] = (dblife_like(scale=BENCH_SCALE), (np.inf, 1.0))
        elif name == "CS":
            _CORPORA[name] = (citeseer_like(scale=BENCH_SCALE), (np.inf, 1.0))
        else:
            raise KeyError(name)
    return _CORPORA[name]


class BottouSGD:
    """lr_t = lr0 / (1 + lr0 * lam * t) — the schedule of Bottou's svmsgd."""

    def __init__(self, lr0: float = 0.02, lam: float = 1e-3):
        self.lr0, self.lam, self.t = lr0, lam, 0

    def step(self, model: LinearModel, f: np.ndarray, y: float) -> LinearModel:
        self.t += 1
        lr = self.lr0 / (1 + self.lr0 * self.lam * self.t)
        z = float(f @ model.w - model.b)
        g = -y if y * z < 1 else 0.0
        w = model.w * (1 - lr * self.lam)
        if g:
            w = w - lr * g * f
        return LinearModel(w.astype(np.float32), float(model.b - lr * (-g)))


def warm_model(c: Corpus, sgd: BottouSGD, n: int = None, seed: int = 3):
    n = n or WARM_EXAMPLES
    stream = example_stream(c, seed=seed, label_noise=0.0)
    model = zero_model(c.features.shape[1])
    for _, f, y in (next(stream) for _ in range(n)):
        model = sgd.step(model, f, y)
    return model, stream


def rate(fn: Callable[[], int], min_seconds: float = 0.5) -> Tuple[float, int]:
    """Run fn (returns #ops) until min_seconds elapsed; return (ops/s, n)."""
    t0 = time.perf_counter()
    total = 0
    while time.perf_counter() - t0 < min_seconds:
        total += fn()
    return total / (time.perf_counter() - t0), total


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
