"""Paper Fig. 4(A): eager Update throughput (updates/s) — naive vs hazy vs
hybrid, per corpus. Warm model (12k examples), 3k update stream."""
from __future__ import annotations

import time


from benchmarks.common import BottouSGD, corpus, emit, warm_model
from repro.core import HazyEngine, NaiveEngine


def run_one(name: str, engine_kind: str, n_updates: int = 1000):
    c, (p, q) = corpus(name)
    sgd = BottouSGD()
    model, stream = warm_model(c, sgd)
    if engine_kind == "naive":
        eng = NaiveEngine(c.features, policy="eager")
    else:
        eng = HazyEngine(c.features, p=p, q=q, policy="eager",
                         buffer_frac=0.01 if engine_kind == "hybrid" else 0.0)
    eng.apply_model(model)
    if isinstance(eng, HazyEngine):
        eng.reorganize()
    updates = [next(stream) for _ in range(n_updates)]
    t0 = time.perf_counter()
    for _, f, y in updates:
        model = sgd.step(model, f, y)
        eng.apply_model(model)
    dt = time.perf_counter() - t0
    stats = ""
    if isinstance(eng, HazyEngine):
        assert eng.check_consistent()
        mb = eng.stats.tuples_reclassified / max(1, eng.stats.tuples_total_possible)
        stats = f"updates/s={n_updates/dt:.0f};reorgs={eng.stats.reorgs};mean_band={mb:.4f}"
    else:
        stats = f"updates/s={n_updates/dt:.0f}"
    emit(f"fig4a_eager_update_{engine_kind}_{name}", dt / n_updates * 1e6, stats)
    return n_updates / dt


def main():
    for name in ("FC", "DB", "CS"):
        naive = run_one(name, "naive", n_updates=300)
        hazy = run_one(name, "hazy")
        hybrid = run_one(name, "hybrid")
        emit(f"fig4a_speedup_{name}", 0.0,
             f"hazy/naive={hazy/naive:.1f}x;hybrid/naive={hybrid/naive:.1f}x")


if __name__ == "__main__":
    main()
