"""Paper Fig. 5: Single Entity read rate (reads/s) for eager+lazy x
{full-recompute ("od"), hybrid eps-map, materialized ("mm")}.
15k uniformly random entity reads against a warm model (paper §4.2)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BottouSGD, corpus, emit, warm_model
from repro.core import HazyEngine


def main():
    n_reads = 15_000
    for name in ("FC", "DB", "CS"):
        c, (p, q) = corpus(name)
        sgd = BottouSGD()
        model, stream = warm_model(c, sgd)
        eng = HazyEngine(c.features, p=p, q=q, policy="eager", buffer_frac=0.01)
        eng.apply_model(model)
        eng.reorganize()
        for _, f, y in (next(stream) for _ in range(50)):  # drift the band open
            model = sgd.step(model, f, y)
            eng.apply_model(model)
        r = np.random.default_rng(0)
        ids = r.integers(0, c.features.shape[0], n_reads)

        t0 = time.perf_counter()
        for i in ids:  # "od": recompute from the feature vector every read
            z = c.features[i] @ model.w - model.b
        dt_od = time.perf_counter() - t0

        t0 = time.perf_counter()
        hows = {"water": 0, "buffer": 0, "disk": 0}
        for i in ids:
            _, how = eng.hybrid_label(int(i))
            hows[how] += 1
        dt_hy = time.perf_counter() - t0

        t0 = time.perf_counter()
        for i in ids:  # "mm": materialized label lookup
            _ = eng.labels_sorted[eng.inv_perm[i]]
        dt_mm = time.perf_counter() - t0

        emit(f"fig5_single_entity_od_{name}", dt_od / n_reads * 1e6,
             f"reads/s={n_reads/dt_od:.0f}")
        emit(f"fig5_single_entity_hybrid_{name}", dt_hy / n_reads * 1e6,
             f"reads/s={n_reads/dt_hy:.0f};water={hows['water']};buffer={hows['buffer']};disk={hows['disk']}")
        emit(f"fig5_single_entity_mm_{name}", dt_mm / n_reads * 1e6,
             f"reads/s={n_reads/dt_mm:.0f};hybrid/mm={dt_mm/dt_hy:.2f}")


if __name__ == "__main__":
    main()
