"""Paper Fig. 10: learning overhead + quality. SGD (incremental, ours) vs a
full-batch subgradient solver (SVMLight stand-in), on FC/DB/CS clones:
train-time and precision/recall on a held-out 10%."""
from __future__ import annotations

import time


from benchmarks.common import corpus, emit
from repro.core import (full_gradient_train, precision_recall, train_batch,
                        zero_model)


def main():
    for name in ("FC", "DB", "CS"):
        c, _ = corpus(name)
        n = c.features.shape[0]
        split = int(n * 0.9)
        Ftr, Ytr = c.features[:split], c.labels[:split]
        Fte, Yte = c.features[split:], c.labels[split:]

        t0 = time.perf_counter()
        m_sgd = train_batch(zero_model(c.features.shape[1]), Ftr[:20000],
                            Ytr[:20000], lr=0.02, l2=1e-3, epochs=1)
        dt_sgd = time.perf_counter() - t0
        p1, r1 = precision_recall(m_sgd, Fte, Yte)

        t0 = time.perf_counter()
        m_fb = full_gradient_train(zero_model(c.features.shape[1]), Ftr[:20000],
                                   Ytr[:20000], lr=0.5, l2=1e-3, iters=100)
        dt_fb = time.perf_counter() - t0
        p2, r2 = precision_recall(m_fb, Fte, Yte)

        emit(f"fig10_sgd_{name}", dt_sgd * 1e6,
             f"P={p1:.3f};R={r1:.3f};seconds={dt_sgd:.2f}")
        emit(f"fig10_fullbatch_{name}", dt_fb * 1e6,
             f"P={p2:.3f};R={r2:.3f};seconds={dt_fb:.2f};sgd_speedup={dt_fb/dt_sgd:.1f}x")


if __name__ == "__main__":
    main()
