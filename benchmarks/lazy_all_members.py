"""Paper Fig. 4(B): lazy All Members throughput (scans/s) — naive vs hazy.
Pattern: one update then one All-Members read, repeatedly (the lazy
bottleneck is the read, which must catch the view up)."""
from __future__ import annotations

import time

from benchmarks.common import BottouSGD, corpus, emit, warm_model
from repro.core import HazyEngine, NaiveEngine


def run_one(name: str, engine_kind: str, n_reads: int = 200):
    c, (p, q) = corpus(name)
    sgd = BottouSGD()
    model, stream = warm_model(c, sgd)
    if engine_kind == "naive":
        eng = NaiveEngine(c.features, policy="lazy")
    else:
        eng = HazyEngine(c.features, p=p, q=q, policy="lazy")
    eng.apply_model(model)
    if isinstance(eng, HazyEngine):
        eng.reorganize()
    updates = [next(stream) for _ in range(n_reads)]
    t0 = time.perf_counter()
    count = 0
    for _, f, y in updates:
        model = sgd.step(model, f, y)
        eng.apply_model(model)
        count = eng.all_members()
    dt = time.perf_counter() - t0
    emit(f"fig4b_lazy_allmembers_{engine_kind}_{name}", dt / n_reads * 1e6,
         f"scans/s={n_reads/dt:.1f};members={count}")
    return n_reads / dt


def main():
    for name in ("FC", "DB", "CS"):
        naive = run_one(name, "naive", n_reads=60)
        hazy = run_one(name, "hazy")
        emit(f"fig4b_speedup_{name}", 0.0, f"hazy/naive={hazy/naive:.1f}x")


if __name__ == "__main__":
    main()
