"""Concurrent SQL serving: a closed-loop client swarm against the asyncio
wire-protocol server (`repro.rdbms.server`), the "many sessions, one
incrementally-maintained state" shape the server mode exists for.

Workload: N sessions (default 64; `BENCH_SERVE_SESSIONS`), each a real
socket connection with its own server-side prepared-statement cache,
issue `BENCH_SERVE_OPS` closed-loop operations at a 95/5 read/write mix
(`BENCH_SERVE_READ_FRAC`) over the cora_like corpus:

  * reads  — `EXECUTE pt (id, view)`: the prepared §3.5.2 point-probe
    route, snapshot-pinned under the shared epoch gate;
  * writes — single-row `INSERT`, queued in the group-commit WAL and
    committed behind the pinned readers (or flushed by the next read —
    read-your-writes).

Reported into `BENCH_serve.json`: per-op p50/p99 latency (ms, full wire
round trip) and aggregate QPS, plus the per-kind split and server/WAL
counters.  Gated by `check_regress.py` (p99 +30% machine-speed-
normalized, QPS as throughput).

Correctness (the acceptance contract): after the swarm, the server's WAL
history is replayed SERIALLY through a fresh REPL `Executor` — commit
markers reproduce the exact group boundaries — and every view's labels,
member sets, and commit count must be identical to the concurrently
served state.

Failure behavior: a server that cannot bind, or any session erroring
mid-run, raises — `run.py` exits non-zero and the CI serve-smoke job
goes red rather than uploading a partial JSON.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.data import cora_like
from repro.rdbms import Catalog, Executor, SqlClient, start_server_thread
from repro.scheduler import FreshnessScheduler

SESSIONS = int(os.environ.get("BENCH_SERVE_SESSIONS", "64"))
OPS = int(os.environ.get("BENCH_SERVE_OPS", "100"))          # per session
READ_FRAC = float(os.environ.get("BENCH_SERVE_READ_FRAC", "0.95"))
GROUP = int(os.environ.get("BENCH_SERVE_GROUP", "32"))
LAG = os.environ.get("BENCH_SERVE_LAG", "2 s")   # the secondary view's lag


def _build_catalog(corpus) -> Catalog:
    catalog = Catalog()
    catalog.register_table("papers", corpus.features, truth=corpus.classes,
                           num_classes=corpus.num_classes)
    # hybrid + a real memory budget: point reads exercise waters -> pinned
    # hot-buffer pages -> the (now thread-safe) BufferPool -> cold reads
    catalog.create_view("topics", "papers", "svm",
                        {"k": corpus.num_classes, "policy": "hybrid",
                         "buffer_frac": 0.02, "cost_mode": "modeled",
                         "memory_budget": 0.25})
    # a second, LAGGED view on the same table (ISSUE 10): its batches
    # queue in the freshness inbox and the background refresher drains
    # them mid-swarm under the exclusive gate — the p99 gate below now
    # also certifies serving stays healthy WITH the refresher running.
    catalog.create_view("audit", "papers", "svm",
                        {"k": corpus.num_classes, "policy": "eager",
                         "cost_mode": "modeled", "target_lag": LAG})
    return catalog


def _session_worker(idx: int, host: str, port: int, corpus,
                    lat: list, errors: list, barrier: threading.Barrier):
    n, k = corpus.features.shape[0], corpus.num_classes
    rng = np.random.default_rng(1000 + idx)
    # pre-draw the op stream so the timed loop is pure serve traffic
    kinds = rng.random(OPS) < READ_FRAC
    ids = rng.integers(0, n, size=OPS)
    views = rng.integers(0, k, size=OPS)
    reads, writes = [], []
    try:
        client = SqlClient.connect(host, port)
        client.prepare("pt",
                       "SELECT label FROM topics WHERE id = ? AND view = ?")
        barrier.wait(timeout=60)
        for j in range(OPS):
            i = int(ids[j])
            if kinds[j]:
                t0 = time.perf_counter()
                client.run_prepared("pt", [i, int(views[j])])
                reads.append(time.perf_counter() - t0)
            else:
                c = int(corpus.classes[i])
                t0 = time.perf_counter()
                client.run(
                    f"INSERT INTO papers (id, class) VALUES ({i}, {c})")
                writes.append(time.perf_counter() - t0)
        client.close()
        lat.append((reads, writes))
    except Exception as e:                   # noqa: BLE001 — re-raised by main
        errors.append((idx, e))
        try:
            barrier.abort()
        except Exception:
            pass


def _replay_serial(history, corpus) -> Executor:
    """The same stream, serially, through the plain REPL executor: commit
    markers reproduce the concurrent run's exact group boundaries."""
    ex = Executor(_build_catalog(corpus), group_commit=len(history) + 1)
    for rec in history:
        if rec.op == "commit":
            ex.execute_one("COMMIT")
        elif rec.op == "insert":
            ex.execute_one(f"INSERT INTO papers (id, class) VALUES "
                           f"({rec.entity_id}, {int(rec.label)})")
        else:
            raise RuntimeError(f"unexpected WAL op in serve workload: "
                               f"{rec.op}")
    return ex


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs) * 1e3, q)) if xs else 0.0


def main() -> None:
    corpus = cora_like(scale=BENCH_SCALE / 0.1)
    n, k = corpus.features.shape[0], corpus.num_classes
    ex = Executor(_build_catalog(corpus), group_commit=GROUP)
    handle = start_server_thread(ex, max_workers=min(32, SESSIONS))
    host, port = handle.address
    refresher = FreshnessScheduler(ex, interval=0.01)
    refresher.start()

    lat: list = []
    errors: list = []
    barrier = threading.Barrier(SESSIONS + 1)
    threads = [threading.Thread(target=_session_worker,
                                args=(i, host, port, corpus, lat, errors,
                                      barrier),
                                daemon=True)
               for i in range(SESSIONS)]
    for t in threads:
        t.start()
    try:
        barrier.wait(timeout=60)             # all sessions connected+prepared
    except threading.BrokenBarrierError:
        pass                                 # a worker failed; fall through
    t_wall = time.perf_counter()
    for t in threads:
        t.join(timeout=600)
    wall = time.perf_counter() - t_wall
    if errors:
        handle.stop()
        idx, err = errors[0]
        raise RuntimeError(
            f"{len(errors)}/{SESSIONS} serve sessions failed "
            f"(first: session {idx}: {type(err).__name__}: {err})") from err
    if any(t.is_alive() for t in threads):
        handle.stop()
        raise RuntimeError("serve swarm hung: sessions still alive after "
                           "600s join")

    # quiesce the refresher, then flush the uncommitted tail so the WAL
    # history is commit-terminated, and freeze it for the serial replay
    refresher.stop()
    ex.execute_one("COMMIT")

    # -- telemetry reconciliation over the wire (CI serve-smoke gate) ----
    # the unified registry must agree with itself after the swarm: the
    # epoch IS the WAL commit counter, every statement took the gate, and
    # the pool's probe ledger balances exactly.
    with SqlClient.connect(host, port) as mc:
        snap = mc.metrics()
    for key in ("counters", "gauges", "histograms", "wal", "view.topics",
                "epoch"):
        assert key in snap, f"metrics snapshot missing {key!r}"
    counters = snap["counters"]
    assert snap["epoch"] == snap["wal"]["commits"] == \
        counters["wal.commits"], (snap["epoch"], snap["wal"]["commits"],
                                  counters["wal.commits"])
    assert counters["gate.shared_acquisitions"] \
        + counters["gate.exclusive_acquisitions"] >= \
        counters["statements"], counters
    st_tel = snap["view.topics"].get("storage")
    if st_tel is not None:
        assert st_tel["hits"] + st_tel["misses"] + st_tel["coalesced"] == \
            st_tel["probes"], st_tel
    assert snap["histograms"]["statement.seconds"]["count"] == \
        counters["statements"], (
            snap["histograms"]["statement.seconds"]["count"],
            counters["statements"])     # quiesced: every statement timed

    handle.stop()
    history = list(ex.log.history)

    reads = [x for r, _ in lat for x in r]
    writes = [x for _, w in lat for x in w]
    all_lat = reads + writes
    total_ops = len(all_lat)
    qps = total_ops / wall if wall > 0 else 0.0

    # -- acceptance: concurrent == serial replay at the same boundaries --
    # one freshness barrier on each side first: whatever the refresher
    # already drained mid-swarm plus this catch-up must land the LAGGED
    # view on the same state as the serial replay's barrier (scheduling
    # moves maintenance in time, never changes what it computes).
    ex.refresh_views()
    serial = _replay_serial(history, corpus)
    serial.refresh_views()
    assert serial.log.commits == ex.log.commits, \
        (serial.log.commits, ex.log.commits)
    for name in ("topics", "audit"):
        f_conc = ex.catalog.view(name).facade
        f_ser = serial.catalog.view(name).facade
        assert np.array_equal(f_conc.counts(), f_ser.counts()), \
            (name, f_conc.counts(), f_ser.counts())
        for v in range(k):
            assert np.array_equal(np.sort(f_conc.members(v)),
                                  np.sort(f_ser.members(v))), (name, v)
    f_conc = ex.catalog.view("topics").facade

    payload = {
        "workload": {"corpus": corpus.name, "n": n,
                     "d": int(corpus.features.shape[1]), "k": k,
                     "sessions": SESSIONS, "ops_per_session": OPS,
                     "read_frac": READ_FRAC, "group_commit": GROUP,
                     "updates": len(writes), "reads": len(reads)},
        "scale": BENCH_SCALE,
        "latency_ms": {"p50": _pct(all_lat, 50), "p99": _pct(all_lat, 99),
                       "read_p50": _pct(reads, 50),
                       "read_p99": _pct(reads, 99),
                       "write_p50": _pct(writes, 50),
                       "write_p99": _pct(writes, 99)},
        "qps": qps,
        "wall_seconds": wall,
        "wal_commits": ex.log.commits,
        "epoch": ex.epoch,
        "server": {"sessions": handle.server.sessions_opened,
                   "statements": handle.server.statements_served},
        "refresher": {
            "lag": LAG,
            "ticks": refresher.ticks,
            "refreshes": ex.catalog.view("audit").runtime.refreshes,
            "rows_applied": ex.catalog.view("audit").runtime.rows_applied,
        },
        "hybrid_tier_hits": dict(f_conc.tier_hits),
        "storage": f_conc.storage_stats(),
        "telemetry": {
            "statements": counters["statements"],
            "errors": counters.get("statements.errors", 0),
            "gate_shared": counters["gate.shared_acquisitions"],
            "gate_exclusive": counters["gate.exclusive_acquisitions"],
            "wal_commits": counters["wal.commits"],
            "statement_p99_s":
                snap["histograms"]["statement.seconds"]["p99"],
        },
    }
    with open("BENCH_serve.json", "w") as f:
        json.dump(payload, f, indent=2)

    emit(f"serve_point_read_s{SESSIONS}_n{n}", _pct(reads, 50) * 1e3,
         f"p99_ms={_pct(reads, 99):.3f};qps={qps:.0f}")
    emit(f"serve_insert_s{SESSIONS}_n{n}", _pct(writes, 50) * 1e3,
         f"p99_ms={_pct(writes, 99):.3f};commits={ex.log.commits}")


if __name__ == "__main__":
    main()
