"""§3.5.2/Fig. 8 hybrid read path on the vectorized multi-view engine.

Mixed single-entity read/update traffic on the cora_like multiclass corpus
(k one-vs-all views over ONE shared table), one run per policy:

  * eager  — every update pays the banded reclassify; reads are plain
             eps-map label lookups (`labels_of`).
  * lazy   — updates defer; the first read of a round catches up (per-view
             pending mask).
  * hybrid — updates defer the relabel but keep the eps-map tight (SKIING
             on the probe miss rate); reads go waters short-circuit ->
             per-view hot buffer (PINNED pool pages) -> the buffer pool
             (`hybrid_labels_of`), which serves a probe miss from a
             resident page ("pool") or pays a real cold page read from the
             memory-mapped entity store ("disk").

Earlier revisions emulated the storage tier with a synthetic 2 µs/tuple
charge; the hybrid run now carries a REAL `repro.storage` buffer pool
under BENCH_STORAGE_BUDGET (default 10% of the entity table's bytes), so
the tier fractions and the read-path latency are measured against actual
page residency — no arithmetic storage emulation anywhere. The read-path
latency — maintenance plus reads, amortized per read — is the number the
comparison is about. With the table genuinely in RAM for eager/lazy, the
paper's disk-resident eager-vs-hybrid contest moves to
``BENCH_storage.json`` (budgeted pool vs all-in-RAM on the SAME policy);
here the deferred-maintenance twins are compared like-for-like: hybrid's
tiered read path must beat lazy's catch-up read path. Emits
``BENCH_hybrid.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.core import MulticlassView
from repro.core.engine import PROBE_TIERS
from repro.data import cora_like, multiclass_example_stream
from repro.storage import BufferPool, EntityStore

BATCH = int(os.environ.get("BENCH_HYBRID_BATCH", "16"))
READS_PER_ROUND = int(os.environ.get("BENCH_HYBRID_READS", "12"))
BUFFER_FRAC = float(os.environ.get("BENCH_HYBRID_BUFFER", "0.05"))
MEMORY_BUDGET = float(os.environ.get("BENCH_STORAGE_BUDGET", "0.10"))


def _workload():
    corpus = cora_like(scale=BENCH_SCALE / 0.1)
    n = corpus.features.shape[0]
    n_updates = max(160, int(2000 * (BENCH_SCALE / 0.1)))
    stream = multiclass_example_stream(corpus, seed=13)
    inserts = [next(stream) for _ in range(n_updates)]
    r = np.random.default_rng(17)
    rounds = []
    for j in range(0, len(inserts), BATCH):
        reads = r.integers(0, n, READS_PER_ROUND)
        rounds.append((inserts[j:j + BATCH], reads))
    return corpus, rounds


def _run(corpus, rounds, policy: str):
    pool = None
    if policy == "hybrid":
        # the REAL storage tier: mmap'd entity store + budgeted pool
        store = EntityStore.from_array(corpus.features)
        pool = BufferPool(store, max(1, int(MEMORY_BUDGET
                                            * corpus.features.nbytes)))
    view = MulticlassView(corpus.features, corpus.num_classes, policy=policy,
                          buffer_frac=BUFFER_FRAC, p=2.0, q=2.0, lr=0.1,
                          cost_mode="measured", store=pool)
    eng = view.engine
    read_s = 0.0
    n_reads = 0
    for chunk, reads in rounds:
        view.insert_examples([i for i, _ in chunk], [c for _, c in chunk])
        t0 = time.perf_counter()
        if policy == "hybrid":
            for i in reads:
                eng.hybrid_labels_of(int(i))
        else:
            for i in reads:
                eng.labels_of(int(i))
        read_s += time.perf_counter() - t0
        n_reads += len(reads)
    # maintenance as the engine's own accounting charges it (wall time;
    # for hybrid this includes the real pool re-warms at reorganization)
    maint_s = eng.stats.incremental_seconds + eng.stats.reorg_seconds
    # snapshot tier counters BEFORE the verification probes below, so the
    # reported fractions describe only the timed workload
    hits = eng.hybrid_hits.copy()
    pool_stats = pool.stats() if pool is not None else None
    # exactness: whatever the policy deferred, reads must be (and stay)
    # exact w.r.t. the current model
    truth = np.where(corpus.features @ view.W.T
                     - view.b.astype(np.float32) >= 0, 1, -1)
    for i in range(0, corpus.features.shape[0], 29):
        probe = (eng.hybrid_labels_of(i)[0] if policy == "hybrid"
                 else eng.labels_of(i))
        assert np.array_equal(probe, truth[i]), (policy, i)
    return view, hits, pool_stats, maint_s, read_s, n_reads


def main() -> None:
    corpus, rounds = _workload()
    n = corpus.features.shape[0]
    k = corpus.num_classes
    results = {}
    for policy in ("eager", "lazy", "hybrid"):
        view, hits, pool_stats, maint_s, read_s, n_reads = _run(
            corpus, rounds, policy)
        read_us = read_s / n_reads * 1e6
        path_us = (maint_s + read_s) / n_reads * 1e6
        results[policy] = {"read_us": read_us, "read_path_us": path_us,
                           "maintenance_seconds": maint_s,
                           "read_seconds": read_s, "n_reads": n_reads,
                           "reorgs": int(view.engine.stats.reorgs)}
        extra = ""
        if policy == "hybrid":
            frac = hits.astype(float) / max(1.0, float(hits.sum()))
            results[policy]["tier_hits"] = {
                t: int(h) for t, h in zip(PROBE_TIERS, hits)}
            results[policy]["tier_fractions"] = {
                t: float(f) for t, f in zip(PROBE_TIERS, frac)}
            results[policy]["storage"] = pool_stats
            extra = (f"water={frac[0]:.3f};buffer={frac[1]:.3f};"
                     f"pool={frac[3]:.3f};disk={frac[2]:.3f}")
        emit(f"hybrid_readpath_{policy}_k{k}_n{n}", path_us,
             f"read_us={read_us:.2f};{extra}")

    hyb, eag, laz = results["hybrid"], results["eager"], results["lazy"]
    fr = hyb["tier_fractions"]
    wb = fr["water"] + fr["buffer"]
    non_disk = 1.0 - fr["disk"]
    payload = {
        "workload": {"corpus": corpus.name, "n": n,
                     "d": int(corpus.features.shape[1]), "k": k,
                     "updates": sum(len(c) for c, _ in rounds),
                     "reads": hyb["n_reads"], "batch": BATCH,
                     "buffer_frac": BUFFER_FRAC,
                     "memory_budget": MEMORY_BUDGET},
        "policies": results,
        "hybrid_water_buffer_fraction": wb,
        "hybrid_non_disk_fraction": non_disk,
        "hybrid_majority_in_memory": non_disk > 0.5,
        "read_path_speedup_vs_eager":
            eag["read_path_us"] / hyb["read_path_us"],
        "read_path_speedup_vs_lazy":
            laz["read_path_us"] / hyb["read_path_us"],
    }
    with open("BENCH_hybrid.json", "w") as f:
        json.dump(payload, f, indent=2)
    assert non_disk > 0.5, \
        f"hybrid tier paid cold disk reads on {1 - non_disk:.2%} of probes"
    # at toy scale (CI smoke) maintenance is too cheap for the read-path
    # comparison to be meaningful; gate it on a real-sized corpus. The
    # like-for-like contest is vs LAZY (the other deferring policy):
    # hybrid's tiered point read must beat lazy's catch-up point read.
    if n >= 1000:
        assert hyb["read_path_us"] < laz["read_path_us"], \
            (hyb["read_path_us"], laz["read_path_us"])


if __name__ == "__main__":
    main()
