"""§3.5.2/Fig. 8 hybrid read path on the vectorized multi-view engine.

Mixed single-entity read/update traffic on the cora_like multiclass corpus
(k one-vs-all views over ONE shared table), one run per policy:

  * eager  — every update pays the banded reclassify; reads are plain
             eps-map label lookups (`labels_of`).
  * lazy   — updates defer; the first read of a round catches up (per-view
             pending mask).
  * hybrid — updates defer the relabel but keep the eps-map tight (SKIING
             on the probe miss rate); reads go waters short-circuit ->
             per-view hot buffer -> one shared "disk" feature-row touch
             (`hybrid_labels_of`).

The paper's architecture stores the table on disk, so `touch_ns`
(BENCH_HYBRID_TOUCH_NS, default 2000 = 2 µs/tuple) emulates the storage
tier exactly as the engines' cost accounting defines it: maintenance is
charged per tuple touched (bands + reorganizations, via
`stats.incremental_seconds`/`reorg_seconds`), hybrid disk probes pay one
touch per read that misses the in-memory tiers (charged arithmetically
from the engine's `disk_touches` counter). The read-path latency —
maintenance plus reads, amortized per read — is the number the paper's
eager-vs-hybrid comparison is about; pure in-memory read wall time is
reported alongside. Emits machine-readable ``BENCH_hybrid.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.core import MulticlassView
from repro.core.multiview import HYBRID_TIERS
from repro.data import cora_like, multiclass_example_stream

BATCH = int(os.environ.get("BENCH_HYBRID_BATCH", "16"))
READS_PER_ROUND = int(os.environ.get("BENCH_HYBRID_READS", "12"))
BUFFER_FRAC = float(os.environ.get("BENCH_HYBRID_BUFFER", "0.05"))
TOUCH_NS = float(os.environ.get("BENCH_HYBRID_TOUCH_NS", "2000"))


def _workload():
    corpus = cora_like(scale=BENCH_SCALE / 0.1)
    n = corpus.features.shape[0]
    n_updates = max(160, int(2000 * (BENCH_SCALE / 0.1)))
    stream = multiclass_example_stream(corpus, seed=13)
    inserts = [next(stream) for _ in range(n_updates)]
    r = np.random.default_rng(17)
    rounds = []
    for j in range(0, len(inserts), BATCH):
        reads = r.integers(0, n, READS_PER_ROUND)
        rounds.append((inserts[j:j + BATCH], reads))
    return corpus, rounds


def _run(corpus, rounds, policy: str):
    view = MulticlassView(corpus.features, corpus.num_classes, policy=policy,
                          buffer_frac=BUFFER_FRAC, p=2.0, q=2.0, lr=0.1,
                          cost_mode="measured", touch_ns=TOUCH_NS)
    eng = view.engine
    read_s = 0.0
    n_reads = 0
    for chunk, reads in rounds:
        view.insert_examples([i for i, _ in chunk], [c for _, c in chunk])
        t0 = time.perf_counter()
        if policy == "hybrid":
            for i in reads:
                eng.hybrid_labels_of(int(i))
        else:
            for i in reads:
                eng.labels_of(int(i))
        read_s += time.perf_counter() - t0
        n_reads += len(reads)
    # maintenance as the engine's own storage-aware accounting charges it
    maint_s = eng.stats.incremental_seconds + eng.stats.reorg_seconds
    # disk probes are charged arithmetically (sleep granularity ~100us would
    # swamp a per-row touch), exactly like the maintenance accounting
    read_s += eng.disk_touches * TOUCH_NS * 1e-9
    # snapshot tier counters BEFORE the verification probes below, so the
    # reported fractions describe only the timed workload
    hits = eng.hybrid_hits.copy()
    # exactness: whatever the policy deferred, reads must be (and stay)
    # exact w.r.t. the current model
    truth = np.where(corpus.features @ view.W.T
                     - view.b.astype(np.float32) >= 0, 1, -1)
    for i in range(0, corpus.features.shape[0], 29):
        probe = (eng.hybrid_labels_of(i)[0] if policy == "hybrid"
                 else eng.labels_of(i))
        assert np.array_equal(probe, truth[i]), (policy, i)
    return view, hits, maint_s, read_s, n_reads


def main() -> None:
    corpus, rounds = _workload()
    n = corpus.features.shape[0]
    k = corpus.num_classes
    results = {}
    for policy in ("eager", "lazy", "hybrid"):
        view, hits, maint_s, read_s, n_reads = _run(corpus, rounds, policy)
        read_us = read_s / n_reads * 1e6
        path_us = (maint_s + read_s) / n_reads * 1e6
        results[policy] = {"read_us": read_us, "read_path_us": path_us,
                           "maintenance_seconds": maint_s,
                           "read_seconds": read_s, "n_reads": n_reads,
                           "reorgs": int(view.engine.stats.reorgs)}
        extra = ""
        if policy == "hybrid":
            frac = hits.astype(float) / max(1.0, float(hits.sum()))
            results[policy]["tier_hits"] = {
                t: int(h) for t, h in zip(HYBRID_TIERS, hits)}
            results[policy]["tier_fractions"] = {
                t: float(f) for t, f in zip(HYBRID_TIERS, frac)}
            extra = (f"water={frac[0]:.3f};buffer={frac[1]:.3f};"
                     f"disk={frac[2]:.3f}")
        emit(f"hybrid_readpath_{policy}_k{k}_n{n}", path_us,
             f"read_us={read_us:.2f};{extra}")

    hyb, eag = results["hybrid"], results["eager"]
    wb = (hyb["tier_fractions"]["water"] + hyb["tier_fractions"]["buffer"])
    payload = {
        "workload": {"corpus": corpus.name, "n": n,
                     "d": int(corpus.features.shape[1]), "k": k,
                     "updates": sum(len(c) for c, _ in rounds),
                     "reads": hyb["n_reads"], "batch": BATCH,
                     "buffer_frac": BUFFER_FRAC, "touch_ns": TOUCH_NS},
        "policies": results,
        "hybrid_water_buffer_fraction": wb,
        "hybrid_majority_in_memory": wb > 0.5,
        "read_path_speedup_vs_eager":
            eag["read_path_us"] / hyb["read_path_us"],
    }
    with open("BENCH_hybrid.json", "w") as f:
        json.dump(payload, f, indent=2)
    assert wb > 0.5, f"hybrid tier resolved only {wb:.2%} without disk"
    # at toy scale (CI smoke) maintenance is too cheap for the read-path
    # comparison to be meaningful; gate it on a real-sized corpus
    if n >= 1000:
        assert hyb["read_path_us"] < eag["read_path_us"], \
            (hyb["read_path_us"], eag["read_path_us"])


if __name__ == "__main__":
    main()
