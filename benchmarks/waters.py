"""Paper Fig. 13: fraction of tuples between low and high water over a
12k-example warm stream + steady-state updates — the paper observes ~1%."""
from __future__ import annotations

import numpy as np

from benchmarks.common import BottouSGD, corpus, emit
from repro.core import HazyEngine, zero_model
from repro.data import example_stream


def main():
    for name in ("FC", "DB"):
        c, (p, q) = corpus(name)
        sgd = BottouSGD()
        stream = example_stream(c, seed=3, label_noise=0.0)
        model = zero_model(c.features.shape[1])
        eng = HazyEngine(c.features, p=p, q=q, policy="eager")
        fracs = []
        for i, (_, f, y) in enumerate(next(stream) for _ in range(12_000)):
            model = sgd.step(model, f, y)
            if i % 50 == 0:
                eng.apply_model(model)
                fracs.append(eng.band_fraction())
        steady = float(np.mean(fracs[-40:]))
        emit(f"fig13_waters_{name}", 0.0,
             f"steady_band={steady:.4f};max_band={max(fracs):.4f};"
             f"reorgs={eng.stats.reorgs}")


if __name__ == "__main__":
    main()
