"""SQL front-end overhead: statements/sec vs direct engine calls.

Two identical hybrid multi-view stacks over the cora_like corpus — one
driven through the relational front-end (parse -> plan -> WAL -> facade),
one through direct `MulticlassView`/`MultiViewEngine` calls — receive the
same workload:

  * group-committed INSERT batches (one multi-row statement per commit ==
    one `insert_examples` engine round on the direct side)
  * point SELECTs (§3.5.2 probe) vs `hybrid_label`
  * band scans (`WHERE class = c`) vs `members(view)`
  * COUNT(*) vs `all_members()`

The front-end overhead (SQL time / direct time) is REPORTED per path, not
hidden — parsing and planning run inside the timed loops. Both sides use
cost_mode=modeled so the SKIING maintenance schedule is identical and the
comparison measures routing overhead only. Timing is PAIRED (each
operation's two sides measured back-to-back in one loop) and each phase
reports the median of `BENCH_SQL_REPS` repetitions, so scheduler noise
mostly cancels out of the ratio. Emits `BENCH_sql.json`; the batched-insert
overhead must stay ≤ 2x (ISSUE 4 acceptance), and the PREPARE/EXECUTE
point-read path must beat the raw point SELECT's overhead (ISSUE 5: the
cached plan route amortizes parse+plan across repeated reads).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import BENCH_SCALE, emit
from repro.core import MulticlassView
from repro.data import cora_like
from repro.rdbms import Catalog, Executor

BATCH = int(os.environ.get("BENCH_SQL_BATCH", "32"))
INSERT_BATCHES = int(os.environ.get("BENCH_SQL_INSERTS", "40"))
POINT_READS = int(os.environ.get("BENCH_SQL_READS", "400"))
SCANS = int(os.environ.get("BENCH_SQL_SCANS", "60"))
REPS = int(os.environ.get("BENCH_SQL_REPS", "3"))


def _build(corpus):
    opts = dict(policy="hybrid", p=2.0, q=2.0, lr=0.1, l2=1e-4,
                buffer_frac=0.02, cost_mode="modeled")
    catalog = Catalog()
    catalog.register_table("papers", corpus.features, truth=corpus.classes,
                           num_classes=corpus.num_classes)
    catalog.create_view("topics", "papers", "svm",
                        {"k": corpus.num_classes, **opts})
    ex = Executor(catalog, group_commit=BATCH)
    direct = MulticlassView(corpus.features, corpus.num_classes,
                            vectorized=True, **opts)
    return ex, catalog.view("topics").facade, direct


def _paired(ex, pairs):
    """Time (sql statement, direct thunk) pairs back-to-back; returns the
    two per-pair wall-time lists."""
    sql_t, dir_t = [], []
    for stmt, thunk in pairs:
        t0 = time.perf_counter()
        ex.execute_one(stmt)
        t1 = time.perf_counter()
        thunk()
        t2 = time.perf_counter()
        sql_t.append(t1 - t0)
        dir_t.append(t2 - t1)
    return sql_t, dir_t


def _overhead(sql_t, dir_t):
    """Median of the per-pair ratios: each ratio compares two adjacent
    operations in the same scheduling window, so a machine-load spike
    poisons one pair, not the whole phase — far more stable than the
    ratio of summed times on a noisy host."""
    r = np.asarray(sql_t) / np.maximum(np.asarray(dir_t), 1e-12)
    return float(np.median(r))


def main() -> None:
    corpus = cora_like(scale=BENCH_SCALE / 0.1)
    n, k = corpus.features.shape[0], corpus.num_classes
    rng = np.random.default_rng(29)
    inserts = [[(int(rng.integers(0, n)),) for _ in range(BATCH)]
               for _ in range(INSERT_BATCHES)]
    inserts = [[(i, int(corpus.classes[i])) for (i,) in batch]
               for batch in inserts]
    reads = [(int(rng.integers(0, n)), int(rng.integers(0, k)))
             for _ in range(POINT_READS)]
    scans = [int(rng.integers(0, k)) for _ in range(SCANS)]
    results = {}

    # -- group-committed INSERT batches: pairs pooled over REPS fresh
    # stack pairs (each rep replays the identical stream on fresh engines)
    ins_sql, ins_dir = [], []
    for _ in range(REPS):
        ex, facade, direct = _build(corpus)
        sql_t, dir_t = _paired(ex, [
            ("INSERT INTO papers (id, class) VALUES "
             + ", ".join(f"({i}, {c})" for i, c in batch),
             lambda batch=batch: direct.insert_examples(
                 [i for i, _ in batch], [c for _, c in batch]))
            for batch in inserts])
        ins_sql.extend(sql_t)
        ins_dir.extend(dir_t)
    sql_s, dir_s = sum(ins_sql) / REPS, sum(ins_dir) / REPS
    rows = INSERT_BATCHES * BATCH
    results["insert"] = {
        "sql_rows_per_s": rows / sql_s, "direct_rows_per_s": rows / dir_s,
        "sql_stmt_per_s": INSERT_BATCHES / sql_s,
        "overhead_x": _overhead(ins_sql, ins_dir),
        "rows": rows, "batch": BATCH, "reps": REPS}
    emit(f"sql_insert_batched_k{k}_n{n}", sql_s / rows * 1e6,
         f"direct_us={dir_s / rows * 1e6:.2f};"
         f"overhead={results['insert']['overhead_x']:.2f}x")

    # read phases run on the last (warm, identical) stack pair; reads are
    # idempotent, so repeating them and pooling the pairs is sound
    def pooled(pairs):
        sql_t, dir_t = [], []
        for _ in range(REPS):
            s, d = _paired(ex, pairs)
            sql_t.extend(s)
            dir_t.extend(d)
        return sum(sql_t) / REPS, sum(dir_t) / REPS, _overhead(sql_t, dir_t)

    # -- point SELECTs (§3.5.2 probe path) -----------------------------
    sql_s, dir_s, over = pooled(
        [(f"SELECT label FROM topics WHERE id = {i} AND view = {v}",
          lambda i=i, v=v: direct.engine.hybrid_label(v, i))
         for i, v in reads])
    results["point_select"] = {
        "sql_stmt_per_s": POINT_READS / sql_s,
        "direct_calls_per_s": POINT_READS / dir_s,
        "overhead_x": over, "reads": POINT_READS}
    emit(f"sql_point_select_k{k}_n{n}", sql_s / POINT_READS * 1e6,
         f"direct_us={dir_s / POINT_READS * 1e6:.2f};overhead={over:.2f}x")

    # -- prepared point SELECTs (PREPARE once, EXECUTE per read) -------
    # the EXECUTE path binds into the CACHED plan route: repeated point
    # reads skip the SELECT parse AND the planner entirely, which is most
    # of the front-end overhead the raw point SELECT pays
    ex.execute_one(
        "PREPARE pt AS SELECT label FROM topics WHERE id = ? AND view = ?")
    sql_s, dir_s, over = pooled(
        [(f"EXECUTE pt ({i}, {v})",
          lambda i=i, v=v: direct.engine.hybrid_label(v, i))
         for i, v in reads])
    results["prepared_point"] = {
        "sql_stmt_per_s": POINT_READS / sql_s,
        "direct_calls_per_s": POINT_READS / dir_s,
        "overhead_x": over, "reads": POINT_READS}
    emit(f"sql_prepared_point_k{k}_n{n}", sql_s / POINT_READS * 1e6,
         f"direct_us={dir_s / POINT_READS * 1e6:.2f};overhead={over:.2f}x")

    # -- band scans (label-predicate membership) -----------------------
    sql_s, dir_s, over = pooled(
        [(f"SELECT id FROM topics WHERE class = {c}",
          lambda c=c: direct.engine.members(c)) for c in scans])
    results["band_scan"] = {
        "sql_stmt_per_s": SCANS / sql_s, "direct_calls_per_s": SCANS / dir_s,
        "overhead_x": over, "scans": SCANS}
    emit(f"sql_band_scan_k{k}_n{n}", sql_s / SCANS * 1e6,
         f"direct_us={dir_s / SCANS * 1e6:.2f};overhead={over:.2f}x")

    # -- counter reads -------------------------------------------------
    sql_s, dir_s, over = pooled(
        [(f"SELECT count(*) FROM topics WHERE class = {c}",
          lambda: direct.engine.all_members()) for c in scans])
    results["count"] = {
        "sql_stmt_per_s": SCANS / sql_s, "direct_calls_per_s": SCANS / dir_s,
        "overhead_x": over}
    emit(f"sql_count_k{k}_n{n}", sql_s / SCANS * 1e6,
         f"overhead={over:.2f}x")

    payload = {
        "workload": {"corpus": corpus.name, "n": n, "d":
                     int(corpus.features.shape[1]), "k": k,
                     "group_commit": BATCH,
                     "insert_batches": INSERT_BATCHES,
                     "point_reads": POINT_READS, "scans": SCANS,
                     "reps": REPS},
        "paths": results,
        "wal_commits": ex.log.commits,
        "hybrid_tier_hits": dict(facade.tier_hits),
        "disk_touches": facade.disk_touches,
    }
    with open("BENCH_sql.json", "w") as f:
        json.dump(payload, f, indent=2)

    # sanity: the two stacks saw identical streams and must agree exactly
    assert np.array_equal(facade.counts(), direct.engine.all_members())
    # acceptance: batched-insert front-end overhead stays ≤ 2x direct
    assert results["insert"]["overhead_x"] <= 2.0, results["insert"]
    # acceptance (ISSUE 5): PREPARE/EXECUTE amortizes parse+plan — the
    # prepared point-read overhead must beat the raw SELECT's
    assert (results["prepared_point"]["overhead_x"]
            < results["point_select"]["overhead_x"]), \
        (results["prepared_point"], results["point_select"])


if __name__ == "__main__":
    main()
