"""Framework table: Pallas kernel tile-level accounting. On CPU we can't
time TPU kernels; we report (a) interpret-mode correctness deltas vs ref
and (b) the analytic bytes/FLOPs per tile that the BlockSpecs commit to —
the quantities the §Roofline compute/memory terms are built from."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def main():
    r = np.random.default_rng(0)

    # eps_affine: bytes/row = 2*d (bf16 features) + 4 (eps) + 1 (label)
    from repro.kernels.eps_affine.ops import eps_affine
    from repro.kernels.eps_affine.ref import eps_affine_ref
    n, d = 4096, 512
    F = jnp.asarray(r.normal(size=(n, d)), jnp.bfloat16)
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    b = jnp.float32(0.1)
    t0 = time.perf_counter()
    eps, lab, cnt = eps_affine(F, w, b, block_n=512, interpret=True)
    dt = time.perf_counter() - t0
    e_r, l_r, c_r = eps_affine_ref(F, w, b)
    err = float(jnp.max(jnp.abs(eps - e_r)))
    emit("kernel_eps_affine", dt * 1e6,
         f"max_err={err:.2e};bytes_per_row={2*d+5};flops_per_row={2*d}")

    # band_reclassify: HBM traffic ∝ cap rows, not n
    from repro.kernels.band_reclassify.ops import band_reclassify
    n, d, cap = 16384, 512, 2048
    F = jnp.asarray(np.sort(r.normal(size=(n, d)), axis=0), jnp.bfloat16)
    labels = jnp.asarray(r.integers(0, 2, n) * 2 - 1, jnp.int8)
    t0 = time.perf_counter()
    out = band_reclassify(F, labels, w, 0.0, 7000, 8500, cap=cap, block_n=512,
                          interpret=True)
    dt = time.perf_counter() - t0
    emit("kernel_band_reclassify", dt * 1e6,
         f"touched_rows={cap};total_rows={n};traffic_ratio={cap/n:.3f}")

    # flash attention: causal block-skip => ~N^2/2 of full rectangle
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b_, s, nq, nkv, hd = 1, 512, 4, 2, 64
    q = jnp.asarray(r.normal(size=(b_, s, nq, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b_, s, nkv, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b_, s, nkv, hd)), jnp.float32)
    t0 = time.perf_counter()
    o = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    dt = time.perf_counter() - t0
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(o - ref)))
    nb = s // 128
    visited = nb * (nb + 1) // 2
    emit("kernel_flash_attention", dt * 1e6,
         f"max_err={err:.2e};blocks_visited={visited};blocks_full={nb*nb};"
         f"flop_frac={visited/(nb*nb):.2f}")

    # decode attention
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    S = 4096
    q1 = jnp.asarray(r.normal(size=(1, 1, 8, 64)), jnp.float32)
    K = jnp.asarray(r.normal(size=(1, S, 2, 64)), jnp.float32)
    V = jnp.asarray(r.normal(size=(1, S, 2, 64)), jnp.float32)
    t0 = time.perf_counter()
    o = decode_attention(q1, K, V, S - 1, block_s=512, interpret=True)
    dt = time.perf_counter() - t0
    ref = decode_attention_ref(q1[:, 0].reshape(1, 2, 4, 64), K, V, S - 1)
    err = float(jnp.max(jnp.abs(o.reshape(1, 2, 4, 64) - ref)))
    emit("kernel_decode_attention", dt * 1e6,
         f"max_err={err:.2e};kv_bytes={S*2*64*2*K.dtype.itemsize}")

    # wkv6: state stays VMEM-resident across the chunk grid
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref
    b2, s2, H2, K2 = 1, 256, 2, 32
    rr = jnp.asarray(r.normal(size=(b2, s2, H2, K2)), jnp.float32)
    kk = jnp.asarray(r.normal(size=(b2, s2, H2, K2)), jnp.float32)
    vv = jnp.asarray(r.normal(size=(b2, s2, H2, K2)), jnp.float32)
    la = -jnp.exp(jnp.asarray(r.normal(size=(b2, s2, H2, K2)) * 0.5 - 2.0, jnp.float32))
    u2 = jnp.asarray(r.normal(size=(H2, K2)), jnp.float32)
    t0 = time.perf_counter()
    o = wkv6(rr, kk, vv, la, u2, chunk=64, interpret=True)
    dt = time.perf_counter() - t0
    tr = lambda t: t.transpose(0, 2, 1, 3)
    ref = wkv6_ref(tr(rr), tr(kk), tr(vv), tr(la), u2).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(o - ref)))
    emit("kernel_wkv6", dt * 1e6,
         f"max_err={err:.2e};state_bytes_hbm=0;per_token_bytes={4*K2*4}")


if __name__ == "__main__":
    main()
