"""Multiclass classification views (paper App. B.5.4 / C.3): one-vs-all
binary HAZY views over a multi-topic corpus — maintained by the vectorized
multi-view engine (ONE shared feature table, stacked (k, d) models, union
eps-band reclassified with one matmul) — plus the random-feature
linearized kernel (App. B.5.3). The seed's per-class Python loop is run on
the same stream for comparison.

Run:  PYTHONPATH=src python examples/multiclass_topics.py
"""
import time

import numpy as np

from repro.core import MulticlassView, RandomFeatures


def main():
    r = np.random.default_rng(0)
    k, n, d = 6, 30_000, 32
    print(f"{n} documents, {k} topics, {d} raw features")
    centers = r.normal(size=(k, d)).astype(np.float32) * 2.5
    cls = r.integers(0, k, n)
    X = centers[cls] + r.normal(size=(n, d)).astype(np.float32)
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)

    # linearized Gaussian kernel (Rahimi–Recht): kernel SVM as a linear view
    rf = RandomFeatures(d, 256, sigma=1.0, seed=1)
    F = rf(X)
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)

    n_updates, batch = 3000, 32
    ids = r.integers(0, n, n_updates)

    mv = MulticlassView(F, k, policy="eager", lr=0.1, p=2.0, q=2.0)
    t0 = time.perf_counter()
    for j in range(0, n_updates, batch):
        chunk = ids[j:j + batch]
        mv.insert_examples(chunk, cls[chunk])
    dt = time.perf_counter() - t0
    print(f"{n_updates} multiclass updates in {dt:.1f}s "
          f"({n_updates/dt:.0f} updates/s across {k} views, batch={batch}, "
          f"one shared table)")
    eng = mv.engine
    for c, (count, reorgs, frac) in enumerate(zip(
            mv.class_counts(), eng.reorg_counts, eng.band_fractions())):
        print(f"  class {c}: {count} members, {reorgs} reorgs, band {frac:.4f}")

    legacy = MulticlassView(F, k, policy="eager", lr=0.1, p=2.0, q=2.0,
                            vectorized=False)
    t0 = time.perf_counter()
    for i in ids[:500]:
        legacy.insert_example(int(i), int(cls[i]))
    per = (time.perf_counter() - t0) / 500
    print(f"seed per-class loop: {per*1e6:.0f} us/update "
          f"({dt/n_updates*1e6:.0f} us/update vectorized batched, "
          f"{per*n_updates/dt:.1f}x speedup)")

    sample = np.arange(0, n, 37)
    acc = np.mean(mv.predict_batch(sample) == cls[sample])
    print(f"one-vs-all accuracy (random-feature kernel): {acc:.3f}")

    # §3.5.2 hybrid read tier: single-entity reads resolved per view by
    # waters short-circuit -> hot buffer -> one shared feature-row touch,
    # with maintenance deferred per view until a read needs it.
    hyb = MulticlassView(F, k, policy="hybrid", buffer_frac=0.05, lr=0.1,
                         p=2.0, q=2.0)
    for j in range(0, n_updates, batch):
        chunk = ids[j:j + batch]
        hyb.insert_examples(chunk, cls[chunk])
    t0 = time.perf_counter()
    via_views = [hyb.predict_via_views(int(i)) for i in sample]
    dt = time.perf_counter() - t0
    hits = hyb.engine.hybrid_hits.copy()
    agree = sum(p == hyb.predict(int(i)) for p, i in zip(via_views, sample))
    frac = hits / max(1, hits.sum())
    print(f"hybrid single-entity reads: {len(sample)/dt:.0f} reads/s, "
          f"tiers water/buffer/disk = {frac[0]:.3f}/{frac[1]:.3f}/{frac[2]:.3f}, "
          f"predict_via_views agrees on {agree}/{len(sample)}")


if __name__ == "__main__":
    main()
