"""Multiclass classification views (paper App. B.5.4 / C.3): one-vs-all
binary HAZY views over a multi-topic corpus, with per-class incremental
maintenance — plus the random-feature linearized kernel (App. B.5.3).

Run:  PYTHONPATH=src python examples/multiclass_topics.py
"""
import time

import numpy as np

from repro.core import MulticlassView, RandomFeatures


def main():
    r = np.random.default_rng(0)
    k, n, d = 6, 30_000, 32
    print(f"{n} documents, {k} topics, {d} raw features")
    centers = r.normal(size=(k, d)).astype(np.float32) * 2.5
    cls = r.integers(0, k, n)
    X = centers[cls] + r.normal(size=(n, d)).astype(np.float32)
    X /= np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-9)

    # linearized Gaussian kernel (Rahimi–Recht): kernel SVM as a linear view
    rf = RandomFeatures(d, 256, sigma=1.0, seed=1)
    F = rf(X)
    F /= np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)

    mv = MulticlassView(F, k, policy="eager", lr=0.1, p=2.0, q=2.0)
    t0 = time.perf_counter()
    n_updates = 3000
    for i in r.integers(0, n, n_updates):
        mv.insert_example(int(i), int(cls[i]))
    dt = time.perf_counter() - t0
    print(f"{n_updates} multiclass updates in {dt:.1f}s "
          f"({n_updates/dt:.0f} updates/s across {k} views)")
    for c, (eng, count) in enumerate(zip(mv.engines, mv.class_counts())):
        print(f"  class {c}: {count} members, {eng.skiing.reorgs} reorgs, "
              f"band {eng.band_fraction():.4f}")
    sample = range(0, n, 37)
    acc = np.mean([mv.predict(i) == cls[i] for i in sample])
    print(f"one-vs-all accuracy (random-feature kernel): {acc:.3f}")


if __name__ == "__main__":
    main()
