"""Train an assigned-architecture LM on the synthetic Markov token stream,
with checkpointing + fault-tolerant resume — the training-side end-to-end
driver. Presets:

  tiny  (default): reduced tinyllama twin, CPU-friendly (~1 min)
  100m           : 12-layer d=768 llama-style (~100M params) — the spec's
                   "train ~100M model for a few hundred steps" run; slow on
                   one CPU core, sized for a real accelerator.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 60 [--preset 100m]
Resume after a crash: just run the same command again (auto-restores).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import smoke_config
from repro.configs.base import ModelConfig
from repro.data import TokenStream
from repro.models import build
from repro.models.steps import init_train_state, make_train_step


def preset_config(preset: str) -> ModelConfig:
    if preset == "tiny":
        return dataclasses.replace(smoke_config("tinyllama-1.1b"),
                                   num_layers=2, d_model=128, d_ff=512,
                                   vocab_size=2048, num_heads=4, num_kv_heads=2,
                                   head_dim=32)
    if preset == "100m":
        return dataclasses.replace(
            smoke_config("tinyllama-1.1b"), name="llama-100m",
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, remat_policy="none")
    raise KeyError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/hazy_jax_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=20)
    args = ap.parse_args()

    cfg = preset_config(args.preset)
    mdl = build(cfg)
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree_util.tree_leaves(
                       jax.tree_util.tree_map(
                           lambda x: x, mdl.param_tree,
                           is_leaf=lambda x: hasattr(x, "shape"))))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    ds = TokenStream(vocab_size=cfg.vocab_size, batch=args.batch,
                     seq_len=args.seq, seed=0)
    step_fn = jax.jit(make_train_step(mdl, lr=1e-3, warmup=20,
                                      total_steps=args.steps))

    start = latest_step(args.ckpt_dir)
    if start is not None:
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            init_train_state(mdl))
        state, start = restore_checkpoint(args.ckpt_dir, abstract)
        print(f"resumed from checkpoint at step {start}")
    else:
        state, start = init_train_state(mdl), 0

    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)
    losses = []
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 10 == 0:
            dt = time.perf_counter() - t0
            tput = args.batch * args.seq * 10 / dt
            print(f"step {i+1}: loss {losses[-1]:.4f} "
                  f"({tput:.0f} tok/s, lr {float(m['lr']):.2e})")
            t0 = time.perf_counter()
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(state, i + 1)
    ckpt.wait()
    ckpt.close()
    if len(losses) >= 20:
        first, last = np.mean(losses[:10]), np.mean(losses[-10:])
        print(f"loss {first:.4f} -> {last:.4f} "
              f"({'improved ✓' if last < first else 'NOT improving ✗'})")


if __name__ == "__main__":
    main()
