"""Quickstart: the paper's Example 2.1 as code.

  CREATE CLASSIFICATION VIEW Labeled_Papers
    ENTITIES  FROM Papers          -- a synthetic DBLife-like corpus
    EXAMPLES  FROM Example_Papers  -- streaming user feedback
    FEATURE FUNCTION tf_bag_of_words (hashed)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import ClassificationView
from repro.data import dblife_like, example_stream


def main():
    papers = dblife_like(scale=0.05)            # 6.2k papers, hashed bag-of-words
    print(f"corpus: {papers.features.shape[0]} papers, "
          f"{papers.features.shape[1]} hashed features")

    view = ClassificationView(
        papers.features,                        # ENTITIES (features precomputed)
        method="svm", policy="eager",           # USING SVM
        norm=(np.inf, 1.0),                     # Hölder (p,q) for l1 text (§3.2)
        lr=0.02,
    )

    feedback = example_stream(papers, seed=0, label_noise=0.0)
    print("streaming 2000 training examples (INSERT INTO Example_Papers)...")
    for _, (i, _f, y) in zip(range(2000), feedback):
        view.insert_example(i, y)

    eng = view.engine
    print(f"view maintained: {view.all_members()} database papers / "
          f"{papers.features.shape[0]}")
    print(f"  reorganizations (SKIING): {eng.skiing.reorgs}")
    print(f"  mean band fraction: "
          f"{eng.stats.tuples_reclassified / max(1, eng.stats.tuples_total_possible):.4f} "
          f"(cold-start training; warm steady state reaches ~0.01 — Fig. 13 repro "
          f"in benchmarks/waters.py)")
    print(f"  single-entity reads: paper 10 -> {view.label(10):+d}, "
          f"paper 42 -> {view.label(42):+d}")
    acc = np.mean([view.label(i) == papers.labels[i]
                   for i in range(0, papers.features.shape[0], 13)])
    print(f"  agreement with ground truth: {acc:.3f}")
    assert eng.check_consistent(), "view != naive relabel — bug!"
    print("view is exact (matches naive relabel under the current model)")


if __name__ == "__main__":
    main()
