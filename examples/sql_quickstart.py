"""SQL quickstart: the paper's workflow end-to-end through the front-end.

Creates a base table from a corpus, registers a hybrid multiclass
classification view on it, streams DML (training inserts through the
group-commit WAL), then reads it back with SELECTs and inspects the
§3.4/§3.5 cost model with EXPLAIN. This replaces the ad-hoc driver
pattern of `examples/serve_view.py` for the view workload — every
interaction below is a SQL statement.

Run:  PYTHONPATH=src python examples/sql_quickstart.py
"""
import time

import numpy as np

from repro.rdbms import Executor


def main():
    ex = Executor(group_commit=32)

    # DDL: a base entity table and a model-based view over it. The
    # memory_budget keeps only 10% of the entity table in memory: feature
    # rows live in an on-disk mmap'd EntityStore and probe misses go
    # through a budgeted BufferPool (SHOW STORAGE below shows residency).
    for r in ex.execute("""
        CREATE TABLE papers FROM CORPUS cora_like WITH (scale = 0.5);
        CREATE CLASSIFICATION VIEW topics ON papers USING MODEL svm
            WITH (policy = hybrid, buffer_frac = 0.05, memory_budget = 0.1);
        SHOW VIEWS;
    """):
        print(r.pretty())

    # DML: stream training examples; the WAL group-commits every 32 rows
    # into ONE engine maintenance round ------------------------------------
    t = ex.catalog.table("papers")
    rng = np.random.default_rng(7)
    n_inserts = 600
    t0 = time.perf_counter()
    batch = []
    for _ in range(n_inserts):
        i = int(rng.integers(0, t.n))
        batch.append(f"({i}, {int(t.truth[i])})")
        if len(batch) == 16:          # multi-row INSERT statements
            ex.execute_one(
                f"INSERT INTO papers (id, class) VALUES {', '.join(batch)}")
            batch = []
    if batch:                         # don't drop the last partial batch
        ex.execute_one(
            f"INSERT INTO papers (id, class) VALUES {', '.join(batch)}")
    ex.execute_one("COMMIT")
    dt = time.perf_counter() - t0
    print(f"\nstreamed {n_inserts} training inserts in {dt:.2f}s "
          f"({n_inserts/dt:.0f} rows/s, {ex.log.commits} group commits)")

    # Reads: point lookups, membership scans, counters, top-k margins ------
    probe = int(rng.integers(0, t.n))
    print("\n-- point lookup (all k one-vs-all views of one entity):")
    print(ex.execute_one(
        f"SELECT id, view, label FROM topics WHERE id = {probe}").pretty())

    # Prepared statements: parse+plan once, EXECUTE per read ---------------
    print("\n-- PREPARE/EXECUTE (point reads skip parse AND plan):")
    ex.execute_one(
        "PREPARE pt AS SELECT label FROM topics WHERE id = ? AND view = ?")
    print(ex.execute_one(f"EXECUTE pt ({probe}, 1)").pretty())

    print("\n-- multiclass prediction:")
    print(ex.execute_one(
        f"SELECT id, class FROM topics WHERE id = {probe}").pretty())

    print("\n-- counter read (zero tuples touched):")
    print(ex.execute_one(
        "SELECT count(*) FROM topics WHERE class = 2").pretty())

    print("\n-- membership scan (band partition; only the band touches F):")
    print(ex.execute_one(
        "SELECT id FROM topics WHERE class = 2 LIMIT 5").pretty())

    print("\n-- top-k margins (eps order + Eq. 2 candidate slack):")
    print(ex.execute_one(
        "SELECT id, margin FROM topics WHERE view = 2 "
        "ORDER BY margin DESC LIMIT 5").pretty())

    # EXPLAIN: the §3.4/§3.5 cost model, user-visible ----------------------
    print("\n-- EXPLAIN a point lookup (reports the tier actually used):")
    print(ex.execute_one(
        f"EXPLAIN SELECT label FROM topics WHERE id = {probe} AND view = 1"
    ).pretty())

    print("\n-- EXPLAIN a membership scan:")
    print(ex.execute_one(
        "EXPLAIN SELECT id FROM topics WHERE label = 1 AND view = 1").pretty())

    print("\n-- EXPLAIN a batched insert (group-commit WAL):")
    print(ex.execute_one(
        "EXPLAIN INSERT INTO papers (id, class) VALUES (0, 1)").pretty())

    # SHOW STORAGE: the buffer pool's residency and hit/miss counters ------
    print("\n-- SHOW STORAGE (the 10% memory budget, physically):")
    print(ex.execute_one("SHOW STORAGE").pretty())

    # EXPLAIN ANALYZE: execute for real, annotate the plan with the span
    # tree and the EXACT tier/pool counter deltas the statement caused ----
    print("\n-- EXPLAIN ANALYZE a point lookup (measured spans + tiers):")
    print(ex.execute_one(
        f"EXPLAIN ANALYZE SELECT label FROM topics "
        f"WHERE id = {probe} AND view = 1").pretty())

    # SHOW METRICS: the unified registry — gate, WAL, pools, spans, views --
    print("\n-- SHOW METRICS (a few rows of the unified telemetry ledger):")
    metrics = ex.execute_one("SHOW METRICS")
    wanted = ("counters.", "epoch", "wal.commits")
    print("\n".join(f"  {k} = {v}" for k, v in metrics.rows
                    if any(k.startswith(w) or k == w for w in wanted)))

    # SHOW COST: modeled SKIING charges next to measured wall clock --------
    print("\n-- SHOW COST ON topics (modeled vs measured SKIING):")
    print(ex.execute_one("SHOW COST ON topics").pretty())

    facade = ex.catalog.view("topics").facade
    print(f"\nhybrid tier hits: {facade.tier_hits} "
          f"(cold feature-row reads: {facade.disk_touches})")
    acc = np.mean([facade.predict(i) == int(t.truth[i])
                   for i in range(0, t.n, 5)])
    print(f"prediction agreement with corpus classes: {acc:.3f}")
    assert facade.engine.check_consistent()
    print("view exact w.r.t. current model ✓")

    # Freshness scheduler: a two-level cascade under TARGET_LAG ------------
    # `base` classifies the raw stream; `triage` is a view OVER the view
    # (its single input feature is base's margin column — a DAG edge in
    # the catalog). `base` declares lag 'downstream': it is exactly as
    # fresh as its consumers need, so triage's 2 s lag governs both.
    print("\n-- freshness: a lagged two-level cascade (views over views):")
    for r in ex.execute("""
        CREATE TABLE stream FROM CORPUS synthetic WITH (scale = 0.1);
        CREATE CLASSIFICATION VIEW base ON stream USING MODEL svm
            WITH (cost_mode = modeled, target_lag = downstream);
        CREATE CLASSIFICATION VIEW triage ON base USING MODEL svm
            WITH (cost_mode = modeled, target_lag = '2 s');
        SHOW VIEWS;
    """):
        print(r.pretty())

    st = ex.catalog.table("stream")
    for i in range(0, 48):                # committed, but NOT applied yet:
        ex.execute_one(f"INSERT INTO stream (id, label) VALUES "
                       f"({i}, {int(st.truth[i])})")
    ex.execute_one("COMMIT")
    print("-- SHOW SCHEDULE (the batches queue in the freshness inbox):")
    print(ex.execute_one("SHOW SCHEDULE").pretty())

    # SUSPEND freezes labels; committed updates keep queueing. RESUME
    # catches up exactly once — bit-identical to never having suspended.
    ex.execute_one("ALTER VIEW base SUSPEND")
    for i in range(48, 64):
        ex.execute_one(f"INSERT INTO stream (id, label) VALUES "
                       f"({i}, {int(st.truth[i])})")
    ex.execute_one("COMMIT")
    print("-- suspended:")
    print(ex.execute_one("ALTER VIEW base RESUME").pretty())

    # the refresh barrier: drain every inbox in topological order (in
    # `--serve` mode a background thread does this continuously, picking
    # the most-stale-per-modeled-cost view each slice)
    refreshed = ex.refresh_views()
    print(f"refresh barrier drained (topo order): {refreshed}")
    print(ex.execute_one("SHOW VIEWS").pretty())


if __name__ == "__main__":
    main()
