"""End-to-end serving driver (the paper's kind of workload): a classification
view over a corpus of documents *encoded by an LM backbone*, serving batched
mixed read/update traffic — Single-Entity reads, All-Members scans, and
streaming training examples — with the HAZY engine maintaining the view and
SKIING deciding reorganizations.

Run:  PYTHONPATH=src python examples/serve_view.py [--requests 3000]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core import ClassificationView
from repro.models import build
from repro.models.steps import init_train_state


def make_backbone_encoder(arch: str = "tinyllama-1.1b", batch: int = 32):
    """A reduced assigned-arch backbone as the HAZY feature function."""
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    params = state["params"]

    @jax.jit
    def encode_batch(tokens):
        hidden, _ = mdl.forward(params, {"tokens": tokens}, return_hidden=True)
        emb = jnp.mean(jnp.take(params["tok"]["embedding"], tokens, axis=0), axis=1)
        # mean-pooled final hidden + mean-pooled token embeddings
        return jnp.concatenate([jnp.mean(hidden, axis=1), emb.astype(hidden.dtype)], -1)

    def encode(docs_tokens: np.ndarray) -> np.ndarray:
        out = []
        for i in range(0, docs_tokens.shape[0], batch):
            out.append(np.asarray(encode_batch(
                jnp.asarray(docs_tokens[i:i + batch])), np.float32))
        F = np.concatenate(out)
        return F / np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)

    return encode, cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3000)
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--doc-len", type=int, default=32)
    args = ap.parse_args()

    r = np.random.default_rng(0)
    encode, cfg = make_backbone_encoder()
    # two "topics": docs drawn from distinct topical vocabularies (with some
    # shared common words mixed in)
    topic = r.random(args.docs) < 0.5
    v8 = cfg.vocab_size // 8
    topical = np.where(topic[:, None],
                       r.integers(0, v8, (args.docs, args.doc_len)),
                       r.integers(4 * v8, 5 * v8, (args.docs, args.doc_len)))
    common = r.integers(6 * v8, 8 * v8, (args.docs, args.doc_len))
    use_common = r.random((args.docs, args.doc_len)) < 0.3
    docs = np.where(use_common, common, topical).astype(np.int32)
    t0 = time.perf_counter()
    F = encode(docs)
    print(f"encoded {args.docs} docs with {cfg.name} backbone "
          f"in {time.perf_counter()-t0:.1f}s -> features {F.shape}")

    view = ClassificationView(F, method="svm", policy="hybrid",
                              norm=(2.0, 2.0), lr=0.1, buffer_frac=0.01)

    labels = np.where(topic, 1.0, -1.0)
    kinds = r.choice(["read", "members", "update"], size=args.requests,
                     p=[0.55, 0.05, 0.40])
    served = {"read": 0, "members": 0, "update": 0}
    t0 = time.perf_counter()
    for kind in kinds:
        if kind == "read":
            view.label(int(r.integers(0, args.docs)))
        elif kind == "members":
            view.all_members()
        else:
            i = int(r.integers(0, args.docs))
            view.insert_example(i, float(labels[i]))
        served[kind] += 1
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.0f} req/s): {served}")
    eng = view.engine
    print(f"SKIING reorgs: {eng.skiing.reorgs}, "
          f"band now: {eng.band_fraction():.4f}")
    acc = np.mean([view.label(i) == labels[i] for i in range(0, args.docs, 7)])
    print(f"classification agreement with topic labels: {acc:.3f}")
    assert eng.check_consistent()
    print("view exact ✓")


if __name__ == "__main__":
    main()
