"""End-to-end serving driver (the paper's kind of workload): a classification
view over a corpus of documents *encoded by an LM backbone*, serving batched
mixed read/update traffic.

The driver itself lives in `repro.launch.view_driver` (importable — also
reachable as `python -m repro.launch.serve --mode view`); this example is a
thin entry point. For the same workload through the SQL front-end, see
`examples/sql_quickstart.py` or pass `--sql`.

Run:  PYTHONPATH=src python examples/serve_view.py [--requests 3000]
"""
from repro.launch.view_driver import main

if __name__ == "__main__":
    main()
