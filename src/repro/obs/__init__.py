"""repro.obs — unified telemetry: metrics registry, span tracing, cost hooks.

Stdlib-only and dependency-free within the tree (``repro.obs`` imports
nothing from the rest of ``repro``), so every layer — core engines, storage,
rdbms, launch — can depend on it without cycles.

``clock`` is the single sanctioned monotonic clock; everything under
``src/repro`` outside this package must time through it (or through the
span/metrics API) — raw ``time.perf_counter()``/``time.time()`` calls are
flagged by the ``repro.analysis`` TEL001 rule.
"""
from repro.obs.metrics import (
    DEFAULT_COUNT_BUCKETS,
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import Span, Tracer, clock, current, finish, render_tree, span, start
from repro.obs.cost import ViewCostRecorder

__all__ = [
    "DEFAULT_COUNT_BUCKETS",
    "DEFAULT_TIME_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Tracer",
    "ViewCostRecorder",
    "clock",
    "current",
    "finish",
    "render_tree",
    "span",
    "start",
]
