"""Measured-cost SKIING hooks: per-view wall-clock cost recorders.

The engines charge *modeled* SKIING costs (in ``cost_mode="modeled"`` those
are deterministic fractions of a scan, pinned so equivalence tests stay
bitwise); a ``ViewCostRecorder`` records the *measured* wall-clock cost of
the same reorganize / incremental / catch-up work alongside, without ever
feeding back into the modeled charges. ``SHOW COST ON <view>`` reports the
modeled-vs-measured ratio per view — the seconds-per-modeled-unit exchange
rate a freshness scheduler needs to turn SKIING charges into wall time.
"""
from __future__ import annotations

import threading
from typing import Any, Dict

from repro.obs.metrics import DEFAULT_TIME_BUCKETS, Histogram


class ViewCostRecorder:
    """Wall-clock reorg/step timings + modeled-charge totals for k views."""

    def __init__(self, k: int = 1) -> None:
        self.k = int(k)
        self._lock = threading.Lock()
        self.reorg_hist = [Histogram(DEFAULT_TIME_BUCKETS) for _ in range(self.k)]
        self.step_hist = [Histogram(DEFAULT_TIME_BUCKETS) for _ in range(self.k)]
        self.charge_modeled = [0.0] * self.k
        self.seconds_measured = [0.0] * self.k
        self.reorg_seconds = [0.0] * self.k

    def record_reorg(self, v: int, seconds: float) -> None:
        self.reorg_hist[v].observe(seconds)
        with self._lock:
            self.reorg_seconds[v] += seconds

    def record_step(self, v: int, seconds: float, charge: float) -> None:
        """One incremental/catch-up step: measured wall seconds alongside the
        modeled charge actually fed to SKIING."""
        self.step_hist[v].observe(seconds)
        with self._lock:
            self.seconds_measured[v] += seconds
            self.charge_modeled[v] += float(charge)

    def snapshot(self, v: int) -> Dict[str, Any]:
        with self._lock:
            modeled = self.charge_modeled[v]
            measured = self.seconds_measured[v]
            reorg_s = self.reorg_seconds[v]
        rh, sh = self.reorg_hist[v], self.step_hist[v]
        return {
            "reorgs_measured": rh.count,
            "S_measured_mean_s": rh.mean,
            "reorg_seconds": reorg_s,
            "steps_measured": sh.count,
            "step_p50_s": sh.quantile(0.50),
            "step_p99_s": sh.quantile(0.99),
            "charge_modeled": modeled,
            "seconds_measured": measured,
            "seconds_per_charge": (measured / modeled) if modeled > 0 else None,
        }
