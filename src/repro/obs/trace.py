"""Statement tracing: parent/child spans on the monotonic clock.

A span is a named interval with attributes and children. The *ambient*
current span is kept on a per-thread stack, so deep layers (WAL group
commit, buffer-pool cold reads) can attach child spans without the executor
threading a tracer handle through every call — ``start()`` parents the new
span under whatever span is current on this thread, or makes it a root.

``finish(span, metrics)`` closes the span, records its duration into the
registry histogram ``span.<name>.seconds`` when a registry is given, and
unwinds the thread-local stack *through* the span — any child left open by
an exception path is discarded rather than corrupting later statements.

Rendered trees back EXPLAIN ANALYZE, the slow-statement log, and the REPL
timing footer, so all three report the same per-phase breakdown.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

# The single sanctioned clock for the whole tree (TEL001: raw
# time.perf_counter()/time.time() calls outside repro.obs are lint errors).
clock = time.perf_counter

_tls = threading.local()


def _stack() -> List["Span"]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


@dataclass(slots=True)
class Span:
    name: str
    t0: float
    t1: Optional[float] = None
    attrs: Dict[str, Any] = field(default_factory=dict)
    children: List["Span"] = field(default_factory=list)

    @property
    def duration_s(self) -> float:
        end = self.t1 if self.t1 is not None else clock()
        return max(0.0, end - self.t0)

    @property
    def duration_us(self) -> float:
        return self.duration_s * 1e6

    def walk(self) -> Iterator["Span"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> Optional["Span"]:
        for s in self.walk():
            if s.name == name:
                return s
        return None

    def sum_us(self, name: str) -> float:
        """Total duration of every descendant span named ``name``."""
        return sum(s.duration_us for s in self.walk() if s.name == name)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"name": self.name, "us": round(self.duration_us, 1)}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d


def current() -> Optional[Span]:
    st = _stack()
    return st[-1] if st else None


_new_span = object.__new__


def start(name: str, **attrs: Any) -> Span:
    """Open a span as a child of this thread's current span (or a root)."""
    # Hand-rolled construction: this runs five times per statement with the
    # registry armed, so skip the dataclass __init__ frame (~25% of span
    # cost) and reuse the **attrs dict, which is already a fresh one.
    sp = _new_span(Span)
    sp.name = name
    sp.t1 = None
    sp.attrs = attrs
    sp.children = []
    st = _stack()
    if st:
        st[-1].children.append(sp)
    st.append(sp)
    sp.t0 = clock()       # last: exclude our own setup from the interval
    return sp


# span name -> "span.<name>.seconds", so the statement hot path doesn't
# rebuild the histogram key on every finish.
_hist_names: Dict[str, str] = {}


def finish(sp: Span, metrics: Any = None) -> Span:
    """Close ``sp``: stamp t1, unwind the stack through it, record duration."""
    sp.t1 = clock()
    st = _stack()
    while st:
        top = st.pop()
        if top is sp:
            break
    if metrics is not None:
        hname = _hist_names.get(sp.name)
        if hname is None:
            hname = _hist_names[sp.name] = f"span.{sp.name}.seconds"
        metrics.histogram(hname).observe(sp.duration_s)
    return sp


@contextmanager
def span(name: str, metrics: Any = None, **attrs: Any) -> Iterator[Span]:
    sp = start(name, **attrs)
    try:
        yield sp
    finally:
        finish(sp, metrics)


class Tracer:
    """A span factory bound to one metrics registry."""

    def __init__(self, metrics: Any = None) -> None:
        self.metrics = metrics

    def span(self, name: str, **attrs: Any):
        return span(name, metrics=self.metrics, **attrs)

    def start(self, name: str, **attrs: Any) -> Span:
        return start(name, **attrs)

    def finish(self, sp: Span) -> Span:
        return finish(sp, self.metrics)


def render_tree(sp: Span, indent: int = 0) -> str:
    """Multi-line ``name  123.4us  k=v`` tree (slow log, REPL, debugging)."""
    attrs = ";".join(f"{k}={v}" for k, v in sp.attrs.items())
    line = f"{'  ' * indent}{sp.name}  {sp.duration_us:.1f}us" + (f"  [{attrs}]" if attrs else "")
    lines = [line]
    for c in sp.children:
        lines.append(render_tree(c, indent + 1))
    return "\n".join(lines)
