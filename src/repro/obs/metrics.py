"""Thread-safe metrics primitives: counters, gauges, fixed-bucket histograms.

One ``MetricsRegistry`` is owned by the catalog and shared by every layer
(gate, WAL, buffer pools, executor). Instruments are get-or-create by dotted
name and cheap enough to leave armed in production: each operation is one
small critical section on a per-instrument lock (CPython ``+=`` on an int is
not atomic across bytecodes, and exact reconciliation — hits + misses ==
probes, commits == epoch — is the whole point of this layer).

Histograms use fixed upper-bound buckets (exponential time buckets by
default) with exact ``count``/``sum``; quantiles report the upper bound of
the first bucket whose cumulative count reaches ``q * count``, which makes
percentile tests exact on known distributions.

Layered snapshots: components that already keep their own locked counters
(buffer pool, prefetcher, facades, WAL) register a *collector* — a zero-arg
callable returning a JSON-able dict — and ``snapshot()`` merges them in.
Collectors run outside the registry lock, so a collector may take its
component's own lock (pool, wal_commit) without ordering hazards.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Callable, Dict, Optional, Sequence

# Upper bucket bounds in seconds, ~1 µs .. 10 s. Spans, gate waits, pool
# reads and SKIING phases all land comfortably inside this range at any
# scale we run.
DEFAULT_TIME_BUCKETS: Sequence[float] = (
    1e-6, 2.5e-6, 5e-6,
    1e-5, 2.5e-5, 5e-5,
    1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3,
    1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1,
    1.0, 2.5, 5.0, 10.0,
)

# Upper bounds for count-like distributions (WAL group sizes, batch sizes).
DEFAULT_COUNT_BUCKETS: Sequence[float] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096,
)


class Counter:
    """Monotonic counter. ``inc`` is a single locked add."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins scalar (queue depths, sizes)."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self.value = v


class Histogram:
    """Fixed-bucket histogram with exact count/sum.

    ``bounds`` are inclusive upper bucket edges; observations above the last
    bound land in an overflow bucket whose quantile reports ``inf``.
    """

    __slots__ = ("_lock", "bounds", "counts", "count", "sum")

    def __init__(self, bounds: Sequence[float] = DEFAULT_TIME_BUCKETS) -> None:
        self._lock = threading.Lock()
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +1 overflow
        self.count = 0
        self.sum = 0.0

    def observe(self, x: float) -> None:
        i = bisect.bisect_left(self.bounds, x)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += x

    def quantile(self, q: float) -> float:
        """Upper bound of the first bucket whose cumulative count reaches
        ``q * count``. Exact for distributions aligned to bucket edges."""
        with self._lock:
            total = self.count
            counts = list(self.counts)
        if total == 0:
            return 0.0
        target = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= target:
                return self.bounds[i] if i < len(self.bounds) else float("inf")
        return float("inf")

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            count, total = self.count, self.sum
            counts = list(self.counts)
        snap: Dict[str, Any] = {
            "count": count,
            "sum": total,
            "mean": (total / count) if count else 0.0,
            "buckets": list(self.bounds),
            "counts": counts,
        }
        # p50/p99 recomputed from the copied counts so the snapshot is
        # internally consistent even under concurrent observes.
        for name, q in (("p50", 0.50), ("p99", 0.99)):
            if count == 0:
                snap[name] = 0.0
                continue
            target, cum, val = q * count, 0, float("inf")
            for i, c in enumerate(counts):
                cum += c
                if cum >= target:
                    val = self.bounds[i] if i < len(self.bounds) else float("inf")
                    break
            snap[name] = val
        return snap


class MetricsRegistry:
    """Process-local registry: named instruments + layered collectors."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._collectors: Dict[str, Callable[[], Any]] = {}

    # Lookups take the lock only on the create path: dict reads are atomic
    # under the GIL and instruments are never removed, so the hit path (every
    # statement, every span) is a single dict probe.

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is not None:
            return c
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is not None:
            return g
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is not None:
            return h
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(buckets or DEFAULT_TIME_BUCKETS)
            return h

    def register_collector(self, name: str, fn: Callable[[], Any]) -> None:
        """Attach a component snapshot under ``name``. Last writer wins, so
        re-creating a view re-points its collector instead of erroring."""
        with self._lock:
            self._collectors[name] = fn

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able point-in-time snapshot of every instrument + collector."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
            collectors = dict(self._collectors)
        out: Dict[str, Any] = {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.snapshot() for n, h in sorted(histograms.items())},
        }
        # Collectors run outside the registry lock: they may take their own
        # component locks (pool, wal_commit) while gathering.
        for name, fn in sorted(collectors.items()):
            try:
                out[name] = fn()
            except Exception as e:  # a dead collector must not kill SHOW METRICS
                out[name] = {"error": type(e).__name__}
        return out
