"""Group-commit update log (WAL-style, replayable).

Heavy write traffic must not pay one engine maintenance round per DML
statement — the batched `insert_examples` path exists precisely so k
training inserts amortize into ONE `apply_model` round. The log is the
relational face of that amortization:

  * every INSERT/UPDATE/DELETE appends a `WalRecord` (monotone LSNs) to a
    per-table pending group and to the durable history;
  * a group commits when it reaches `group_size`, when a read arrives on
    one of the table's views (read-your-writes: SELECTs always observe all
    submitted DML), on `COMMIT` / `UPDATE MODEL`, or on explicit `flush`;
  * a commit feeds each view of the table one batched
    `facade.insert_examples` call (DELETE breaks the batch: it retrains
    non-incrementally per paper footnote 2, so order is preserved around
    it) and appends a commit marker to the history;
  * the history (optionally mirrored to a JSONL file) replays into a fresh
    catalog with identical commit boundaries — `replay_into` is the
    recovery path, and the equivalence tests replay it against direct
    engine calls.

Concurrency: appends and commits are serialized behind ONE explicit
commit lock (`_commit_lock`). N server sessions share one log; without
the lock two sessions' appends interleave inside the pending-group list
mid-`flush` (records silently dropped from the popped group) and two
concurrent flushes double-feed the same batch to the engines. Point
reads never take this lock — they proceed under the executor's shared
epoch gate while writers queue behind it.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Dict, List, Optional

from repro.analysis.witness import wrap
from repro.obs.metrics import DEFAULT_COUNT_BUCKETS
from repro.obs.trace import span as _span
from repro.rdbms.ast_nodes import SqlError


@dataclasses.dataclass
class WalRecord:
    lsn: int
    op: str                    # insert | update | delete | commit
    table: str
    entity_id: int = -1
    label: float = 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(line: str) -> "WalRecord":
        return WalRecord(**json.loads(line))


class UpdateLog:
    def __init__(self, group_size: int = 64, path: Optional[str] = None,
                 metrics=None):
        assert group_size >= 1
        self.group_size = int(group_size)
        self.path = path
        self._fh = open(path, "a") if path else None
        self._commit_lock = wrap(threading.RLock(), "wal_commit")
        self.history: List[WalRecord] = []
        self.pending: Dict[str, List[WalRecord]] = {}
        self.lsn = 0
        self.commits = 0
        self._metrics = metrics
        if metrics is not None:
            self._m_appends = metrics.counter("wal.appends")
            self._m_commits = metrics.counter("wal.commits")
            self._m_group = metrics.histogram("wal.group_size",
                                              DEFAULT_COUNT_BUCKETS)
        else:
            self._m_appends = self._m_commits = self._m_group = None

    # -- append --------------------------------------------------------
    def _record(self, op: str, table: str, entity_id: int = -1,
                label: float = 0.0) -> WalRecord:
        self.lsn += 1
        rec = WalRecord(self.lsn, op, table, int(entity_id), float(label))
        self.history.append(rec)
        if self._fh:
            self._fh.write(rec.to_json() + "\n")
            self._fh.flush()
        return rec

    def append(self, op: str, table: str, entity_id: int, label: float,
               catalog) -> int:
        """Log one DML record; auto-commits the table's group when it
        reaches `group_size`. Returns the number of commits triggered."""
        if op not in ("insert", "update", "delete"):
            raise SqlError(f"bad WAL op {op!r}")
        with self._commit_lock:
            self.pending.setdefault(table, []).append(
                self._record(op, table, entity_id, label))
            if self._m_appends is not None:
                self._m_appends.inc()
            if len(self.pending[table]) >= self.group_size:
                return self.flush(catalog, table)
            return 0

    def has_pending(self, table: Optional[str] = None) -> bool:
        """Any uncommitted DML (for `table`, or anywhere)? Read-your-writes
        checks this before deciding whether a read must flush first."""
        with self._commit_lock:
            if table is not None:
                return bool(self.pending.get(table))
            return any(self.pending.values())

    # -- commit --------------------------------------------------------
    def flush(self, catalog, table: Optional[str] = None) -> int:
        """Commit pending groups (one table, or all). Each commit is ONE
        batched engine round per view; DELETEs preserve statement order by
        splitting the batch around the retrain."""
        with self._commit_lock:
            with _span("wal.commit", metrics=self._metrics) as sp:
                n = self._flush_locked(catalog, table)
                sp.attrs["commits"] = n
            return n

    def _flush_locked(self, catalog, table: Optional[str] = None) -> int:
        tables = [table] if table is not None else list(self.pending)
        commits = 0
        for t in tables:
            group = self.pending.pop(t, [])
            if not group:
                continue
            # the catalog's view DAG decides per-view what "apply" means:
            # immediate views train right here (one batched engine round,
            # exactly the old inline feed); scheduled views queue the
            # batch in their inbox for the freshness scheduler
            catalog.deliver_group(t, group)
            self._record("commit", t)
            self.commits += 1
            commits += 1
            if self._m_commits is not None:
                self._m_commits.inc()
                self._m_group.observe(len(group))
        return commits

    # -- telemetry -----------------------------------------------------
    def telemetry_snapshot(self) -> Dict[str, object]:
        """Collector payload for the metrics registry (`wal` key)."""
        with self._commit_lock:
            return {
                "commits": self.commits,
                "lsn": self.lsn,
                "group_size": self.group_size,
                "pending_tables": sum(1 for g in self.pending.values() if g),
                "pending_records": sum(len(g) for g in self.pending.values()),
            }

    # -- recovery ------------------------------------------------------
    @staticmethod
    def replay_into(history: List[WalRecord], catalog,
                    group_size: int = 64) -> "UpdateLog":
        """Re-apply a history against a fresh catalog (tables and views
        already created). Commit markers in the history reproduce the
        original commit boundaries exactly, whatever `group_size` was."""
        log = UpdateLog(group_size=max(group_size, len(history) + 1))
        for rec in history:
            if rec.op == "commit":
                log.pending.setdefault(rec.table, [])
                log.flush(catalog, rec.table)
            else:
                log.pending.setdefault(rec.table, []).append(
                    log._record(rec.op, rec.table, rec.entity_id, rec.label))
        return log

    @staticmethod
    def load(path: str) -> List[WalRecord]:
        with open(path) as fh:
            return [WalRecord.from_json(line) for line in fh
                    if line.strip()]

    def close(self):
        if self._fh:
            self._fh.close()
            self._fh = None
