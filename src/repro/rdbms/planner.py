"""Planner: statement AST -> a priced `Plan` routed to a §3.5 tier.

The paper's §3.4/§3.5 argument is that the *right* physical operator for a
classification-view read depends on what the waters already guarantee:

  * point lookups (`WHERE id = ?`) route to the §3.5.2 probe — eps-map +
    waters short-circuit + hot buffer; the feature table is touched only
    on probe misses, so the estimated touched-tuple count is
    #ids × band/n (the probe miss probability);
  * label/class membership scans route to the Lemma 3.1 band partition —
    the certainly-positive suffix is served straight from the clustered
    labels and ONLY the band rows ever need feature access, never full F
    when the waters suffice;
  * COUNT(*) with a label/class predicate is a counter read
    (`pos_count`) — zero tuples touched;
  * top-k margin queries route to the entity-margin step: stored eps
    bound the current margin (Eq. 2), so only `limit + slack` candidate
    rows are recomputed;
  * DML routes through the group-commit WAL: per commit, ONE engine round
    whose touched tuples are the union band.

`plan_statement` is pure — it reads facade state (band widths, pending
masks) but never mutates it, so EXPLAIN costs nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.rdbms.ast_nodes import (AlterView, Commit, CreateTable,
                                   CreateView, Delete, ExecutePrepared,
                                   Explain, Insert, Prepare, Select, Show,
                                   Update, UpdateModel, Where)
from repro.rdbms.catalog import Catalog, PlanError


@dataclasses.dataclass
class Plan:
    kind: str           # point | scan | count | topk | full | group-commit | ddl | ...
    tier: str           # physical tier the executor will use
    est_touched: int    # §3.4/§3.5 cost model: feature tuples touched
    detail: str = ""
    view: Optional[str] = None

    def row(self):
        return (self.kind, self.tier, self.est_touched, self.detail)


def _resolve_view_index(where: Optional[Where], facade, columns) -> int:
    """Which one-vs-all view a label read addresses. k = 1 -> view 0;
    k > 1 needs `view = j` / `class = c` unless all views are read."""
    w = where or Where()
    if w.view is not None:
        if not (0 <= w.view < facade.num_views):
            raise PlanError(f"view = {w.view} out of range "
                            f"(k = {facade.num_views})")
        return w.view
    if w.cls is not None:
        if not (0 <= w.cls < facade.num_views):
            raise PlanError(f"class = {w.cls} out of range "
                            f"(k = {facade.num_views})")
        return w.cls
    return 0


def plan_select(sel: Select, catalog: Catalog) -> Plan:
    vd = catalog.view(sel.view)
    f = vd.facade
    w = sel.where or Where()
    k = f.num_views
    multi = k > 1

    if sel.count:
        if w.ids is not None:
            raise PlanError("COUNT(*) with id predicate is unsupported")
        if w.label is None and w.cls is None:
            # unpredicated COUNT(*): the base table's cardinality, known
            # without touching any view state
            return Plan("count", "table-cardinality", 0, f"n={f.n}",
                        view=sel.view)
        band, certain_pos, n = f.band_info(_resolve_view_index(w, f, None))
        pend = bool(f.pending()[_resolve_view_index(w, f, None)])
        # a pending lazy view must catch up before the counter is exact
        return Plan("count", "counter(pos_count)"
                    + ("+catch-up" if pend else ""),
                    band if pend else 0, f"certain_pos={certain_pos}",
                    view=sel.view)

    if w.ids is not None:                       # point lookup(s)
        # LIMIT caps the probes the executor will actually issue
        n_ids = len(w.ids) if sel.limit is None \
            else min(len(w.ids), max(1, sel.limit))
        for i in w.ids:
            if not (0 <= i < f.n):
                raise PlanError(f"id = {i} out of range (n = {f.n})")
        if multi and w.view is None and "view" not in sel.columns \
                and "class" not in sel.columns and "margin" not in sel.columns:
            raise PlanError(
                f"view {sel.view!r} has k = {k} one-vs-all views: add "
                f"`view = j` to the WHERE clause, select the `view` "
                f"column (all views), or select `class`")
        v = _resolve_view_index(w, f, sel.columns)
        band, _, n = f.band_info(v)
        if "margin" in sel.columns:
            # margins always recompute from the feature row
            return Plan("point", "margin(feature-row)", n_ids,
                        f"ids={n_ids}", view=sel.view)
        if f.policy == "hybrid":
            # probe miss probability = band fraction; misses touch the
            # storage tier once (a budgeted buffer pool when the view has
            # one — resident page = pool hit, else a cold disk page read)
            est = max(0 if band == 0 else 1,
                      round(n_ids * band / max(1, n)))
            tier = ("probe(water->buffer->pool->disk)"
                    if f.storage_stats() is not None
                    else "probe(water->buffer->disk)")
            return Plan("point", tier, est,
                        f"ids={n_ids};band={band};n={n}", view=sel.view)
        pend = bool(f.pending()[v])
        return Plan("point", "eps-map" + ("+catch-up" if pend else ""),
                    band if pend else 0, f"ids={n_ids}", view=sel.view)

    if sel.order_by == "margin":                # top-k margin
        limit = sel.limit if sel.limit is not None else 10
        v = _resolve_view_index(w, f, sel.columns)
        band, _, n = f.band_info(v)
        est = min(n, limit + band)              # Eq. 2 candidate slack
        return Plan("topk", "eps-order+margin-recompute", est,
                    f"limit={limit};slack<=band={band}", view=sel.view)

    if w.label is not None or w.cls is not None:    # membership scan
        v = _resolve_view_index(w, f, sel.columns)
        band, certain_pos, n = f.band_info(v)
        return Plan("scan", "band-partition", band,
                    f"certain_pos={certain_pos};band={band};n={n}",
                    view=sel.view)

    # bare SELECT id, label FROM v: serve every label from the clustered
    # scratch table; only a pending band would need feature rows
    v = _resolve_view_index(w, f, sel.columns)
    band, _, n = f.band_info(v)
    pend = bool(f.pending()[v])
    return Plan("full", "clustered-labels" + ("+catch-up" if pend else ""),
                band if pend else 0, f"n={n}", view=sel.view)


def plan_statement(stmt, catalog: Catalog, log=None) -> Plan:
    if isinstance(stmt, Select):
        return plan_select(stmt, catalog)
    if isinstance(stmt, Insert):
        views = catalog.views_on(stmt.table)
        catalog.table(stmt.table)
        est = 0
        for vd in views:
            band, _, _ = vd.facade.band_info(0)
            est += band                       # one union-band round/commit
        group = log.group_size if log is not None else 1
        return Plan("group-commit", "wal(batched insert_examples)", est,
                    f"rows={len(stmt.rows)};group_size={group};"
                    f"views={len(views)}")
    if isinstance(stmt, Update):
        catalog.table(stmt.table)
        return Plan("group-commit", "wal(online relabel example)",
                    sum(vd.facade.band_info(0)[0]
                        for vd in catalog.views_on(stmt.table)),
                    f"id={stmt.entity_id}")
    if isinstance(stmt, Delete):
        t = catalog.table(stmt.table)
        unsupported = [vd.name for vd in catalog.views_on(stmt.table)
                       if not vd.facade.supports_delete]
        if unsupported:
            raise PlanError(
                f"DELETE retrains from scratch (paper footnote 2) and is "
                f"only supported by single-view views; views "
                f"{unsupported} on table {stmt.table!r} cannot")
        return Plan("retrain", "full-retrain (footnote 2)", t.n,
                    f"id={stmt.entity_id}")
    if isinstance(stmt, UpdateModel):
        vd = catalog.view(stmt.view)
        band, _, _ = vd.facade.band_info(0)
        return Plan("model-round", "flush+apply_model", band,
                    view=stmt.view)
    if isinstance(stmt, Commit):
        pending = sum(len(v) for v in log.pending.values()) if log else 0
        return Plan("commit", "wal-flush", 0, f"pending={pending}")
    if isinstance(stmt, CreateTable):
        return Plan("ddl", "create-table", 0, stmt.corpus)
    if isinstance(stmt, CreateView):
        if stmt.table in catalog.views:          # derived: ON another view
            parent = catalog.view(stmt.table)
            return Plan("ddl", "create-view(derived, margin-column pull)",
                        parent.facade.n,
                        f"{stmt.options.get('policy', 'eager')};"
                        f"on={stmt.table}")
        t = catalog.table(stmt.table)
        return Plan("ddl", "create-view(initial clustering)", t.n,
                    stmt.options.get("policy", "eager"))
    if isinstance(stmt, AlterView):
        vd = catalog.view(stmt.view)
        if stmt.action == "refresh":
            # catch-up: queued rows + the band a round relabels (SKIING
            # units, same as the scheduler's modeled cost)
            from repro.scheduler import refresh as _refresh
            est = int(_refresh.modeled_catchup_cost(catalog, vd))
            return Plan("refresh", "scheduler(topo catch-up)", est,
                        view=stmt.view)
        return Plan("ddl", f"alter-view({stmt.action})", 0,
                    ",".join(sorted(stmt.options)) or stmt.action,
                    view=stmt.view)
    if isinstance(stmt, Show):
        return Plan("show", "catalog", 0, stmt.what)
    if isinstance(stmt, Prepare):
        # the template may hold ? placeholders — planning happens at the
        # first EXECUTE, and the route is cached from then on
        return Plan("prepare", "statement-cache", 0,
                    f"{stmt.name};params={stmt.n_params}")
    if isinstance(stmt, ExecutePrepared):
        return Plan("execute", "prepared(cached-route)", 0, stmt.name)
    if isinstance(stmt, Explain):
        return plan_statement(stmt.stmt, catalog, log)
    raise PlanError(f"cannot plan {type(stmt).__name__}")
