"""Typed WITH-option schemas for the DDL/ALTER surface.

Every `WITH (...)` option the dialect accepts is declared ONCE here as an
`OptionSpec` (value type, default, choices, whether `ALTER VIEW ... SET`
may change it). The parser, `Catalog.create_view`, `ALTER VIEW ... SET`
and the facade constructors all consume the same parsed dataclass —
there is exactly one place a new DDL option gets added, one coercion per
value type, and one error message that lists the valid options.

Value kinds:

  int / float / str    plain scalars (the lexer delivers numbers as
                       floats and bare identifiers/strings as str)
  flag                 on/off | true/false | 1/0
  choice               one of `spec.choices`
  budget               memory budget: a fraction in (0, 1] of the entity
                       table's bytes, or an absolute byte count (> 1)
  lag                  a freshness target: '5 s' / '500 ms' / '2 m' (a
                       quoted duration), a bare number of seconds, or
                       `downstream` (derive the lag from consumer views)

`target_lag` values parse to float seconds, the `DOWNSTREAM` sentinel, or
None (no lag declared: the view is maintained at commit time, exactly the
pre-scheduler behavior).
"""
from __future__ import annotations

import dataclasses
import re
from math import isfinite
from typing import Any, Dict, Optional, Tuple

from repro.rdbms.ast_nodes import PlanError

#: `target_lag = downstream`: the view's lag is derived from its consumers.
DOWNSTREAM = "downstream"

_LAG_RE = re.compile(r"^\s*([0-9]*\.?[0-9]+)\s*(ms|s|m|h)?\s*$")
_LAG_UNITS = {"ms": 1e-3, "s": 1.0, "m": 60.0, "h": 3600.0, None: 1.0}

_TRUE = ("on", "true", "1", "1.0")
_FALSE = ("off", "false", "0", "0.0")


def coerce_number(value: float):
    """The dialect's single number coercion: integral floats become ints
    (the lexer produces floats; `k = 3` must arrive as the int 3)."""
    if isfinite(value) and value == int(value):
        return int(value)
    return value


def parse_lag(value) -> Optional[object]:
    """'5 s' / '500 ms' / bare seconds / 'downstream' -> seconds | sentinel."""
    if value is None:
        return None
    if isinstance(value, (int, float)):
        seconds = float(value)
    else:
        text = str(value).strip().lower()
        if text in ("downstream",):
            return DOWNSTREAM
        m = _LAG_RE.match(text)
        if not m:
            raise PlanError(
                f"bad target_lag {value!r}: want a duration like '5 s', "
                f"'500 ms', '2 m', a bare number of seconds, or downstream")
        seconds = float(m.group(1)) * _LAG_UNITS[m.group(2)]
    if seconds <= 0:
        raise PlanError(f"target_lag must be positive, got {value!r}")
    return seconds


def format_lag(lag) -> str:
    if lag is None:
        return "-"
    if lag == DOWNSTREAM:
        return "downstream"
    if lag < 1.0:
        return f"{lag * 1e3:g} ms"
    return f"{lag:g} s"


@dataclasses.dataclass(frozen=True)
class OptionSpec:
    name: str
    kind: str                       # int | float | str | flag | choice | budget | lag
    default: Any = None
    choices: Tuple[str, ...] = ()
    alterable: bool = False         # may ALTER VIEW ... SET change it?

    def coerce(self, value):
        try:
            if self.kind == "int":
                v = int(value)
                if v != float(value):
                    raise ValueError
                return v
            if self.kind == "float":
                return float(value)
            if self.kind == "str":
                return str(value)
            if self.kind == "flag":
                text = str(value).lower()
                if text in _TRUE:
                    return True
                if text in _FALSE:
                    return False
                raise ValueError
            if self.kind == "choice":
                text = str(value).lower()
                if text not in self.choices:
                    raise PlanError(
                        f"option {self.name} must be one of "
                        f"{'/'.join(self.choices)}, got {value!r}")
                return text
            if self.kind == "budget":
                v = float(value)
                if v <= 0:
                    raise PlanError(
                        f"option {self.name} must be positive (a fraction "
                        f"in (0, 1] of the entity table, or bytes)")
                return v
            if self.kind == "lag":
                return parse_lag(value)
        except PlanError:
            raise
        except (TypeError, ValueError):
            pass
        raise PlanError(f"option {self.name} wants a {self.kind}, "
                       f"got {value!r}")


class _OptionSchema:
    """Shared parse/validate machinery for one statement's option set."""

    specs: Dict[str, OptionSpec] = {}
    what = "option"

    @classmethod
    def parse(cls, raw: Optional[dict]):
        raw = dict(raw or {})
        unknown = set(raw) - set(cls.specs)
        if unknown:
            raise PlanError(
                f"unknown {cls.what}s: {sorted(unknown)}; valid {cls.what}s "
                f"are {', '.join(sorted(cls.specs))}")
        fields = {name: spec.coerce(raw[name]) if name in raw else spec.default
                  for name, spec in cls.specs.items()}
        return cls(**fields)

    def alter(self, raw: dict):
        """A new options object with the ALTER-able subset of `raw`
        applied; non-alterable options raise (they shape the engine at
        construction time and cannot be changed in place)."""
        raw = dict(raw or {})
        unknown = set(raw) - set(self.specs)
        if unknown:
            raise PlanError(
                f"unknown {self.what}s: {sorted(unknown)}; valid {self.what}s "
                f"are {', '.join(sorted(self.specs))}")
        frozen = [k for k in raw if not self.specs[k].alterable]
        if frozen:
            alterable = sorted(k for k, s in self.specs.items()
                               if s.alterable)
            raise PlanError(
                f"option(s) {sorted(frozen)} cannot be changed by ALTER "
                f"(they fix the engine at CREATE); alterable options are "
                f"{alterable}")
        changed = {k: self.specs[k].coerce(v) for k, v in raw.items()}
        return dataclasses.replace(self, **changed)


_VIEW_SPECS = [
    OptionSpec("policy", "choice", "eager", ("eager", "lazy", "hybrid")),
    OptionSpec("k", "int", None),
    OptionSpec("engine", "choice", None, ("hazy", "multiview", "sharded")),
    OptionSpec("buffer_frac", "float", None),
    OptionSpec("p", "float", 2.0),
    OptionSpec("q", "float", 2.0),
    OptionSpec("alpha", "float", 1.0),
    OptionSpec("lr", "float", 0.1),
    OptionSpec("l2", "float", 1e-4),
    OptionSpec("cost_mode", "choice", "measured", ("measured", "modeled")),
    OptionSpec("touch_ns", "float", 0.0),
    OptionSpec("cap_frac", "float", 0.5),
    OptionSpec("memory_budget", "budget", None),
    OptionSpec("page_bytes", "int", None),
    OptionSpec("prefetch", "flag", False),
    OptionSpec("target_lag", "lag", None, alterable=True),
]


@dataclasses.dataclass(frozen=True)
class ViewOptions(_OptionSchema):
    """Parsed `CREATE CLASSIFICATION VIEW ... WITH (...)` options."""

    policy: str = "eager"
    k: Optional[int] = None                 # default: table's num_classes
    engine: Optional[str] = None            # default: multiview iff k > 1
    buffer_frac: Optional[float] = None     # default: 0.01 iff hybrid
    p: float = 2.0
    q: float = 2.0
    alpha: float = 1.0
    lr: float = 0.1
    l2: float = 1e-4
    cost_mode: str = "measured"
    touch_ns: float = 0.0
    cap_frac: float = 0.5
    memory_budget: Optional[float] = None
    page_bytes: Optional[int] = None
    prefetch: bool = False
    target_lag: Optional[object] = None     # seconds | DOWNSTREAM | None

    specs = {s.name: s for s in _VIEW_SPECS}
    what = "view option"


_TABLE_SPECS = [
    OptionSpec("scale", "float", 0.1),
    OptionSpec("seed", "int", 0),
]


@dataclasses.dataclass(frozen=True)
class TableOptions(_OptionSchema):
    """Parsed `CREATE TABLE ... FROM CORPUS ... WITH (...)` options."""

    scale: float = 0.1
    seed: int = 0

    specs = {s.name: s for s in _TABLE_SPECS}
    what = "CREATE TABLE option"
