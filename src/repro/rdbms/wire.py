"""Wire format shared by the SQL server and client: length-prefixed JSON.

One frame = a 4-byte big-endian unsigned length followed by that many
bytes of UTF-8 JSON. JSON because every result row is scalars (ids,
labels, margins); length-prefixing because it needs no escaping, works on
any stream transport, and lets both sides read exactly one message
without a streaming parser.

Requests (client -> server), one object per frame:

  {"op": "query",   "sql": "<';'-separated statements>"}
  {"op": "execute", "name": "<prepared name>", "params": [..]}
  {"op": "ping"}
  {"op": "metrics"}
  {"op": "close"}

Responses (server -> client), one object per frame:

  {"ok": true,  "results": [{"columns": [...], "rows": [[...], ...],
                             "epoch": E, "plan": "...", "tiers": [...],
                             "elapsed_us": T, "phases": {"parse": ..}}],
   "session": S, "elapsed_us": T}
  {"ok": true,  "metrics": {"counters": .., "gauges": .., "histograms": ..,
                            "collectors": .., "epoch": E}, "session": S}
  {"ok": false, "error": "...", "error_type": "SqlError|..."}

`epoch` is the committed WAL batch index the statement was pinned at —
the snapshot version a reader observed, the post-commit index for DML.
`metrics` is the executor's unified telemetry snapshot (the same payload
`SHOW METRICS` flattens); per-result `elapsed_us`/`phases` come from the
statement's span tree, so the wire, EXPLAIN ANALYZE, and the REPL footer
all report one per-phase breakdown.
"""
from __future__ import annotations

import json
import struct

import numpy as np

# A result frame is bounded by LIMIT/row-count, not by n; 64 MiB is far
# above any legitimate frame and fails fast on a desynced stream.
MAX_FRAME = 64 * 1024 * 1024
_LEN = struct.Struct(">I")


class WireError(RuntimeError):
    pass


def _default(o):
    """JSON fallback for the numpy scalars engine rows carry."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def encode_frame(obj) -> bytes:
    payload = json.dumps(obj, default=_default,
                         separators=(",", ":")).encode()
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame of {len(payload)} bytes exceeds "
                        f"MAX_FRAME = {MAX_FRAME}")
    return _LEN.pack(len(payload)) + payload


def decode_payload(payload: bytes):
    return json.loads(payload.decode())


def frame_length(header: bytes) -> int:
    """Validate + decode the 4-byte length prefix."""
    (length,) = _LEN.unpack(header)
    if length > MAX_FRAME:
        raise WireError(f"frame length {length} exceeds MAX_FRAME = "
                        f"{MAX_FRAME} (desynced stream?)")
    return length


def recv_frame(sock):
    """Blocking read of one frame from a socket (client side); returns the
    decoded object or None on clean EOF at a frame boundary."""
    header = _recv_exact(sock, _LEN.size, eof_ok=True)
    if header is None:
        return None
    payload = _recv_exact(sock, frame_length(header))
    return decode_payload(payload)


def send_frame(sock, obj):
    sock.sendall(encode_frame(obj))


def _recv_exact(sock, n: int, *, eof_ok: bool = False):
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if eof_ok and not buf:
                return None
            raise WireError(f"connection closed mid-frame "
                            f"({len(buf)}/{n} bytes)")
        buf.extend(chunk)
    return bytes(buf)
