"""Catalog: base entity tables + the DAG of classification views.

A *base table* is an entity relation — the (n, d) feature rows plus the
ground-truth labels/classes a corpus carries (used only by examples and
benchmarks; the engines never see them). A *classification view* is a
model-based view registered on a base table: `CREATE CLASSIFICATION VIEW`
builds one of the three engine shells behind an `EngineFacade` —

  engine=hazy       k = 1 `ClassificationView` over `HazyEngine`
  engine=multiview  k one-vs-all views over ONE `MultiViewEngine` (default
                    whenever k > 1)
  engine=sharded    `ShardedMultiViewHazy` (device-resident shared order,
                    Pallas band kernel; eager only)

`CREATE CLASSIFICATION VIEW child ON parent` where `parent` is itself a
view registers a *derived* view: its feature table is the parent's margin
column (a `(n, 1)` float32 matrix), the edge lives in the catalog
(`ViewDef.upstreams` / `.downstreams` — this module is the only one that
touches those attributes directly; everyone else goes through
`topo_order` / `parents_of` / `children_of`, rule FRS001), and the
freshness scheduler refreshes the DAG in topological order.

WITH-options are parsed by the typed `ViewOptions` / `TableOptions`
schemas (`repro.rdbms.options`) — one spec per option, one coercion per
value type, unknown options raise listing the valid set. `memory_budget`
attaches the real storage tier (§3.5.2/Fig. 8 economics): the base
table's feature rows live in an on-disk `EntityStore` (one memory-mapped
file per table, SHARED by every budgeted view on it) and the view gets
its own `BufferPool` over those pages — values in (0, 1] are a fraction
of the entity table's bytes, values > 1 are bytes. `page_bytes` picks the
page geometry (default 8 KiB). `prefetch = on` attaches a background
`Prefetcher` to the pool. `target_lag` hands the view to the freshness
scheduler (`repro.scheduler`): commits queue in the view's inbox instead
of training synchronously, and the daemon refreshes it before staleness
exceeds the lag.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.facade import (DerivedViewFacade, EngineFacade,
                               MultiViewFacade, SingleViewFacade,
                               make_sharded_facade)
from repro.core.multiclass import MulticlassView
from repro.core.view import ClassificationView
from repro.obs import MetricsRegistry, clock as obs_clock
from repro.rdbms.ast_nodes import PlanError, SqlError
from repro.rdbms.options import DOWNSTREAM, TableOptions, ViewOptions
from repro.scheduler.state import ViewRuntime

__all__ = ["BaseTable", "Catalog", "PlanError", "SqlError", "ViewDef"]


@dataclasses.dataclass
class BaseTable:
    name: str
    features: np.ndarray                      # (n, d) float32
    truth: Optional[np.ndarray] = None        # ground-truth labels/classes
    num_classes: int = 2                      # 2 = binary (±1 labels)
    # on-disk entity stores, keyed by page_bytes — built lazily on the
    # first memory-budgeted view and SHARED by every pool on this table
    stores: Dict[int, object] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.features.shape[0]

    def entity_store(self, page_bytes: int):
        from repro.storage import EntityStore
        es = self.stores.get(int(page_bytes))
        if es is None:
            es = EntityStore.from_array(self.features, page_bytes=page_bytes)
            self.stores[int(page_bytes)] = es
        return es


@dataclasses.dataclass
class ViewDef:
    name: str
    table: str          # ROOT base table (derived views resolve through)
    model: str
    facade: EngineFacade
    options: ViewOptions
    source: Optional[str] = None   # parent VIEW name (derived views only)
    # DAG edges — only this module reads/writes these attributes (FRS001);
    # other modules use topo_order / parents_of / children_of / subtree_of
    upstreams: List[str] = dataclasses.field(default_factory=list)
    downstreams: List[str] = dataclasses.field(default_factory=list)
    # freshness ledger, mutated only inside repro.scheduler (FRS001)
    runtime: ViewRuntime = dataclasses.field(default_factory=ViewRuntime)


class Catalog:
    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.tables: Dict[str, BaseTable] = {}
        self.views: Dict[str, ViewDef] = {}
        # the catalog owns the process-wide registry: views register their
        # facade collectors here, pools record cold-read latencies into it,
        # and the executor adopts it for gate/WAL/span instruments.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        # freshness clock: staleness stamps and lag deadlines read THIS,
        # so tests (and the scheduler determinism suite) can swap in a
        # modeled clock. Measured-cost recording stays on the obs clock.
        self.clock = obs_clock

    # -- base tables ---------------------------------------------------
    def register_table(self, name: str, features: np.ndarray, *,
                       truth: Optional[np.ndarray] = None,
                       num_classes: int = 2) -> BaseTable:
        if name in self.tables:
            raise PlanError(f"table {name!r} already exists")
        t = BaseTable(name, np.ascontiguousarray(features, np.float32),
                      truth=truth, num_classes=int(num_classes))
        self.tables[name] = t
        return t

    def create_table_from_corpus(self, name: str, corpus: str,
                                 options: Optional[dict] = None) -> BaseTable:
        """`CREATE TABLE t FROM CORPUS c` — c is a repro.data factory."""
        import repro.data as data
        opts = (options if isinstance(options, TableOptions)
                else TableOptions.parse(options))
        if corpus in ("forest_like", "dblife_like", "citeseer_like"):
            c = getattr(data, corpus)(scale=opts.scale)
            return self.register_table(name, c.features, truth=c.labels)
        if corpus == "cora_like":
            c = data.cora_like(scale=opts.scale)
            return self.register_table(name, c.features, truth=c.classes,
                                       num_classes=c.num_classes)
        if corpus == "synthetic":
            c = data.synthetic_corpus("synthetic",
                                      max(256, int(4000 * opts.scale)),
                                      64, seed=opts.seed)
            return self.register_table(name, c.features, truth=c.labels)
        raise PlanError(f"unknown corpus {corpus!r}; have forest_like, "
                        f"dblife_like, citeseer_like, cora_like, synthetic")

    # -- classification views ------------------------------------------
    def create_view(self, name: str, table: str, model: str = "svm",
                    options: Optional[dict] = None) -> ViewDef:
        if name in self.views:
            raise PlanError(f"view {name!r} already exists")
        if model not in ("svm", "logistic"):
            raise PlanError(f"USING MODEL must be svm or logistic, "
                            f"got {model!r}")
        opts = (options if isinstance(options, ViewOptions)
                else ViewOptions.parse(options))
        if table == name or (table in self.views
                             and name in self._ancestors(table)):
            raise PlanError(f"view {name!r} ON {table!r} would create a "
                            f"cycle; classification views form a DAG")
        if table in self.views:
            return self._create_derived(name, table, model, opts)
        if table not in self.tables:
            raise PlanError(f"unknown table {table!r}")
        t = self.tables[table]

        k = opts.k if opts.k is not None else (
            t.num_classes if t.num_classes > 2 else 1)
        engine = opts.engine or ("multiview" if k > 1 else "hazy")
        buffer_frac = (opts.buffer_frac if opts.buffer_frac is not None
                       else (0.01 if opts.policy == "hybrid" else 0.0))

        store = None
        if opts.memory_budget is not None:
            if engine == "sharded":
                raise PlanError("memory_budget requires engine=hazy or "
                                "engine=multiview (the sharded engine keeps "
                                "its scratch table device-resident)")
            mb = float(opts.memory_budget)
            budget = int(mb * t.features.nbytes) if mb <= 1.0 else int(mb)
            from repro.storage import PAGE_BYTES, BufferPool
            store = BufferPool(t.entity_store(opts.page_bytes or PAGE_BYTES),
                               budget, metrics=self.metrics)
            if opts.prefetch:
                from repro.storage import Prefetcher
                Prefetcher(store)       # attaches itself as store.prefetcher
        elif opts.page_bytes is not None:
            raise PlanError("page_bytes only applies with memory_budget")
        elif opts.prefetch:
            raise PlanError("prefetch = on requires memory_budget (the "
                            "readahead worker feeds a buffer pool)")

        if model == "logistic" and engine != "hazy":
            # MulticlassView/ShardedFacade train hinge SVM only; a view
            # silently trained with the wrong loss is worse than an error
            raise PlanError("USING MODEL logistic requires engine=hazy "
                            "(k = 1); the multiview/sharded engines train "
                            "svm only")
        if engine == "hazy":
            if k != 1:
                raise PlanError("engine=hazy is single-view; use "
                                "engine=multiview for k > 1")
            cv = ClassificationView(
                t.features, method=model, policy=opts.policy,
                norm=(opts.p, opts.q), lr=opts.lr, l2=opts.l2,
                alpha=opts.alpha, buffer_frac=buffer_frac,
                cost_mode=opts.cost_mode, touch_ns=opts.touch_ns,
                store=store)
            facade: EngineFacade = SingleViewFacade(cv)
        elif engine == "multiview":
            mc = MulticlassView(
                t.features, k, policy=opts.policy, lr=opts.lr, l2=opts.l2,
                alpha=opts.alpha, p=opts.p, q=opts.q,
                cost_mode=opts.cost_mode, touch_ns=opts.touch_ns,
                buffer_frac=buffer_frac, vectorized=True, store=store)
            facade = MultiViewFacade(mc)
        else:                                   # engine == "sharded"
            if opts.policy != "eager":
                raise PlanError("engine=sharded maintains eagerly; "
                                "policy must be eager")
            facade = make_sharded_facade(t.features, k, p=opts.p, q=opts.q,
                                         lr=opts.lr, l2=opts.l2,
                                         alpha=opts.alpha,
                                         cap_frac=opts.cap_frac)
        return self._register_view(ViewDef(name, table, model, facade, opts))

    def _create_derived(self, name: str, parent_name: str, model: str,
                        opts: ViewOptions) -> ViewDef:
        """`CREATE CLASSIFICATION VIEW child ON parent` — a view whose
        feature table is the parent view's margin column."""
        parent = self.views[parent_name]
        if parent.facade.num_views != 1:
            raise PlanError(
                f"view {parent_name!r} has {parent.facade.num_views} "
                f"one-vs-all views; a derived view consumes a single "
                f"margin column — its parent must be a k = 1 view")
        if opts.k not in (None, 1):
            raise PlanError("derived views are single-view (k = 1): their "
                            "input is the parent's one margin column")
        if opts.engine not in (None, "hazy"):
            raise PlanError("derived views require engine=hazy (k = 1 over "
                            "the parent's margin column)")
        if (opts.memory_budget is not None or opts.page_bytes is not None
                or opts.prefetch):
            raise PlanError("derived views keep their (n, 1) margin column "
                            "in RAM; memory_budget/page_bytes/prefetch "
                            "apply to views ON a base table")
        buffer_frac = (opts.buffer_frac if opts.buffer_frac is not None
                       else (0.01 if opts.policy == "hybrid" else 0.0))
        feats = parent.facade.margins_of(np.arange(parent.facade.n))
        cv = ClassificationView(
            feats, method=model, policy=opts.policy, norm=(opts.p, opts.q),
            lr=opts.lr, l2=opts.l2, alpha=opts.alpha,
            buffer_frac=buffer_frac, cost_mode=opts.cost_mode,
            touch_ns=opts.touch_ns)
        facade = DerivedViewFacade(cv, parent_name)
        vd = ViewDef(name, parent.table, model, facade, opts,
                     source=parent_name, upstreams=[parent_name],
                     runtime=ViewRuntime(
                         upstream_version_seen=parent.runtime.version))
        parent.downstreams.append(name)
        return self._register_view(vd)

    def _register_view(self, vd: ViewDef) -> ViewDef:
        self.views[vd.name] = vd
        self.metrics.register_collector(f"view.{vd.name}",
                                        vd.facade.telemetry_snapshot)
        return vd

    def alter_view_options(self, name: str, options: dict) -> ViewDef:
        """`ALTER VIEW v SET (...)` — typed-schema validated; only options
        marked alterable (today: target_lag) may change post-CREATE."""
        vd = self.view(name)
        vd.options = vd.options.alter(options)
        return vd

    # -- lookups -------------------------------------------------------
    def table(self, name: str) -> BaseTable:
        if name not in self.tables:
            raise PlanError(f"unknown table {name!r}")
        return self.tables[name]

    def view(self, name: str) -> ViewDef:
        if name not in self.views:
            raise PlanError(f"unknown view {name!r}")
        return self.views[name]

    def views_on(self, table: str) -> List[ViewDef]:
        return [v for v in self.views.values() if v.table == table]

    # -- the view DAG (sole owner of the edge attributes — FRS001) -----
    def parents_of(self, name: str) -> List[ViewDef]:
        return [self.views[u] for u in self.view(name).upstreams]

    def children_of(self, name: str) -> List[ViewDef]:
        return [self.views[d] for d in self.view(name).downstreams]

    def _ancestors(self, name: str) -> List[str]:
        out: List[str] = []
        vd = self.views.get(name)
        while vd is not None and vd.source is not None:
            out.append(vd.source)
            vd = self.views.get(vd.source)
        return out

    def topo_order(self) -> List[ViewDef]:
        """Every view, parents before children; deterministic (catalog
        insertion order among independents). THE refresh order — modules
        that need one consume this instead of re-deriving it (FRS001)."""
        out: List[ViewDef] = []
        seen: set = set()

        def visit(vd: ViewDef) -> None:
            if vd.name in seen:
                return
            seen.add(vd.name)
            for u in vd.upstreams:
                visit(self.views[u])
            out.append(vd)

        for vd in self.views.values():
            visit(vd)
        # children can be visited before unrelated roots; re-sort stably
        # by dependency depth to keep parents strictly first
        rank: Dict[str, int] = {}

        def depth(vd: ViewDef) -> int:
            if vd.name not in rank:
                rank[vd.name] = 1 + max(
                    (depth(self.views[u]) for u in vd.upstreams), default=-1)
            return rank[vd.name]

        return sorted(out, key=depth)

    def subtree_of(self, roots: List[ViewDef]) -> List[ViewDef]:
        """`roots` plus every (transitive) derived consumer, topo order."""
        want: set = set()

        def walk(vd: ViewDef) -> None:
            if vd.name in want:
                return
            want.add(vd.name)
            for d in vd.downstreams:
                walk(self.views[d])

        for vd in roots:
            walk(vd)
        return [vd for vd in self.topo_order() if vd.name in want]

    def effective_lag(self, name: str) -> Optional[float]:
        """Resolve a view's freshness target: a declared number of seconds
        stands; `downstream` takes the tightest effective lag among the
        view's consumers; None (or `downstream` with no numeric consumer)
        means the view is maintained at commit time — immediate."""
        vd = self.view(name)
        lag = vd.options.target_lag
        if lag is None:
            return None
        if lag != DOWNSTREAM:
            return float(lag)
        lags = [self.effective_lag(d) for d in vd.downstreams]
        lags = [v for v in lags if v is not None]
        return min(lags) if lags else None

    def deliver_group(self, table: str, group) -> None:
        """One committed WAL group -> the table's view DAG (the scheduler
        package owns delivery semantics; the WAL just hands over)."""
        from repro.scheduler import refresh as _refresh
        _refresh.deliver_group(self, table, group)
