"""Catalog: base entity tables + registered classification views.

A *base table* is an entity relation — the (n, d) feature rows plus the
ground-truth labels/classes a corpus carries (used only by examples and
benchmarks; the engines never see them). A *classification view* is a
model-based view registered on a base table: `CREATE CLASSIFICATION VIEW`
builds one of the three engine shells behind an `EngineFacade` —

  engine=hazy       k = 1 `ClassificationView` over `HazyEngine`
  engine=multiview  k one-vs-all views over ONE `MultiViewEngine` (default
                    whenever k > 1)
  engine=sharded    `ShardedMultiViewHazy` (device-resident shared order,
                    Pallas band kernel; eager only)

WITH-options map straight onto the engine ctor knobs: policy (eager/lazy/
hybrid), k, buffer_frac, p, q, alpha, lr, l2, cost_mode (measured/modeled),
touch_ns. Unknown options raise instead of being silently dropped.

`memory_budget` attaches the real storage tier (§3.5.2/Fig. 8 economics):
the base table's feature rows live in an on-disk `EntityStore` (one
memory-mapped file per table, SHARED by every budgeted view on it) and
the view gets its own `BufferPool` over those pages — values in (0, 1]
are a fraction of the entity table's bytes, values > 1 are bytes.
`page_bytes` picks the page geometry (default 8 KiB). `prefetch = on`
attaches a background `Prefetcher` to the pool: reorganize warm-ups and
band-scan readahead run on its worker thread, overlapping serving (cold
reads already run off the pool lock either way). `SHOW STORAGE` renders
each view's pool residency and hit/miss/eviction/coalescing/readahead
counters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.facade import (EngineFacade, MultiViewFacade,
                               SingleViewFacade, make_sharded_facade)
from repro.core.multiclass import MulticlassView
from repro.core.view import ClassificationView
from repro.obs import MetricsRegistry
from repro.rdbms.ast_nodes import SqlError


class PlanError(SqlError):
    pass


@dataclasses.dataclass
class BaseTable:
    name: str
    features: np.ndarray                      # (n, d) float32
    truth: Optional[np.ndarray] = None        # ground-truth labels/classes
    num_classes: int = 2                      # 2 = binary (±1 labels)
    # on-disk entity stores, keyed by page_bytes — built lazily on the
    # first memory-budgeted view and SHARED by every pool on this table
    stores: Dict[int, object] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.features.shape[0]

    def entity_store(self, page_bytes: int):
        from repro.storage import EntityStore
        es = self.stores.get(int(page_bytes))
        if es is None:
            es = EntityStore.from_array(self.features, page_bytes=page_bytes)
            self.stores[int(page_bytes)] = es
        return es


@dataclasses.dataclass
class ViewDef:
    name: str
    table: str
    model: str
    facade: EngineFacade
    options: dict


_VIEW_OPTIONS = {"policy", "k", "engine", "buffer_frac", "p", "q", "alpha",
                 "lr", "l2", "cost_mode", "touch_ns", "cap_frac",
                 "memory_budget", "page_bytes", "prefetch"}


class Catalog:
    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.tables: Dict[str, BaseTable] = {}
        self.views: Dict[str, ViewDef] = {}
        # the catalog owns the process-wide registry: views register their
        # facade collectors here, pools record cold-read latencies into it,
        # and the executor adopts it for gate/WAL/span instruments.
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    # -- base tables ---------------------------------------------------
    def register_table(self, name: str, features: np.ndarray, *,
                       truth: Optional[np.ndarray] = None,
                       num_classes: int = 2) -> BaseTable:
        if name in self.tables:
            raise PlanError(f"table {name!r} already exists")
        t = BaseTable(name, np.ascontiguousarray(features, np.float32),
                      truth=truth, num_classes=int(num_classes))
        self.tables[name] = t
        return t

    def create_table_from_corpus(self, name: str, corpus: str,
                                 options: Optional[dict] = None) -> BaseTable:
        """`CREATE TABLE t FROM CORPUS c` — c is a repro.data factory."""
        import repro.data as data
        opts = dict(options or {})
        scale = float(opts.pop("scale", 0.1))
        seed = int(opts.pop("seed", 0))
        if opts:
            raise PlanError(f"unknown CREATE TABLE options: {sorted(opts)}")
        if corpus in ("forest_like", "dblife_like", "citeseer_like"):
            c = getattr(data, corpus)(scale=scale)
            return self.register_table(name, c.features, truth=c.labels)
        if corpus == "cora_like":
            c = data.cora_like(scale=scale)
            return self.register_table(name, c.features, truth=c.classes,
                                       num_classes=c.num_classes)
        if corpus == "synthetic":
            c = data.synthetic_corpus("synthetic", max(256, int(4000 * scale)),
                                      64, seed=seed)
            return self.register_table(name, c.features, truth=c.labels)
        raise PlanError(f"unknown corpus {corpus!r}; have forest_like, "
                        f"dblife_like, citeseer_like, cora_like, synthetic")

    # -- classification views ------------------------------------------
    def create_view(self, name: str, table: str, model: str = "svm",
                    options: Optional[dict] = None) -> ViewDef:
        if name in self.views:
            raise PlanError(f"view {name!r} already exists")
        if table not in self.tables:
            raise PlanError(f"unknown table {table!r}")
        if model not in ("svm", "logistic"):
            raise PlanError(f"USING MODEL must be svm or logistic, "
                            f"got {model!r}")
        t = self.tables[table]
        opts = dict(options or {})
        unknown = set(opts) - _VIEW_OPTIONS
        if unknown:
            raise PlanError(f"unknown view options: {sorted(unknown)}")
        k = int(opts.pop("k", t.num_classes if t.num_classes > 2 else 1))
        engine = opts.pop("engine", "multiview" if k > 1 else "hazy")
        policy = opts.pop("policy", "eager")
        if policy not in ("eager", "lazy", "hybrid"):
            raise PlanError(f"policy must be eager/lazy/hybrid, got "
                            f"{policy!r}")
        p = float(opts.pop("p", 2.0))
        q = float(opts.pop("q", 2.0))
        alpha = float(opts.pop("alpha", 1.0))
        lr = float(opts.pop("lr", 0.1))
        l2 = float(opts.pop("l2", 1e-4))
        buffer_frac = float(opts.pop("buffer_frac",
                                     0.01 if policy == "hybrid" else 0.0))
        cost_mode = opts.pop("cost_mode", "measured")
        touch_ns = float(opts.pop("touch_ns", 0.0))
        cap_frac = float(opts.pop("cap_frac", 0.5))
        memory_budget = opts.pop("memory_budget", None)
        page_bytes = int(opts.pop("page_bytes", 0)) or None
        # parser delivers numbers as floats ("1" -> "1.0") and bare
        # identifiers as strings ("on")
        prefetch = str(opts.pop("prefetch", "off")).lower() in (
            "on", "true", "1", "1.0")

        store = None
        if memory_budget is not None:
            if engine == "sharded":
                raise PlanError("memory_budget requires engine=hazy or "
                                "engine=multiview (the sharded engine keeps "
                                "its scratch table device-resident)")
            mb = float(memory_budget)
            if mb <= 0:
                raise PlanError("memory_budget must be positive (a fraction "
                                "in (0, 1] of the entity table, or bytes)")
            budget = int(mb * t.features.nbytes) if mb <= 1.0 else int(mb)
            from repro.storage import PAGE_BYTES, BufferPool
            store = BufferPool(t.entity_store(page_bytes or PAGE_BYTES),
                               budget, metrics=self.metrics)
            if prefetch:
                from repro.storage import Prefetcher
                Prefetcher(store)       # attaches itself as store.prefetcher
        elif page_bytes is not None:
            raise PlanError("page_bytes only applies with memory_budget")
        elif prefetch:
            raise PlanError("prefetch = on requires memory_budget (the "
                            "readahead worker feeds a buffer pool)")

        if model == "logistic" and engine != "hazy":
            # MulticlassView/ShardedFacade train hinge SVM only; a view
            # silently trained with the wrong loss is worse than an error
            raise PlanError("USING MODEL logistic requires engine=hazy "
                            "(k = 1); the multiview/sharded engines train "
                            "svm only")
        if engine == "hazy":
            if k != 1:
                raise PlanError("engine=hazy is single-view; use "
                                "engine=multiview for k > 1")
            cv = ClassificationView(
                t.features, method=model, policy=policy, norm=(p, q),
                lr=lr, l2=l2, alpha=alpha, buffer_frac=buffer_frac,
                cost_mode=cost_mode, touch_ns=touch_ns, store=store)
            facade: EngineFacade = SingleViewFacade(cv)
        elif engine == "multiview":
            mc = MulticlassView(
                t.features, k, policy=policy, lr=lr, l2=l2, alpha=alpha,
                p=p, q=q, cost_mode=cost_mode, touch_ns=touch_ns,
                buffer_frac=buffer_frac, vectorized=True, store=store)
            facade = MultiViewFacade(mc)
        elif engine == "sharded":
            if policy != "eager":
                raise PlanError("engine=sharded maintains eagerly; "
                                "policy must be eager")
            facade = make_sharded_facade(t.features, k, p=p, q=q, lr=lr,
                                         l2=l2, alpha=alpha,
                                         cap_frac=cap_frac)
        else:
            raise PlanError(f"engine must be hazy/multiview/sharded, "
                            f"got {engine!r}")
        vd = ViewDef(name, table, model, facade, dict(options or {}))
        self.views[name] = vd
        self.metrics.register_collector(f"view.{name}",
                                        facade.telemetry_snapshot)
        return vd

    # -- lookups -------------------------------------------------------
    def table(self, name: str) -> BaseTable:
        if name not in self.tables:
            raise PlanError(f"unknown table {name!r}")
        return self.tables[name]

    def view(self, name: str) -> ViewDef:
        if name not in self.views:
            raise PlanError(f"unknown view {name!r}")
        return self.views[name]

    def views_on(self, table: str) -> List[ViewDef]:
        return [v for v in self.views.values() if v.table == table]
