"""Recursive-descent parser: token stream -> statement AST.

Grammar (keywords case-insensitive, statements `;`-separated):

  CREATE TABLE t FROM CORPUS name [WITH (opt = val, ...)]
  CREATE CLASSIFICATION VIEW v ON t USING MODEL svm [WITH (opt = val, ...)]
        (ON may name another classification view: a derived view over its
         margin column — the freshness DAG edge)
  ALTER VIEW v SUSPEND | RESUME | REFRESH | SET (opt = val, ...)
  INSERT INTO t [(id, label)] VALUES (i, y) [, (i, y) ...]
  UPDATE t SET label = y WHERE id = i
  UPDATE MODEL ON v
  DELETE FROM t WHERE id = i
  COMMIT
  SELECT cols | COUNT(*) FROM v [WHERE pred [AND pred ...]]
         [ORDER BY margin [ASC|DESC]] [LIMIT n]
  EXPLAIN [ANALYZE] <any statement>
  SHOW TABLES | SHOW VIEWS | SHOW STORAGE | SHOW METRICS | SHOW SCHEDULE
       | SHOW COST ON v
  PREPARE p AS <statement with ? placeholders>
  EXECUTE p [(v1, v2, ...)]

  cols: * | id | view | label | margin | class  (comma-separated)
  pred: id = i | id IN (i, ...) | label = ±1 | class = c | view = v
  Inside PREPARE, any number position in a predicate / LIMIT / SET may be
  a `?` placeholder (numbered left to right); EXECUTE binds them.
"""
from __future__ import annotations

from typing import List, Optional

from repro.rdbms.ast_nodes import (AlterView, Commit, CreateTable,
                                   CreateView, Delete, ExecutePrepared,
                                   Explain, Insert, Param, Prepare, Select,
                                   Show, SqlError, Statement, Update,
                                   UpdateModel, Where)
from repro.rdbms.lexer import Token, tokenize
from repro.rdbms.options import coerce_number

COLUMNS = ("id", "view", "label", "margin", "class")


class ParseError(SqlError):
    pass


def _num(text: str) -> float:
    return float(text)


class _Parser:
    def __init__(self, tokens: List[Token]):
        self.toks = tokens
        self.i = 0
        self._prepare_depth = 0      # > 0 while parsing a PREPARE body
        self._n_params = 0           # ? placeholders seen in that body

    # -- token helpers -------------------------------------------------
    def peek(self) -> Token:
        return self.toks[self.i]

    def next(self) -> Token:
        t = self.toks[self.i]
        if t.kind != "END":
            self.i += 1
        return t

    def at_kw(self, *words: str) -> bool:
        t = self.peek()
        return t.kind == "KW" and t.value in words

    def expect_kw(self, word: str) -> Token:
        t = self.next()
        if t.kind != "KW" or t.value != word:
            raise ParseError(f"expected {word.upper()} at {t.pos}, got "
                             f"{t.value or 'end of input'!r}")
        return t

    def expect_punct(self, ch: str) -> Token:
        t = self.next()
        if t.kind != "PUNCT" or t.value != ch:
            raise ParseError(f"expected {ch!r} at {t.pos}, got "
                             f"{t.value or 'end of input'!r}")
        return t

    def expect_name(self) -> str:
        t = self.next()
        if t.kind not in ("IDENT", "KW", "STRING"):
            raise ParseError(f"expected a name at {t.pos}, got {t.value!r}")
        return t.value

    def expect_number(self) -> float:
        t = self.next()
        if t.kind != "NUMBER":
            raise ParseError(f"expected a number at {t.pos}, got {t.value!r}")
        return _num(t.value)

    def maybe_punct(self, ch: str) -> bool:
        if self.peek().kind == "PUNCT" and self.peek().value == ch:
            self.i += 1
            return True
        return False

    def number_or_param(self):
        """A literal number, or (inside PREPARE only) a `?` placeholder."""
        t = self.peek()
        if t.kind == "PUNCT" and t.value == "?":
            self.next()
            if not self._prepare_depth:
                raise ParseError(f"'?' placeholder outside PREPARE at {t.pos}")
            p = Param(self._n_params)
            self._n_params += 1
            return p
        return self.expect_number()

    @staticmethod
    def _as_int(v):
        return v if isinstance(v, Param) else int(v)

    # -- grammar -------------------------------------------------------
    def statements(self) -> List[Statement]:
        out: List[Statement] = []
        while self.peek().kind != "END":
            if self.maybe_punct(";"):
                continue
            out.append(self.statement())
            if self.peek().kind != "END":
                self.expect_punct(";")
        return out

    def statement(self) -> Statement:
        t = self.peek()
        if t.kind != "KW":
            raise ParseError(f"expected a statement at {t.pos}, got "
                             f"{t.value!r}")
        if t.value == "create":
            return self.create()
        if t.value == "alter":
            return self.alter()
        if t.value == "insert":
            return self.insert()
        if t.value == "update":
            return self.update()
        if t.value == "delete":
            return self.delete()
        if t.value == "commit":
            self.next()
            return Commit()
        if t.value == "select":
            return self.select()
        if t.value == "explain":
            self.next()
            analyze = False
            if self.at_kw("analyze"):
                self.next()
                analyze = True
            return Explain(self.statement(), analyze=analyze)
        if t.value == "show":
            self.next()
            what = self.next()
            if what.value == "cost":
                self.expect_kw("on")
                return Show("cost", view=self.expect_name())
            if what.value not in ("tables", "views", "storage", "metrics",
                                  "schedule"):
                raise ParseError(f"SHOW TABLES, SHOW VIEWS, SHOW STORAGE, "
                                 f"SHOW METRICS, SHOW SCHEDULE or "
                                 f"SHOW COST ON <view>, got {what.value!r}")
            return Show(what.value)
        if t.value == "prepare":
            return self.prepare()
        if t.value == "execute":
            return self.execute_prepared()
        raise ParseError(f"unknown statement {t.value!r} at {t.pos}")

    def alter(self) -> AlterView:
        self.expect_kw("alter")
        self.expect_kw("view")
        name = self.expect_name()
        t = self.next()
        if t.kind == "KW" and t.value in ("suspend", "resume", "refresh"):
            return AlterView(name, t.value)
        if t.kind == "KW" and t.value == "set":
            return AlterView(name, "set", self.options_body())
        raise ParseError(f"ALTER VIEW wants SUSPEND, RESUME, REFRESH or "
                         f"SET (...) at {t.pos}, got {t.value!r}")

    def prepare(self) -> Prepare:
        self.expect_kw("prepare")
        name = self.expect_name()
        self.expect_kw("as")
        self._prepare_depth += 1
        self._n_params = 0
        try:
            inner = self.statement()
        finally:
            self._prepare_depth -= 1
        if isinstance(inner, (Prepare, ExecutePrepared)):
            raise ParseError("cannot PREPARE a PREPARE/EXECUTE statement")
        return Prepare(name, inner, self._n_params)

    def execute_prepared(self) -> ExecutePrepared:
        self.expect_kw("execute")
        name = self.expect_name()
        params: List[float] = []
        if self.maybe_punct("("):
            params.append(self.expect_number())
            while self.maybe_punct(","):
                params.append(self.expect_number())
            self.expect_punct(")")
        return ExecutePrepared(name, params)

    def with_options(self) -> dict:
        if not self.at_kw("with"):
            return {}
        self.next()
        return self.options_body()

    def options_body(self) -> dict:
        """`(key = value, ...)` — shared by WITH and ALTER ... SET. Values
        stay RAW here (number/identifier/string); the typed schemas in
        `repro.rdbms.options` own all per-option validation, the parser
        only applies the dialect-wide number coercion."""
        opts: dict = {}
        self.expect_punct("(")
        while True:
            key = self.expect_name()
            self.expect_punct("=")
            t = self.next()
            if t.kind == "NUMBER":
                opts[key] = coerce_number(_num(t.value))
            elif t.kind in ("IDENT", "KW", "STRING"):
                opts[key] = t.value
            else:
                raise ParseError(f"bad option value at {t.pos}")
            if not self.maybe_punct(","):
                break
        self.expect_punct(")")
        return opts

    def create(self) -> Statement:
        self.expect_kw("create")
        if self.at_kw("table"):
            self.next()
            name = self.expect_name()
            self.expect_kw("from")
            self.expect_kw("corpus")
            corpus = self.expect_name()
            return CreateTable(name, corpus, self.with_options())
        self.expect_kw("classification")
        self.expect_kw("view")
        name = self.expect_name()
        self.expect_kw("on")
        table = self.expect_name()
        self.expect_kw("using")
        self.expect_kw("model")
        model = self.expect_name()
        return CreateView(name, table, model, self.with_options())

    def insert(self) -> Insert:
        self.expect_kw("insert")
        self.expect_kw("into")
        table = self.expect_name()
        if self.maybe_punct("("):       # optional (id, label) column list
            c1, = (self.expect_name(),)
            self.expect_punct(",")
            c2 = self.expect_name()
            self.expect_punct(")")
            if (c1, c2) not in (("id", "label"), ("id", "class")):
                raise ParseError(
                    f"INSERT columns must be (id, label) or (id, class), "
                    f"got ({c1}, {c2})")
        self.expect_kw("values")
        # tight loop over the '(' NUMBER ',' NUMBER ')' tuples — this is
        # the batched-DML hot path (the front-end overhead the benchmarks
        # gate on); malformed input rewinds into the expect_* helpers for
        # their error messages
        toks, j = self.toks, self.i
        rows = []
        while True:
            chunk = toks[j:j + 5]
            if (len(chunk) == 5 and chunk[0].value == "("
                    and chunk[1].kind == "NUMBER" and chunk[2].value == ","
                    and chunk[3].kind == "NUMBER" and chunk[4].value == ")"):
                rows.append((int(float(chunk[1].value)),
                             float(chunk[3].value)))
                j += 5
            else:
                self.i = j
                self.expect_punct("(")
                i = self.expect_number()
                self.expect_punct(",")
                y = self.expect_number()
                self.expect_punct(")")
                rows.append((int(i), float(y)))
                j = self.i
            t = toks[j]
            if t.kind == "PUNCT" and t.value == ",":
                j += 1
                continue
            break
        self.i = j
        return Insert(table, rows)

    def update(self) -> Statement:
        self.expect_kw("update")
        if self.at_kw("model"):         # UPDATE MODEL ON v
            self.next()
            self.expect_kw("on")
            return UpdateModel(self.expect_name())
        table = self.expect_name()
        self.expect_kw("set")
        col = self.expect_name()
        if col not in ("label", "class"):
            raise ParseError(f"can only SET label/class, got {col!r}")
        self.expect_punct("=")
        y = self.number_or_param()
        self.expect_kw("where")
        idcol = self.expect_name()
        if idcol != "id":
            raise ParseError(f"UPDATE needs WHERE id = n, got {idcol!r}")
        self.expect_punct("=")
        i = self.number_or_param()
        return Update(table, self._as_int(i),
                      y if isinstance(y, Param) else float(y))

    def delete(self) -> Delete:
        self.expect_kw("delete")
        self.expect_kw("from")
        table = self.expect_name()
        self.expect_kw("where")
        idcol = self.expect_name()
        if idcol != "id":
            raise ParseError(f"DELETE needs WHERE id = n, got {idcol!r}")
        self.expect_punct("=")
        return Delete(table, self._as_int(self.number_or_param()))

    def select(self) -> Select:
        self.expect_kw("select")
        count = False
        columns: List[str] = []
        if self.at_kw("count"):
            self.next()
            self.expect_punct("(")
            self.expect_punct("*")
            self.expect_punct(")")
            count = True
        elif self.maybe_punct("*"):
            columns = ["id", "label"]
        else:
            while True:
                col = self.expect_name()
                if col not in COLUMNS:
                    raise ParseError(
                        f"unknown column {col!r}; columns are "
                        f"{', '.join(COLUMNS)}")
                columns.append(col)
                if not self.maybe_punct(","):
                    break
        self.expect_kw("from")
        view = self.expect_name()
        where = self.where() if self.at_kw("where") else None
        order_by, desc = None, True
        if self.at_kw("order"):
            self.next()
            self.expect_kw("by")
            order_by = self.expect_name()
            if order_by != "margin":
                raise ParseError(f"can only ORDER BY margin, got {order_by!r}")
            if self.at_kw("asc"):
                self.next()
                desc = False
            elif self.at_kw("desc"):
                self.next()
        limit: Optional[int] = None
        if self.at_kw("limit"):
            self.next()
            limit = self._as_int(self.number_or_param())
        return Select(view, columns, count=count, where=where,
                      order_by=order_by, descending=desc, limit=limit)

    def where(self) -> Where:
        self.expect_kw("where")
        w = Where()
        while True:
            col = self.expect_name()
            if col == "id":
                if self.at_kw("in"):
                    self.next()
                    self.expect_punct("(")
                    ids = [self._as_int(self.number_or_param())]
                    while self.maybe_punct(","):
                        ids.append(self._as_int(self.number_or_param()))
                    self.expect_punct(")")
                    w.ids = ids
                else:
                    self.expect_punct("=")
                    w.ids = [self._as_int(self.number_or_param())]
            elif col == "label":
                self.expect_punct("=")
                w.label = self._as_int(self.number_or_param())
                if not isinstance(w.label, Param) and w.label not in (1, -1):
                    raise ParseError("label predicate must be 1 or -1")
            elif col == "class":
                self.expect_punct("=")
                w.cls = self._as_int(self.number_or_param())
            elif col == "view":
                self.expect_punct("=")
                w.view = self._as_int(self.number_or_param())
            else:
                raise ParseError(f"unsupported predicate column {col!r}")
            if not self.at_kw("and"):
                break
            self.next()
        return w


def parse(sql: str) -> List[Statement]:
    """Parse a `;`-separated script into a list of statements."""
    return _Parser(tokenize(sql)).statements()


def parse_one(sql: str) -> Statement:
    stmts = parse(sql)
    if len(stmts) != 1:
        raise ParseError(f"expected one statement, got {len(stmts)}")
    return stmts[0]
