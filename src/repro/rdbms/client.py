"""Blocking SQL client for the wire protocol (`repro.rdbms.wire`).

One `SqlClient` == one server session (its own prepared-statement cache
server-side). The API mirrors the Executor surface the REPL uses:

    with SqlClient.connect(host, port) as c:
        c.query("CREATE TABLE papers FROM CORPUS cora_like; ...")
        c.prepare("pt", "SELECT label FROM topics WHERE id = ? AND view = ?")
        rows = c.execute("pt", [17, 3]).rows

Every call is a strict request/response round trip (closed loop), so a
session's statements are totally ordered — which is exactly what makes
read-your-writes meaningful at the protocol level.

`ServerError` carries the server-side error string; transport problems
raise `WireError`.
"""
from __future__ import annotations

import dataclasses
import socket
from typing import List, Optional, Sequence

from repro.rdbms.wire import recv_frame, send_frame, WireError


class ServerError(RuntimeError):
    def __init__(self, message: str, error_type: str = "SqlError"):
        super().__init__(message)
        self.error_type = error_type

    def __str__(self) -> str:
        return f"{self.error_type}: {super().__str__()}"


@dataclasses.dataclass
class ClientResult:
    columns: List[str]
    rows: List[list]
    epoch: Optional[int] = None
    plan: Optional[dict] = None
    tiers: Optional[List[str]] = None
    elapsed_us: Optional[float] = None      # span-derived statement time
    phases: Optional[dict] = None           # {span name: µs} top-level phases

    def __iter__(self):
        return iter(self.rows)

    @staticmethod
    def from_payload(p: dict) -> "ClientResult":
        return ClientResult(p.get("columns", []), p.get("rows", []),
                            p.get("epoch"), p.get("plan"), p.get("tiers"),
                            p.get("elapsed_us"), p.get("phases"))


class SqlClient:
    def __init__(self, sock: socket.socket):
        self._sock = sock
        self.session_id: Optional[int] = None
        self.last_elapsed_us: Optional[float] = None

    @classmethod
    def connect(cls, host: str, port: int,
                timeout: Optional[float] = 30.0) -> "SqlClient":
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return cls(sock)

    # -- protocol round trips ------------------------------------------
    def request(self, obj: dict) -> dict:
        send_frame(self._sock, obj)
        response = recv_frame(self._sock)
        if response is None:
            raise WireError("server closed the connection")
        self.session_id = response.get("session", self.session_id)
        if not response.get("ok"):
            raise ServerError(response.get("error", "unknown server error"),
                              response.get("error_type", "SqlError"))
        self.last_elapsed_us = response.get("elapsed_us")
        return response

    def query(self, sql: str) -> List[ClientResult]:
        response = self.request({"op": "query", "sql": sql})
        return [ClientResult.from_payload(p)
                for p in response.get("results", [])]

    def query_one(self, sql: str) -> ClientResult:
        results = self.query(sql)
        if len(results) != 1:
            raise ServerError(f"expected one result, got {len(results)}")
        return results[0]

    def prepare(self, name: str, sql: str) -> ClientResult:
        return self.query_one(f"PREPARE {name} AS {sql.rstrip(';')}")

    def execute(self, name: str,
                params: Sequence[float] = ()) -> ClientResult:
        response = self.request({"op": "execute", "name": name,
                                 "params": list(params)})
        return ClientResult.from_payload(response["results"][0])

    def ping(self) -> int:
        """Round trip; returns the server's current epoch."""
        return self.request({"op": "ping"})["epoch"]

    def metrics(self) -> dict:
        """The server's unified telemetry snapshot (counters, gauges,
        histograms, per-component collectors, epoch) as plain JSON."""
        return self.request({"op": "metrics"})["metrics"]

    def close(self):
        if self._sock is not None:
            try:
                send_frame(self._sock, {"op": "close"})
                recv_frame(self._sock)
            except (OSError, WireError):
                pass
            finally:
                self._sock.close()
                self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
