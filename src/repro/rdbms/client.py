"""Blocking SQL client for the wire protocol (`repro.rdbms.wire`).

One `SqlClient` == one server session (its own prepared-statement cache
server-side). The canonical surface mirrors the redesigned DDL/ALTER
statements one-to-one:

    with SqlClient.connect(host, port) as c:
        c.run("CREATE TABLE papers FROM CORPUS cora_like; ...")
        c.prepare("pt", "SELECT label FROM topics WHERE id = ? AND view = ?")
        rows = c.run_prepared("pt", [17, 3]).rows
        c.alter_view("slow", target_lag="5 s")   # ALTER VIEW ... SET (...)
        c.suspend("slow"); c.resume("slow")
        c.refresh()                              # freshness barrier
        for row in c.show("schedule"):           # typed rows
            print(row.view, row.state, row.staleness_s)

`query` / `query_one` / `execute` are the legacy spellings — thin
deprecated wrappers that emit byte-identical wire frames (a test pins
that), kept so embedders written against the old surface keep working.

Every call is a strict request/response round trip (closed loop), so a
session's statements are totally ordered — which is exactly what makes
read-your-writes meaningful at the protocol level.

`ServerError` carries the server-side error string; transport problems
raise `WireError`.
"""
from __future__ import annotations

import dataclasses
import socket
import warnings
from collections import namedtuple
from typing import List, Optional, Sequence

from repro.rdbms.wire import recv_frame, send_frame, WireError


class ServerError(RuntimeError):
    def __init__(self, message: str, error_type: str = "SqlError"):
        super().__init__(message)
        self.error_type = error_type

    def __str__(self) -> str:
        return f"{self.error_type}: {super().__str__()}"


@dataclasses.dataclass
class ClientResult:
    columns: List[str]
    rows: List[list]
    epoch: Optional[int] = None
    plan: Optional[dict] = None
    tiers: Optional[List[str]] = None
    elapsed_us: Optional[float] = None      # span-derived statement time
    phases: Optional[dict] = None           # {span name: µs} top-level phases

    def __iter__(self):
        return iter(self.rows)

    @staticmethod
    def from_payload(p: dict) -> "ClientResult":
        return ClientResult(p.get("columns", []), p.get("rows", []),
                            p.get("epoch"), p.get("plan"), p.get("tiers"),
                            p.get("elapsed_us"), p.get("phases"))

    def typed_rows(self) -> list:
        """The rows as namedtuples keyed by the result's column names."""
        row_t = namedtuple("Row", self.columns, rename=True)
        return [row_t(*r) for r in self.rows]


def _option_sql(value) -> str:
    """Render one option value for `SET (k = v)`: numbers bare, flags as
    on/off, strings quoted (a target_lag like '5 s' needs the quotes)."""
    if isinstance(value, bool):
        return "on" if value else "off"
    if isinstance(value, (int, float)):
        return repr(value)
    return "'" + str(value).replace("'", "") + "'"


class SqlClient:
    def __init__(self, sock: socket.socket):
        self._sock = sock
        self.session_id: Optional[int] = None
        self.last_elapsed_us: Optional[float] = None

    @classmethod
    def connect(cls, host: str, port: int,
                timeout: Optional[float] = 30.0) -> "SqlClient":
        sock = socket.create_connection((host, port), timeout=timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return cls(sock)

    # -- protocol round trips ------------------------------------------
    def request(self, obj: dict) -> dict:
        send_frame(self._sock, obj)
        response = recv_frame(self._sock)
        if response is None:
            raise WireError("server closed the connection")
        self.session_id = response.get("session", self.session_id)
        if not response.get("ok"):
            raise ServerError(response.get("error", "unknown server error"),
                              response.get("error_type", "SqlError"))
        self.last_elapsed_us = response.get("elapsed_us")
        return response

    def run(self, sql: str) -> List[ClientResult]:
        """Execute a `;`-separated SQL script; one result per statement."""
        response = self.request({"op": "query", "sql": sql})
        return [ClientResult.from_payload(p)
                for p in response.get("results", [])]

    def run_one(self, sql: str) -> ClientResult:
        results = self.run(sql)
        if len(results) != 1:
            raise ServerError(f"expected one result, got {len(results)}")
        return results[0]

    def prepare(self, name: str, sql: str) -> ClientResult:
        return self.run_one(f"PREPARE {name} AS {sql.rstrip(';')}")

    def run_prepared(self, name: str,
                     params: Sequence[float] = ()) -> ClientResult:
        """EXECUTE a prepared statement (the zero-parse wire path)."""
        response = self.request({"op": "execute", "name": name,
                                 "params": list(params)})
        return ClientResult.from_payload(response["results"][0])

    # -- the freshness surface -----------------------------------------
    def alter_view(self, view: str, **options) -> ClientResult:
        """`ALTER VIEW view SET (opt = val, ...)` — typed-schema checked
        server-side; e.g. `c.alter_view("v", target_lag="5 s")`."""
        if not options:
            raise ValueError("alter_view() needs at least one option")
        body = ", ".join(f"{k} = {_option_sql(v)}"
                         for k, v in options.items())
        return self.run_one(f"ALTER VIEW {view} SET ({body})")

    def suspend(self, view: str) -> ClientResult:
        """Freeze a view: reads keep serving its current labels while
        committed base-table updates queue."""
        return self.run_one(f"ALTER VIEW {view} SUSPEND")

    def resume(self, view: str) -> ClientResult:
        """Unfreeze a view; it catches up exactly once, bit-identically
        to never having been suspended."""
        return self.run_one(f"ALTER VIEW {view} RESUME")

    def refresh(self, view: Optional[str] = None,
                wait: bool = True) -> List[str]:
        """Freshness barrier: commit pending DML and refresh every view
        (or `view` plus its ancestors) in topological order. Returns the
        refreshed view names. The protocol is closed-loop, so the call
        always blocks until the barrier completes — `wait` is accepted
        for signature stability."""
        del wait
        request: dict = {"op": "refresh"}
        if view is not None:
            request["view"] = view
        return list(self.request(request).get("refreshed", []))

    def show(self, what: str, view: Optional[str] = None) -> list:
        """`SHOW <what>` as typed rows (namedtuples keyed by the result
        columns): `c.show("schedule")[0].staleness_s`, etc. `what` is one
        of tables/views/storage/metrics/schedule/cost (cost needs
        `view=`)."""
        if what == "cost":
            if view is None:
                raise ValueError('show("cost") needs view=')
            return self.run_one(f"SHOW COST ON {view}").typed_rows()
        return self.run_one(f"SHOW {what.upper()}").typed_rows()

    # -- legacy spellings (deprecated, wire-format identical) ----------
    def query(self, sql: str) -> List[ClientResult]:
        warnings.warn("SqlClient.query() is deprecated; use run()",
                      DeprecationWarning, stacklevel=2)
        return self.run(sql)

    def query_one(self, sql: str) -> ClientResult:
        warnings.warn("SqlClient.query_one() is deprecated; use run_one()",
                      DeprecationWarning, stacklevel=2)
        return self.run_one(sql)

    def execute(self, name: str,
                params: Sequence[float] = ()) -> ClientResult:
        warnings.warn("SqlClient.execute() is deprecated; use "
                      "run_prepared()", DeprecationWarning, stacklevel=2)
        return self.run_prepared(name, params)

    # -- plumbing ------------------------------------------------------
    def ping(self) -> int:
        """Round trip; returns the server's current epoch."""
        return self.request({"op": "ping"})["epoch"]

    def metrics(self) -> dict:
        """The server's unified telemetry snapshot (counters, gauges,
        histograms, per-component collectors, epoch) as plain JSON."""
        return self.request({"op": "metrics"})["metrics"]

    def close(self):
        if self._sock is not None:
            try:
                send_frame(self._sock, {"op": "close"})
                recv_frame(self._sock)
            except (OSError, WireError):
                pass
            finally:
                self._sock.close()
                self._sock = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
