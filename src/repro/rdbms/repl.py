"""SQL entry point: interactive REPL and scripted execution.

Used by `python -m repro.launch.serve --mode sql` (interactive), with
`--script f.sql` (run a file) or `--execute "stmt; stmt"` (one-shot).
"""
from __future__ import annotations

import sys
from typing import Optional

from repro.rdbms.ast_nodes import SqlError
from repro.rdbms.executor import Executor

BANNER = """HAZY SQL — classification views inside the relational front-end.
Statements end with ';'.  Try:
  CREATE TABLE papers FROM CORPUS cora_like WITH (scale = 0.1);
  CREATE CLASSIFICATION VIEW topics ON papers USING MODEL svm
      WITH (policy = hybrid, k = 7, memory_budget = 0.1);
  INSERT INTO papers (id, class) VALUES (0, 3), (1, 0);
  SELECT id, view, label FROM topics WHERE id = 0;
  EXPLAIN SELECT label FROM topics WHERE id = 0 AND view = 3;
  PREPARE pt AS SELECT label FROM topics WHERE id = ? AND view = ?;
  EXECUTE pt (0, 3);
  SHOW STORAGE;
Ctrl-D to exit."""


def run_script(sql: str, executor: Optional[Executor] = None, *,
               echo: bool = True, out=None) -> Executor:
    """Execute a `;`-separated script, printing each result table."""
    out = sys.stdout if out is None else out   # resolve at call time
    ex = executor or Executor()
    for result in ex.execute(sql):
        if echo:
            print(result.pretty(), file=out)
    return ex


def repl(executor: Optional[Executor] = None, *, stdin=None,
         out=None) -> Executor:
    stdin = sys.stdin if stdin is None else stdin
    out = sys.stdout if out is None else out
    ex = executor or Executor()
    print(BANNER, file=out)
    buf = ""
    while True:
        try:
            prompt = "sql> " if not buf else "...> "
            if stdin is sys.stdin and sys.stdin.isatty():
                line = input(prompt)
            else:
                line = stdin.readline()
                if not line:
                    break
        except EOFError:
            break
        buf += line.rstrip("\n") + "\n"
        if ";" not in buf:
            if buf.strip().lower() in ("quit", "exit"):
                break
            continue
        try:
            results = ex.execute(buf)
            for result in results:
                print(result.pretty(), file=out)
                if result.plan is not None:
                    p = result.plan
                    print(f"-- plan: {p.kind} via {p.tier} "
                          f"(est {p.est_touched} tuples)", file=out)
            print(_timing_footer(results), file=out)
        except SqlError as e:
            print(f"error: {e}", file=out)
        buf = ""
    return ex


def _timing_footer(results) -> str:
    """`-- N ms (gate-wait g ms, execute e ms)` from the statements' span
    trees — the SAME per-phase numbers the server's elapsed_us and EXPLAIN
    ANALYZE report (no second clock in the REPL)."""
    traces = [r.trace for r in results if r.trace is not None]
    total = sum(t.duration_us for t in traces) / 1e3
    gate = sum(t.sum_us("gate.wait") for t in traces) / 1e3
    execute = sum(t.sum_us("execute") for t in traces) / 1e3
    return (f"-- {total:.2f} ms (gate-wait {gate:.2f} ms, "
            f"execute {execute:.2f} ms)")
