"""SQL entry point: interactive REPL and scripted execution.

Used by `python -m repro.launch.serve --mode sql` (interactive), with
`--script f.sql` (run a file) or `--execute "stmt; stmt"` (one-shot).
"""
from __future__ import annotations

import sys
import time
from typing import Optional

from repro.rdbms.ast_nodes import SqlError
from repro.rdbms.executor import Executor

BANNER = """HAZY SQL — classification views inside the relational front-end.
Statements end with ';'.  Try:
  CREATE TABLE papers FROM CORPUS cora_like WITH (scale = 0.1);
  CREATE CLASSIFICATION VIEW topics ON papers USING MODEL svm
      WITH (policy = hybrid, k = 7, memory_budget = 0.1);
  INSERT INTO papers (id, class) VALUES (0, 3), (1, 0);
  SELECT id, view, label FROM topics WHERE id = 0;
  EXPLAIN SELECT label FROM topics WHERE id = 0 AND view = 3;
  PREPARE pt AS SELECT label FROM topics WHERE id = ? AND view = ?;
  EXECUTE pt (0, 3);
  SHOW STORAGE;
Ctrl-D to exit."""


def run_script(sql: str, executor: Optional[Executor] = None, *,
               echo: bool = True, out=sys.stdout) -> Executor:
    """Execute a `;`-separated script, printing each result table."""
    ex = executor or Executor()
    for result in ex.execute(sql):
        if echo:
            print(result.pretty(), file=out)
    return ex


def repl(executor: Optional[Executor] = None, *, stdin=sys.stdin,
         out=sys.stdout) -> Executor:
    ex = executor or Executor()
    print(BANNER, file=out)
    buf = ""
    while True:
        try:
            prompt = "sql> " if not buf else "...> "
            if stdin is sys.stdin and sys.stdin.isatty():
                line = input(prompt)
            else:
                line = stdin.readline()
                if not line:
                    break
        except EOFError:
            break
        buf += line.rstrip("\n") + "\n"
        if ";" not in buf:
            if buf.strip().lower() in ("quit", "exit"):
                break
            continue
        t0 = time.perf_counter()
        try:
            for result in ex.execute(buf):
                print(result.pretty(), file=out)
                if result.plan is not None:
                    p = result.plan
                    print(f"-- plan: {p.kind} via {p.tier} "
                          f"(est {p.est_touched} tuples)", file=out)
            print(f"-- {1e3 * (time.perf_counter() - t0):.2f} ms", file=out)
        except SqlError as e:
            print(f"error: {e}", file=out)
        buf = ""
    return ex
