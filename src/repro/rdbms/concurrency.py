"""Epoch gate: statement-scoped snapshot isolation for the SQL layer.

The engines maintain ONE mutable state in place (clustered labels, waters,
buffer windows) — there is no version chain to read from, so snapshot
isolation is enforced by *scheduling*, not by copying state:

  * epoch       == the committed WAL batch index (`UpdateLog.commits`).
  * a reader    pins the epoch at statement start by holding the gate in
                shared mode for the statement's duration; the engine state
                it reads is exactly the epoch-E state throughout, because
                nothing that advances the epoch can run concurrently.
  * a writer    (group commit, UPDATE MODEL, DDL, catch-up-capable reads)
                holds the gate exclusively: it waits behind every in-flight
                pinned read, runs alone, advances the epoch, and releases.

Writer preference: once a commit is waiting, new readers queue behind it.
A 95/5 read-heavy swarm would otherwise starve the group commit forever —
and with it every session's read-your-writes flush.

The gate is deliberately NOT reentrant across modes; the executor keeps a
thread-local depth counter so nested statement dispatch (EXECUTE ->
SELECT) runs inside the guard already held.
"""
from __future__ import annotations

import contextlib
import threading

from repro.analysis.witness import WITNESS
from repro.obs import clock


class EpochGate:
    """Shared/exclusive gate with writer preference (see module doc)."""

    def __init__(self, metrics=None):
        self._cv = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0
        # pre-resolved instruments so the hot path never does a registry
        # name lookup; wait time is observed AFTER the cv is released.
        if metrics is not None:
            self._m_shared = metrics.counter("gate.shared_acquisitions")
            self._m_excl = metrics.counter("gate.exclusive_acquisitions")
            self._m_shared_wait = metrics.histogram("gate.shared_wait_seconds")
            self._m_excl_wait = metrics.histogram("gate.exclusive_wait_seconds")
        else:
            self._m_shared = self._m_excl = None
            self._m_shared_wait = self._m_excl_wait = None

    @contextlib.contextmanager
    def read(self):
        """Hold shared for a statement-scoped snapshot-pinned read."""
        # witness seam: check the declared order BEFORE blocking, so an
        # inversion surfaces as LockOrderError, not a deadlock.
        if WITNESS.active:
            WITNESS.push("gate", self)
        try:
            t0 = clock() if self._m_shared is not None else 0.0
            with self._cv:
                while self._writer or self._writers_waiting:
                    self._cv.wait()
                self._readers += 1
            if self._m_shared is not None:
                self._m_shared.inc()
                self._m_shared_wait.observe(clock() - t0)
            try:
                yield
            finally:
                with self._cv:
                    self._readers -= 1
                    if self._readers == 0:
                        self._cv.notify_all()
        finally:
            WITNESS.pop("gate", self)

    @contextlib.contextmanager
    def write(self):
        """Hold exclusive for anything that may advance the epoch or
        mutate engine state non-idempotently."""
        if WITNESS.active:
            WITNESS.push("gate", self)
        try:
            t0 = clock() if self._m_excl is not None else 0.0
            with self._cv:
                self._writers_waiting += 1
                try:
                    while self._writer or self._readers:
                        self._cv.wait()
                finally:
                    self._writers_waiting -= 1
                self._writer = True
            if self._m_excl is not None:
                self._m_excl.inc()
                self._m_excl_wait.observe(clock() - t0)
            try:
                yield
            finally:
                with self._cv:
                    self._writer = False
                    self._cv.notify_all()
        finally:
            WITNESS.pop("gate", self)

    # -- introspection (tests) -----------------------------------------
    @property
    def readers(self) -> int:
        return self._readers

    @property
    def write_held(self) -> bool:
        return self._writer
