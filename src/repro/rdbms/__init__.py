"""Relational front-end over the HAZY engines (the paper's actual surface).

The paper's architecture puts classification *inside* the RDBMS: users
issue SQL DDL/DML/SELECTs against model-based views and the system picks
eager/lazy/hybrid maintenance under the covers. This package is that
surface for our engines:

  * `catalog`  — base entity tables + registered classification views
                 (single-view / multiclass / sharded, behind `EngineFacade`)
  * `lexer`/`parser`/`ast_nodes` — the SQL dialect
  * `wal`      — group-commit update log (WAL-style, replayable): heavy
                 write traffic amortizes into ONE engine round per commit
  * `planner`  — routes reads to the cheapest §3.5 tier and prices every
                 statement in touched tuples (the §3.4/§3.5 cost model)
  * `executor` — executes plans; `EXPLAIN` makes tier + cost user-visible;
                 `Session` scopes a prepared-statement cache per client
  * `concurrency` — the epoch gate: statement-scoped snapshot isolation
                 (readers pin the committed WAL batch index; commits
                 serialize exclusively behind them)
  * `wire`/`server`/`client` — length-prefixed-JSON protocol, the asyncio
                 SQL server (N concurrent sessions over ONE executor),
                 and the blocking client
                 (`python -m repro.launch.serve --mode sql --serve ...`)
  * `repl`     — interactive / scripted entry point
                 (`python -m repro.launch.serve --mode sql`)
"""
from repro.rdbms.ast_nodes import (AlterView, Commit, CreateTable,
                                   CreateView, Delete, ExecutePrepared,
                                   Explain, Insert, Param, Prepare, Select,
                                   Show, Update, UpdateModel, Where)
from repro.rdbms.catalog import Catalog, PlanError, SqlError, ViewDef
from repro.rdbms.client import ClientResult, ServerError, SqlClient
from repro.rdbms.concurrency import EpochGate
from repro.rdbms.executor import Executor, Result, Session
from repro.rdbms.lexer import LexError
from repro.rdbms.options import (DOWNSTREAM, TableOptions, ViewOptions,
                                 format_lag, parse_lag)
from repro.rdbms.parser import ParseError, parse
from repro.rdbms.planner import Plan, plan_statement
from repro.rdbms.server import ServerHandle, SqlServer, start_server_thread
from repro.rdbms.wal import UpdateLog, WalRecord
