"""Executor: plans -> engine calls; EXPLAIN makes the cost model visible.

Execution contract:

  * DML (INSERT / UPDATE / DELETE) goes through the group-commit WAL —
    statements return immediately with `queued` rows; the engine round
    happens at commit (group full, read on the table, UPDATE MODEL, or
    COMMIT).
  * reads flush the target table's pending group first (read-your-writes),
    then route through the planned tier; the executed `Result` carries the
    plan AND the actually-used tiers, so `EXPLAIN` for a point SELECT
    reports the waters/buffer/band(disk) tier that really answered it.
  * `EXPLAIN <stmt>` never commits and never mutates engine state beyond
    the dry-run probe it reports (for point lookups under hybrid, the
    probe IS the cheapest way to know the tier — it is tier-counted like
    any probe).

Concurrency contract (the SQL server drives one Executor from N session
threads; see `repro.rdbms.concurrency`):

  * every statement runs under the epoch gate. Point SELECTs on eager /
    hybrid views hold it SHARED — they pin the epoch (committed WAL batch
    index) at statement start, proceed concurrently with each other, and
    are guaranteed never to observe a later commit's labels/waters
    mid-statement (the executed `Result.epoch` records the pin, and the
    guard re-checks it at statement end).
  * everything that mutates engine state — DML appends + group commits,
    UPDATE MODEL, DDL, and catch-up-capable reads (scans / counts / top-k
    / any read on a LAZY view) — holds the gate EXCLUSIVELY and advances
    the epoch behind the pinned readers.
  * the read-your-writes flush runs as its own exclusive section BEFORE
    the read takes its shared pin, so a flush can never interleave with
    anyone's pinned snapshot.

`Session` wraps an Executor with a per-session prepared-statement cache —
each SQL-server connection gets one, so PREPARE names are session-scoped
exactly like real wire protocols scope them.
"""
from __future__ import annotations

import dataclasses
import itertools
import logging
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.facade import TIERS
from repro.obs import trace
from repro.obs.trace import Span, render_tree
from repro.rdbms.ast_nodes import (AlterView, Commit, CreateTable,
                                   CreateView, Delete, ExecutePrepared,
                                   Explain, Insert, Param, Prepare, Select,
                                   Show, SqlError, Statement, Update,
                                   UpdateModel, Where)
from repro.rdbms.catalog import Catalog, PlanError
from repro.rdbms.concurrency import EpochGate
from repro.rdbms.options import format_lag
from repro.rdbms.parser import parse
from repro.rdbms.planner import Plan, _resolve_view_index, plan_statement
from repro.rdbms.wal import UpdateLog
from repro.scheduler import refresh as freshness

_slow_log = logging.getLogger("repro.obs.slowlog")

# AST class -> lowercase statement kind ("select", "insert", ...), cached so
# the per-statement hot path skips the __name__.lower() allocation.
_KIND_NAMES: dict = {}


@dataclasses.dataclass
class Result:
    columns: Tuple[str, ...]
    rows: List[tuple]
    plan: Optional[Plan] = None
    tiers_used: Optional[List[str]] = None
    epoch: Optional[int] = None     # committed WAL batch index pinned by
                                    # the statement (None: pre-gate paths)
    trace: Optional[Span] = None    # the statement's finished span tree
                                    # (None on nested dispatch)

    def __iter__(self):
        return iter(self.rows)

    def pretty(self) -> str:
        if not self.rows:
            return "(0 rows)"
        widths = [max(len(str(c)), *(len(str(r[j])) for r in self.rows))
                  for j, c in enumerate(self.columns)]
        fmt = "  ".join(f"{{:<{w}}}" for w in widths)
        lines = [fmt.format(*self.columns),
                 fmt.format(*("-" * w for w in widths))]
        lines += [fmt.format(*(str(x) for x in r)) for r in self.rows]
        return "\n".join(lines) + f"\n({len(self.rows)} rows)"


@dataclasses.dataclass
class _Prepared:
    """A PREPAREd template plus its cached route: the first EXECUTE plans
    once; later EXECUTEs bind parameters and go straight to the physical
    operator — point reads skip parse AND plan."""
    stmt: Statement
    n_params: int
    plan: Optional[Plan] = None


def _bind(stmt: Statement, params: Sequence[float]) -> Statement:
    """Substitute positional parameters for the `?` placeholders of a
    prepared template (the template itself is never mutated)."""
    def val(x, as_int=False):
        if isinstance(x, Param):
            v = params[x.index]
            return int(v) if as_int else v
        return x

    if isinstance(stmt, Select):
        w = stmt.where
        if w is not None:
            label = val(w.label, True) if w.label is not None else None
            if label is not None and label not in (1, -1):
                raise SqlError(f"label parameter must be 1 or -1, "
                               f"got {label}")
            w = Where(
                ids=None if w.ids is None else [val(i, True) for i in w.ids],
                label=label,
                cls=val(w.cls, True) if w.cls is not None else None,
                view=val(w.view, True) if w.view is not None else None)
        limit = val(stmt.limit, True) if stmt.limit is not None else None
        return dataclasses.replace(stmt, where=w, limit=limit)
    if isinstance(stmt, Update):
        return dataclasses.replace(stmt, entity_id=val(stmt.entity_id, True),
                                   label=float(val(stmt.label)))
    if isinstance(stmt, Delete):
        return dataclasses.replace(stmt, entity_id=val(stmt.entity_id, True))
    return stmt


class Executor:
    def __init__(self, catalog: Optional[Catalog] = None, *,
                 group_commit: int = 64, wal_path: Optional[str] = None,
                 slow_ms: Optional[float] = None):
        self.catalog = catalog if catalog is not None else Catalog()
        # ONE registry for the whole stack: owned by the catalog (views and
        # pools already feed it), adopted here for gate/WAL/span instruments.
        self.metrics = self.catalog.metrics
        self.log = UpdateLog(group_size=group_commit, path=wal_path,
                             metrics=self.metrics)
        self.prepared: dict[str, _Prepared] = {}
        self.gate = EpochGate(metrics=self.metrics)
        self.slow_ms = slow_ms              # slow-statement log threshold
        self._tls = threading.local()       # .depth: nested dispatch guard
        self.metrics.register_collector("wal", self.log.telemetry_snapshot)
        # the freshness ledger rides the unified snapshot (`SHOW METRICS`,
        # the wire `metrics` op) under the "schedule" key
        self.metrics.register_collector(
            "schedule", lambda: freshness.schedule_snapshot(self.catalog))
        # hot-path instruments, resolved once
        self._m_statements = self.metrics.counter("statements")
        self._m_errors = self.metrics.counter("statements.errors")
        self._m_stmt_seconds = self.metrics.histogram("statement.seconds")
        self._m_kind: dict = {}             # kind -> statements.<kind> counter

    @property
    def epoch(self) -> int:
        """Committed WAL batch index — the snapshot version readers pin."""
        return self.log.commits

    def metrics_snapshot(self) -> dict:
        """The unified telemetry snapshot (`SHOW METRICS`, the wire
        `metrics` op): registry instruments + per-component collectors,
        stamped with the current epoch."""
        snap = self.metrics.snapshot()
        snap["epoch"] = self.log.commits
        return snap

    # -- entry points --------------------------------------------------
    def execute(self, sql: str, *,
                prepared: Optional[Dict[str, _Prepared]] = None
                ) -> List[Result]:
        ps = trace.start("parse")
        try:
            stmts = parse(sql)
            ps.attrs["statements"] = len(stmts)
        finally:
            trace.finish(ps)
        return [self.execute_statement(s, prepared=prepared,
                                       _parse_span=ps if i == 0 else None)
                for i, s in enumerate(stmts)]

    def execute_one(self, sql: str, *,
                    prepared: Optional[Dict[str, _Prepared]] = None
                    ) -> Result:
        results = self.execute(sql, prepared=prepared)
        if len(results) != 1:
            raise SqlError(f"expected one statement, got {len(results)}")
        return results[0]

    # -- the concurrency wrapper ---------------------------------------
    def execute_statement(self, stmt: Statement, *,
                          prepared: Optional[Dict[str, _Prepared]] = None,
                          _parse_span: Optional[Span] = None) -> Result:
        """Gate + dispatch under a root "statement" span. Point SELECTs on
        eager/hybrid views run under the SHARED gate (epoch-pinned
        snapshot); everything else runs exclusively (see the module doc's
        concurrency contract). The finished span tree rides on
        `Result.trace` — the server's elapsed_us, EXPLAIN ANALYZE and the
        REPL footer all read the same phases from it."""
        prepared = self.prepared if prepared is None else prepared
        depth = getattr(self._tls, "depth", 0)
        if depth:                            # nested dispatch: guard held
            return self._dispatch(stmt, prepared)
        self._tls.depth = 1
        cls = type(stmt)
        kind = _KIND_NAMES.get(cls)
        if kind is None:
            kind = _KIND_NAMES[cls] = cls.__name__.lower()
        root = trace.start("statement", kind=kind)
        if _parse_span is not None:          # adopt execute()'s parse span
            root.children.insert(0, _parse_span)
        ok = True
        try:
            res = self._execute_gated(stmt, prepared)
            res.trace = root
            return res
        except BaseException:
            ok = False
            raise
        finally:
            self._tls.depth = 0
            trace.finish(root)               # also unwinds any span an
            self._record_statement(root, kind, ok)   # exception left open

    def _execute_gated(self, stmt: Statement,
                       prepared: Dict[str, _Prepared]) -> Result:
        table = self._read_target_table(stmt, prepared)
        if self._shared_eligible(stmt, prepared):
            # read-your-writes flush in its OWN exclusive section,
            # before the shared pin
            if table is not None and self.log.has_pending(table):
                gw = trace.start("gate.wait", mode="exclusive")
                with self.gate.write():
                    trace.finish(gw)
                    with trace.span("flush.read_your_writes", table=table):
                        self.log.flush(self.catalog, table)
            gw = trace.start("gate.wait", mode="shared")
            with self.gate.read():
                trace.finish(gw)
                ex_sp = trace.start("execute")
                try:
                    epoch = self.log.commits
                    res = self._dispatch(stmt, prepared)
                    if self.log.commits != epoch:   # must be unreachable
                        raise SqlError(
                            f"snapshot violated: epoch {epoch} -> "
                            f"{self.log.commits} mid-statement")
                finally:
                    trace.finish(ex_sp)
            res.epoch = epoch
            return res
        gw = trace.start("gate.wait", mode="exclusive")
        with self.gate.write():
            trace.finish(gw)
            if table is not None:           # read-your-writes, already
                with trace.span("flush.read_your_writes", table=table):
                    self.log.flush(self.catalog, table)  # exclusive here
            ex_sp = trace.start("execute")
            try:
                res = self._dispatch(stmt, prepared)
                res.epoch = self.log.commits
            finally:
                trace.finish(ex_sp)
        return res

    def _record_statement(self, root: Span, kind: str, ok: bool):
        """Per-statement registry counters + the slow-statement log."""
        self._m_stmt_seconds.observe(root.duration_s)
        self._m_statements.inc()
        ck = self._m_kind.get(kind)
        if ck is None:
            ck = self._m_kind[kind] = self.metrics.counter(f"statements.{kind}")
        ck.inc()
        if not ok:
            self._m_errors.inc()
        if self.slow_ms is not None and root.duration_s * 1e3 >= self.slow_ms:
            _slow_log.warning("slow statement (%.2f ms >= %.2f ms):\n%s",
                              root.duration_s * 1e3, self.slow_ms,
                              render_tree(root))

    def _read_target_table(self, stmt: Statement,
                           prepared: Dict[str, _Prepared]) -> Optional[str]:
        """The base table a SELECT/EXECUTE reads (None for non-reads or
        unresolvable targets — dispatch raises the real error then)."""
        if isinstance(stmt, Explain) and stmt.analyze:
            stmt = stmt.stmt       # EXPLAIN ANALYZE executes the inner read,
                                   # so read-your-writes must flush for it too
        if isinstance(stmt, ExecutePrepared):
            ps = prepared.get(stmt.name)
            if ps is None:
                return None
            stmt = ps.stmt
        if not isinstance(stmt, Select):
            return None
        try:
            return self.catalog.view(stmt.view).table
        except PlanError:
            return None

    def _shared_eligible(self, stmt: Statement,
                         prepared: Dict[str, _Prepared]) -> bool:
        """True iff the statement is a point read that can run under the
        shared gate: a non-COUNT SELECT with an id predicate on an eager
        or hybrid view. Those never catch up (hybrid probes are exact via
        the waters; eager has nothing deferred) — a LAZY view's point read
        relabels its band and must run exclusively."""
        if isinstance(stmt, ExecutePrepared):
            ps = prepared.get(stmt.name)
            if ps is None:
                return False                 # dispatch raises the real error
            stmt = ps.stmt
        if not isinstance(stmt, Select):
            return False
        w = stmt.where
        if stmt.count or w is None or w.ids is None:
            return False
        try:
            return self.catalog.view(stmt.view).facade.policy != "lazy"
        except PlanError:
            return False                     # dispatch raises the real error

    def _dispatch(self, stmt: Statement,
                  prepared: Dict[str, _Prepared]) -> Result:
        if isinstance(stmt, Explain):
            return self._explain(stmt.stmt, prepared, analyze=stmt.analyze)
        if isinstance(stmt, CreateTable):
            t = self.catalog.create_table_from_corpus(
                stmt.name, stmt.corpus, stmt.options)
            return Result(("table", "n", "d"),
                          [(t.name, t.n, t.features.shape[1])])
        if isinstance(stmt, CreateView):
            vd = self.catalog.create_view(stmt.name, stmt.table, stmt.model,
                                          stmt.options)
            f = vd.facade
            return Result(("view", "table", "k", "policy", "engine"),
                          [(vd.name, vd.table, f.num_views, f.policy,
                            type(f).__name__)])
        if isinstance(stmt, Insert):
            self.catalog.table(stmt.table)
            commits = 0
            wa = trace.start("wal.append", rows=len(stmt.rows))
            try:
                for i, y in stmt.rows:
                    commits += self.log.append("insert", stmt.table, i, y,
                                               self.catalog)
            finally:
                trace.finish(wa)
            return Result(("queued", "commits"), [(len(stmt.rows), commits)])
        if isinstance(stmt, Update):
            self.catalog.table(stmt.table)
            commits = self.log.append("update", stmt.table, stmt.entity_id,
                                      stmt.label, self.catalog)
            return Result(("queued", "commits"), [(1, commits)])
        if isinstance(stmt, Delete):
            # reject BEFORE the record enters the WAL: a facade without the
            # footnote-2 retrain would otherwise fail mid-flush, after the
            # pending group was popped (losing the records ordered after it)
            plan_statement(stmt, self.catalog, self.log)
            commits = self.log.append("delete", stmt.table, stmt.entity_id,
                                      0.0, self.catalog)
            return Result(("queued", "commits"), [(1, commits)])
        if isinstance(stmt, UpdateModel):
            vd = self.catalog.view(stmt.view)
            self.log.flush(self.catalog, vd.table)
            vd.facade.force_round()
            return Result(("view", "round"), [(stmt.view, "applied")])
        if isinstance(stmt, AlterView):
            return self._alter_view(stmt)
        if isinstance(stmt, Commit):
            n = self.log.flush(self.catalog)
            return Result(("commits",), [(n,)])
        if isinstance(stmt, Show):
            if stmt.what == "tables":
                return Result(("table", "n", "d"),
                              [(t.name, t.n, t.features.shape[1])
                               for t in self.catalog.tables.values()])
            if stmt.what == "storage":
                return self._show_storage()
            if stmt.what == "metrics":
                return self._show_metrics()
            if stmt.what == "cost":
                return self._show_cost(stmt.view)
            if stmt.what == "schedule":
                return self._show_schedule()
            return self._show_views()
        if isinstance(stmt, Prepare):
            if stmt.name in prepared:
                raise SqlError(f"prepared statement {stmt.name!r} already "
                               f"exists")
            prepared[stmt.name] = _Prepared(stmt.stmt, stmt.n_params)
            return Result(("prepared", "params"),
                          [(stmt.name, stmt.n_params)])
        if isinstance(stmt, ExecutePrepared):
            return self._execute_prepared(stmt, prepared)
        if isinstance(stmt, Select):
            return self._select(stmt)
        raise SqlError(f"cannot execute {type(stmt).__name__}")

    def _alter_view(self, stmt: AlterView) -> Result:
        """ALTER VIEW — lifecycle verbs route to the scheduler package
        (the only module allowed to mutate freshness state, FRS001);
        SET goes through the typed option schema's alter path."""
        vd = self.catalog.view(stmt.view)
        if stmt.action == "suspend":
            with trace.span("view.suspend", view=vd.name):
                freshness.suspend_view(self.catalog, vd)
        elif stmt.action == "resume":
            # catch up EXACTLY once, right here: queued batches replay
            # with their original commit boundaries
            with trace.span("view.resume", view=vd.name):
                freshness.resume_view(self.catalog, vd)
        elif stmt.action == "refresh":
            with trace.span("view.refresh", view=vd.name):
                self.log.flush(self.catalog, vd.table)
                freshness.refresh_view(self.catalog, vd)
        else:                                   # "set"
            vd = self.catalog.alter_view_options(stmt.view, stmt.options)
        return self._freshness_result(vd)

    def refresh_views(self, view: Optional[str] = None) -> List[str]:
        """The wire `refresh` op — a freshness BARRIER: commit all pending
        DML and bring every view (or `view` + its ancestors) up to date in
        topological order, under one exclusive gate slice. Runs outside
        `execute_statement` so a barrier does not perturb the per-
        statement telemetry the serve benchmarks assert on."""
        gw = trace.start("gate.wait", mode="exclusive")
        with self.gate.write():
            trace.finish(gw)
            with trace.span("refresh.barrier", view=view or "*"):
                self.log.flush(self.catalog)
                return freshness.refresh_all(self.catalog, only=view)

    def _freshness_result(self, vd) -> Result:
        row = next(r for r in freshness.schedule_snapshot(self.catalog)
                   if r["view"] == vd.name)
        return Result(
            ("view", "state", "target_lag", "staleness_s", "inbox_rows"),
            [(vd.name, row["state"], format_lag(row["target_lag"]),
              round(row["staleness_s"], 6), row["inbox_rows"])])

    def _show_views(self) -> Result:
        """SHOW VIEWS — the catalog plus each view's freshness face:
        state (immediate/scheduled/suspended), declared + effective lag,
        measured staleness, last refresh."""
        snap = {r["view"]: r for r in
                freshness.schedule_snapshot(self.catalog)}
        cols = ("view", "on", "k", "policy", "state", "target_lag",
                "effective_lag", "staleness_s", "last_refresh_s")
        rows = []
        for v in self.catalog.views.values():
            r = snap[v.name]
            rows.append((v.name, r["on"], v.facade.num_views,
                         v.facade.policy, r["state"],
                         format_lag(r["target_lag"]),
                         format_lag(r["effective_lag"]),
                         round(r["staleness_s"], 6),
                         ("-" if r["last_refresh_age_s"] is None
                          else round(r["last_refresh_age_s"], 6))))
        return Result(cols, rows)

    def _show_schedule(self) -> Result:
        """SHOW SCHEDULE — the scheduler's full ledger: what's queued,
        what it would cost (SKIING-modeled), who goes next (priority)."""
        cols = ("view", "on", "state", "target_lag", "effective_lag",
                "staleness_s", "inbox_batches", "inbox_rows",
                "modeled_cost", "priority", "refreshes", "rows_applied")
        rows = []
        for r in freshness.schedule_snapshot(self.catalog):
            rows.append((r["view"], r["on"], r["state"],
                         format_lag(r["target_lag"]),
                         format_lag(r["effective_lag"]),
                         round(r["staleness_s"], 6), r["inbox_batches"],
                         r["inbox_rows"], int(r["modeled_cost"]),
                         ("-" if r["priority"] is None
                          else round(r["priority"], 4)),
                         r["refreshes"], r["rows_applied"]))
        return Result(cols, rows)

    def _show_storage(self) -> Result:
        """One row per view: the storage tier's residency and counters
        (views without a memory budget report the whole table in RAM)."""
        cols = ("view", "policy", "budget_bytes", "table_bytes",
                "pages_resident", "pages_total", "pinned_pages", "hits",
                "misses", "evictions", "hit_rate", "in_flight", "coalesced",
                "readahead_pages", "readahead_used")
        rows = []
        for v in self.catalog.views.values():
            st = v.facade.storage_stats()
            if st is None:
                n_bytes = self.catalog.table(v.table).features.nbytes
                rows.append((v.name, v.facade.policy, "in-ram", n_bytes,
                             "-", "-", "-", "-", "-", "-", "-",
                             "-", "-", "-", "-"))
            else:
                rows.append((v.name, v.facade.policy, st["budget_bytes"],
                             st["table_bytes"], st["pages_resident"],
                             st["pages_total"], st["pinned_pages"],
                             st["hits"], st["misses"], st["evictions"],
                             f"{st['hit_rate']:.3f}", st["in_flight"],
                             st["coalesced"], st["readahead_pages"],
                             st["readahead_used"]))
        return Result(cols, rows)

    def execute_prepared(self, name: str, params: Sequence[float] = (), *,
                         prepared: Optional[Dict[str, _Prepared]] = None
                         ) -> Result:
        """Programmatic EXECUTE: bind + run a prepared statement without
        any SQL text (the zero-parse path for embedders)."""
        return self.execute_statement(ExecutePrepared(name, list(params)),
                                      prepared=prepared)

    def _execute_prepared(self, ex: ExecutePrepared,
                          prepared: Dict[str, _Prepared]) -> Result:
        ps = prepared.get(ex.name)
        if ps is None:
            raise SqlError(f"unknown prepared statement {ex.name!r}")
        if len(ex.params) != ps.n_params:
            raise SqlError(f"prepared statement {ex.name!r} takes "
                           f"{ps.n_params} parameter(s), got "
                           f"{len(ex.params)}")
        bound = _bind(ps.stmt, ex.params)
        if isinstance(bound, Select) and bound.where is not None \
                and bound.where.ids is not None and not bound.count:
            # the amortized point route: the cached plan — repeated
            # EXECUTEs skip parse AND plan, paying only a cheap id-range
            # guard (read-your-writes was flushed by the gate wrapper)
            vd = self.catalog.view(bound.view)
            f = vd.facade
            if ps.plan is None:
                ps.plan = plan_statement(bound, self.catalog, self.log)
            else:
                for i in bound.where.ids:
                    if not (0 <= i < f.n):
                        raise PlanError(f"id = {i} out of range (n = {f.n})")
            return self._select_point(bound, f, bound.where, ps.plan)
        # _execute_prepared only ever runs from _dispatch, i.e. with the
        # gate already held — dispatch the bound statement directly
        # instead of re-entering execute_statement, so the gate is
        # acquired on exactly one statically-visible path
        return self._dispatch(bound, prepared)

    # -- SELECT --------------------------------------------------------
    def _select(self, sel: Select) -> Result:
        vd = self.catalog.view(sel.view)
        # (read-your-writes flush happens in the gate wrapper, before the
        # shared pin — never here, where it would commit mid-snapshot)
        with trace.span("plan") as pl:
            plan = plan_statement(sel, self.catalog, self.log)
            pl.attrs["tier"] = plan.tier
        f = vd.facade
        w = sel.where or Where()

        if sel.count:
            if w.label is None and w.cls is None:
                # unpredicated COUNT(*): table cardinality, not membership
                return Result(("count",), [(f.n,)], plan=plan)
            v = _resolve_view_index(w, f, None)
            c = int(f.counts()[v])
            if (w.label is not None and w.label == -1):
                c = f.n - c
            return Result(("count",), [(c,)], plan=plan)

        if w.ids is not None:
            return self._select_point(sel, f, w, plan)

        if sel.order_by == "margin":
            v = _resolve_view_index(w, f, sel.columns)
            limit = sel.limit if sel.limit is not None else 10
            ids, margins, touched = f.top_margins(v, limit, sel.descending)
            plan.detail += f";touched={touched}"
            cols = sel.columns or ["id", "margin"]
            if "margin" not in cols:
                cols = cols + ["margin"]
            rows = [self._row(cols, f, int(i), view=v, margin=float(m),
                              label=(1 if m >= 0 else -1))
                    for i, m in zip(ids, margins)]
            return Result(tuple(cols), rows, plan=plan)

        if w.label is not None or w.cls is not None:
            v = _resolve_view_index(w, f, sel.columns)
            # class = c picks the one-vs-all view; a conjoined label = ±1
            # picks the polarity within it (default: the members)
            positive = (w.label != -1)
            # scan route: schedule the prospective band's pages for
            # readahead BEFORE the catch-up relabel iterates it (advisory;
            # no-op without a storage tier + prefetcher)
            f.prefetch_band(v)
            ids = f.members(v, positive=positive)
            if sel.limit is not None:
                ids = ids[:sel.limit]
            cols = sel.columns or ["id", "label"]
            lab = 1 if positive else -1
            rows = [self._row(cols, f, int(i), view=v, label=lab)
                    for i in ids]
            return Result(tuple(cols), rows, plan=plan)

        # bare scan: every entity's label of one view
        v = _resolve_view_index(w, f, sel.columns)
        cols = sel.columns or ["id", "label"]
        f.prefetch_band(v)                   # advisory band readahead
        pos = set(int(x) for x in f.members(v, True))   # catches up the view
        ids = np.arange(f.n)
        if sel.limit is not None:
            ids = ids[:sel.limit]
        rows = [self._row(cols, f, int(i), view=v,
                          label=(1 if int(i) in pos else -1))
                for i in ids]
        return Result(tuple(cols), rows, plan=plan)

    def _select_point(self, sel: Select, f, w: Where, plan: Plan) -> Result:
        cols = sel.columns or ["id", "label"]
        all_views = f.num_views > 1 and w.view is None and "view" in cols
        if w.label is not None and "class" in cols:
            raise PlanError("a label predicate cannot be combined with the "
                            "class column on a point lookup")
        # each id yields >= 1 row, so never probe more ids than LIMIT rows
        ids = w.ids if sel.limit is None else w.ids[:max(1, sel.limit)]
        rows: List[tuple] = []
        tiers: List[str] = []
        pr = trace.start("probe", ids=len(ids))
        try:
            for i in ids:
                if "class" in cols:
                    cls = f.predict(int(i))
                    rows.append(self._row(cols, f, int(i), cls=cls))
                    tiers.append("probe" if f.policy == "hybrid" else "map")
                elif "margin" in cols:
                    v = _resolve_view_index(w, f, cols)
                    z = f.margin(int(i), v)
                    if w.label is not None \
                            and (1 if z >= 0 else -1) != w.label:
                        continue       # conjoined label predicate filters
                    rows.append(self._row(cols, f, int(i), view=v,
                                          label=(1 if z >= 0 else -1),
                                          margin=z))
                    tiers.append("disk")
                elif all_views:
                    labels, hows = f.point_labels_of(int(i))
                    tiers.extend(hows)
                    for v in range(f.num_views):
                        if w.label is not None and int(labels[v]) != w.label:
                            continue
                        rows.append(self._row(cols, f, int(i), view=v,
                                              label=int(labels[v])))
                else:
                    v = _resolve_view_index(w, f, cols)
                    lab, how = f.point_label(int(i), v)
                    tiers.append(how)
                    if w.label is not None and lab != w.label:
                        continue       # conjoined label predicate filters
                    rows.append(self._row(cols, f, int(i), view=v, label=lab))
            pr.attrs["tiers"] = ",".join(tiers)
        finally:
            trace.finish(pr)
        if sel.limit is not None:
            rows = rows[:sel.limit]
        return Result(tuple(cols), rows, plan=plan, tiers_used=tiers)

    @staticmethod
    def _row(cols: Sequence[str], f, entity_id: int, *, view: int = 0,
             label: Optional[int] = None, margin: Optional[float] = None,
             cls: Optional[int] = None) -> tuple:
        out = []
        for c in cols:
            if c == "id":
                out.append(entity_id)
            elif c == "view":
                out.append(view)
            elif c == "label":
                out.append(label if label is not None
                           else f.label(entity_id, view))
            elif c == "margin":
                out.append(margin if margin is not None
                           else f.margin(entity_id, view))
            elif c == "class":
                out.append(cls if cls is not None else f.predict(entity_id))
            else:
                raise PlanError(f"unknown column {c!r}")
        return tuple(out)

    # -- EXPLAIN -------------------------------------------------------
    def _explain(self, stmt: Statement, prepared: Dict[str, _Prepared],
                 analyze: bool = False) -> Result:
        with trace.span("plan"):
            plan = plan_statement(stmt, self.catalog, self.log)
        if analyze:
            return self._explain_analyze(stmt, prepared, plan)
        cols = ("step", "tier", "est_touched_tuples", "detail")
        rows = [plan.row()]
        if isinstance(stmt, Select) and stmt.where is not None \
                and stmt.where.ids is not None and not stmt.count \
                and "margin" not in stmt.columns \
                and "class" not in stmt.columns \
                and self.catalog.view(stmt.view).facade.policy == "hybrid":
            # dry-run the probe: for a point SELECT the actual §3.5.2 tier
            # is cheapest to *measure* (one eps-map probe), and that is
            # what the acceptance contract asks EXPLAIN to report.
            vd = self.catalog.view(stmt.view)
            f = vd.facade
            used = []
            w = stmt.where
            all_views = f.num_views > 1 and w.view is None \
                and "view" in stmt.columns
            for i in w.ids:
                if 0 <= i < f.n:
                    if all_views:
                        _, hows = f.point_labels_of(int(i))
                        used.extend(hows)
                    else:
                        v = _resolve_view_index(w, f, stmt.columns)
                        _, how = f.point_label(int(i), v)
                        used.append(how)
            rows.append(("probe(actual)", "/".join(used),
                         sum(h == "disk" for h in used),
                         "tiers actually used by the dry-run probe"))
        return Result(cols, rows, plan=plan)

    def _explain_analyze(self, stmt: Statement,
                         prepared: Dict[str, _Prepared],
                         plan: Plan) -> Result:
        """EXPLAIN ANALYZE: EXECUTE the inner statement (Postgres
        semantics — DML commits!) and annotate the plan with the measured
        span tree plus the EXACT per-tier counter deltas it caused. The
        tier row is computed from the facade's `tier_hits` (and the pool's
        counters) sampled before/after, so it reconciles with the registry
        by construction."""
        target = stmt
        if isinstance(target, ExecutePrepared):
            ps = prepared.get(target.name)
            if ps is not None:
                target = ps.stmt
        f = None
        if isinstance(target, Select):
            try:
                f = self.catalog.view(target.view).facade
            except PlanError:
                f = None               # dispatch raises the real error
        tiers0 = dict(f.tier_hits) if f is not None else None
        st0 = f.storage_stats() if f is not None else None
        sp = trace.start("analyze")
        try:
            inner = self._dispatch(stmt, prepared)
        finally:
            trace.finish(sp)
        cols = ("phase", "actual_us", "detail")
        rows: List[tuple] = [("plan", "-", ";".join(
            str(x) for x in plan.row()))]
        def emit(s: Span, depth: int):
            attrs = ";".join(f"{k}={v}" for k, v in s.attrs.items())
            rows.append(("  " * depth + s.name, f"{s.duration_us:.1f}",
                         attrs))
            for c in s.children:
                emit(c, depth + 1)

        emit(sp, 0)
        if tiers0 is not None:
            delta = {t: f.tier_hits[t] - tiers0.get(t, 0) for t in TIERS}
            rows.append(("tiers", "-",
                         ";".join(f"{t}={delta[t]}" for t in TIERS)))
        st1 = f.storage_stats() if f is not None else None
        if st0 is not None and st1 is not None:
            rows.append(("pool", "-",
                         f"hits={st1['hits'] - st0['hits']};"
                         f"misses={st1['misses'] - st0['misses']};"
                         f"coalesced={st1['coalesced'] - st0['coalesced']}"))
        rows.append(("epoch", "-", str(self.log.commits)))
        rows.append(("rows", "-", str(len(inner.rows))))
        return Result(cols, rows, plan=plan, tiers_used=inner.tiers_used)

    # -- SHOW METRICS / SHOW COST --------------------------------------
    def _show_metrics(self) -> Result:
        """The registry snapshot flattened to sorted dotted keys (nested
        collector dicts included; list-valued entries — e.g. per-view cost
        rows, histogram bucket arrays — are summarized, not exploded)."""
        flat: Dict[str, object] = {}

        def add(prefix: str, obj):
            if isinstance(obj, dict):
                for k in obj:
                    add(f"{prefix}.{k}" if prefix else str(k), obj[k])
            elif isinstance(obj, (list, tuple)):
                flat[prefix] = f"<{len(obj)} entries>"
            elif isinstance(obj, float):
                flat[prefix] = f"{obj:.6g}"
            else:
                flat[prefix] = obj

        add("", self.metrics_snapshot())
        return Result(("metric", "value"),
                      [(k, flat[k]) for k in sorted(flat)])

    def _show_cost(self, name: Optional[str]) -> Result:
        """SHOW COST ON <view>: per-view modeled-vs-measured SKIING rows —
        the modeled S / accumulated charges next to the wall-clock
        reorganize and step timings the engine recorded alongside them."""
        vd = self.catalog.view(name)
        stats = vd.facade.cost_stats()
        if stats is None:
            raise SqlError(f"view {name!r} records no cost telemetry "
                           f"(engine=sharded keeps its state on-device)")
        cols = ("view", "v", "policy", "cost_mode", "S_model",
                "S_measured_mean_s", "reorgs", "steps", "charge_modeled",
                "seconds_measured", "seconds_per_charge", "acc",
                "lazy_waste")

        def fmt(x):
            if x is None:
                return "-"
            if isinstance(x, float):
                return f"{x:.6g}"
            return x

        rows = [(name, r["view"], r["policy"], r["cost_mode"],
                 fmt(r["S_model"]), fmt(r["S_measured_mean_s"]),
                 r["reorgs_modeled"], r["steps_measured"],
                 fmt(r["charge_modeled"]), fmt(r["seconds_measured"]),
                 fmt(r["seconds_per_charge"]), fmt(r["acc"]),
                 fmt(r.get("lazy_waste")))
                for r in stats]
        return Result(cols, rows)


class Session:
    """One client's view of a shared Executor: a private prepared-statement
    cache (PREPARE names are session-scoped, like every real wire
    protocol) over the shared catalog/WAL/engines. The SQL server opens
    one per connection; N sessions drive one Executor concurrently and the
    epoch gate arbitrates."""

    _ids = itertools.count(1)

    def __init__(self, executor: Executor):
        self.executor = executor
        self.session_id = next(Session._ids)
        self.prepared: Dict[str, _Prepared] = {}
        self.statements = 0

    def execute(self, sql: str) -> List[Result]:
        self.statements += 1
        return self.executor.execute(sql, prepared=self.prepared)

    def execute_one(self, sql: str) -> Result:
        self.statements += 1
        return self.executor.execute_one(sql, prepared=self.prepared)

    def execute_prepared(self, name: str,
                         params: Sequence[float] = ()) -> Result:
        self.statements += 1
        return self.executor.execute_prepared(name, params,
                                              prepared=self.prepared)
