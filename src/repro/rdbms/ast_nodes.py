"""Statement AST of the SQL dialect (and the front-end error hierarchy).

One dataclass per statement kind; the parser builds these, the planner
prices them, the executor runs them. `Where` is deliberately tiny — the
dialect supports exactly the predicates the paper's workloads need (point
lookups, label/class membership scans, top-k margins), so the planner can
always route to a §3.5 tier instead of a generic filter scan.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union


class SqlError(Exception):
    """Base of every front-end error (lex, parse, plan, execution)."""


class PlanError(SqlError):
    """Catalog / planner / option-validation errors (defined here, at the
    bottom of the import graph, so the typed option schemas can raise it;
    `repro.rdbms.catalog` re-exports it for its historical import path)."""


@dataclasses.dataclass(frozen=True)
class Param:
    """A `?` placeholder inside a PREPAREd statement, numbered in parse
    order; EXECUTE binds positional values over these."""
    index: int


@dataclasses.dataclass
class Where:
    """Conjunction of the supported predicates (any subset may be set)."""
    ids: Optional[List[int]] = None        # id = n  /  id IN (...)
    label: Optional[int] = None            # label = ±1
    cls: Optional[int] = None              # class = c (multiclass views)
    view: Optional[int] = None             # view = v (selects one o-v-a view)

    def is_point(self) -> bool:
        return self.ids is not None


@dataclasses.dataclass
class CreateTable:
    name: str
    corpus: str                            # repro.data corpus factory name
    options: dict


@dataclasses.dataclass
class CreateView:
    name: str
    table: str
    model: str                             # "svm" | "logistic"
    options: dict                          # policy=, k=, engine=, buffer_frac=, ...


@dataclasses.dataclass
class Insert:
    table: str
    rows: List[Tuple[int, float]]          # (entity_id, label/class)


@dataclasses.dataclass
class Update:
    table: str
    entity_id: int
    label: float                           # SET label = y WHERE id = i


@dataclasses.dataclass
class Delete:
    table: str
    entity_id: int


@dataclasses.dataclass
class UpdateModel:
    view: str                              # UPDATE MODEL ON v


@dataclasses.dataclass
class Commit:
    pass


@dataclasses.dataclass
class Select:
    view: str
    columns: List[str]                     # id/view/label/margin/class, or *
    count: bool = False                    # SELECT COUNT(*)
    where: Optional[Where] = None
    order_by: Optional[str] = None         # only "margin"
    descending: bool = True
    limit: Optional[int] = None


@dataclasses.dataclass
class Explain:
    """EXPLAIN <stmt> plans without executing; EXPLAIN ANALYZE <stmt>
    EXECUTES the inner statement (Postgres semantics — DML included) and
    annotates the plan with the measured span tree and tier deltas."""
    stmt: Statement
    analyze: bool = False


@dataclasses.dataclass
class AlterView:
    """ALTER VIEW v SUSPEND | RESUME | REFRESH | SET (opt = val, ...)."""
    view: str
    action: str                            # "suspend"|"resume"|"refresh"|"set"
    options: dict = dataclasses.field(default_factory=dict)  # SET only


@dataclasses.dataclass
class Show:
    what: str      # "tables" | "views" | "storage" | "metrics" | "cost" | "schedule"
    view: Optional[str] = None             # SHOW COST ON <view>


@dataclasses.dataclass
class Prepare:
    """PREPARE name AS <statement with ? placeholders>."""
    name: str
    stmt: Statement
    n_params: int = 0


@dataclasses.dataclass
class ExecutePrepared:
    """EXECUTE name (v1, v2, ...) — binds and runs a prepared statement,
    reusing its cached plan route (point reads skip parse AND plan)."""
    name: str
    params: List[float] = dataclasses.field(default_factory=list)


Statement = Union[CreateTable, CreateView, AlterView, Insert, Update,
                  Delete, UpdateModel, Commit, Select, Explain, Show,
                  Prepare, ExecutePrepared]
