"""Concurrent SQL server: asyncio wire protocol over the shared Executor.

Architecture (thin shell over the `EngineFacade` seam — the serving layer
adds NO engine semantics of its own):

  * one asyncio event loop accepts connections and frames messages
    (`repro.rdbms.wire`: 4-byte length prefix + JSON);
  * each connection gets a `Session` — a private prepared-statement cache
    over the ONE shared `Executor` (catalog, WAL, engines);
  * statement execution is synchronous numpy work, so each request is
    handed to a thread pool; the executor's epoch gate arbitrates — point
    reads on eager/hybrid views run concurrently under a pinned epoch
    (snapshot isolation), group commits serialize exclusively behind
    them (see `repro.rdbms.concurrency`);
  * a session's own DML is always visible to its next read
    (read-your-writes: reads flush the target table's pending group
    before pinning), and the closed loop per connection means the flush
    is ordered after the append.

`SqlServer` is the asyncio core; `ServerHandle`/`start_server_thread` run
it on a background thread for tests, benchmarks, and embedders that live
in sync code.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import logging
import os
import threading
from typing import Optional

from repro.obs import trace
from repro.rdbms.ast_nodes import SqlError
from repro.rdbms.executor import Executor, Result, Session
from repro.rdbms.wire import (WireError, decode_payload, encode_frame,
                              frame_length)

logger = logging.getLogger("repro.rdbms.server")


def _result_payload(res: Result) -> dict:
    out = {"columns": list(res.columns),
           "rows": [list(r) for r in res.rows],
           "epoch": res.epoch}
    if res.plan is not None:
        out["plan"] = {"kind": res.plan.kind, "tier": res.plan.tier,
                       "est_touched": res.plan.est_touched}
    if res.tiers_used is not None:
        out["tiers"] = list(res.tiers_used)
    if res.trace is not None:
        # span-derived timing: the SAME tree EXPLAIN ANALYZE and the REPL
        # footer render, so every surface reports one per-phase breakdown
        out["elapsed_us"] = round(res.trace.duration_us, 1)
        out["phases"] = {c.name: round(c.duration_us, 1)
                         for c in res.trace.children}
    return out


class SqlServer:
    """Asyncio server; construct, `await start()`, then `serve_forever()`
    (or use `start_server_thread` from sync code)."""

    def __init__(self, executor: Optional[Executor] = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 max_workers: Optional[int] = None,
                 log_statements: bool = False):
        self.executor = executor if executor is not None else Executor()
        self.host = host
        self.port = port                    # 0 -> ephemeral; set by start()
        self.log_statements = log_statements    # access log (one INFO line
                                                # per statement) on/off
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max_workers or min(32, (os.cpu_count() or 4) * 4),
            thread_name_prefix="sql-session")
        self._server: Optional[asyncio.AbstractServer] = None
        self.sessions_opened = 0
        self.statements_served = 0

    async def start(self):
        self._server = await asyncio.start_server(
            self._handle_connection, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def serve_forever(self):
        assert self._server is not None, "call start() first"
        async with self._server:
            await self._server.serve_forever()

    async def aclose(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self._pool.shutdown(wait=False)

    # -- one connection == one session ---------------------------------
    async def _handle_connection(self, reader: asyncio.StreamReader,
                                 writer: asyncio.StreamWriter):
        session = Session(self.executor)
        self.sessions_opened += 1
        loop = asyncio.get_running_loop()
        try:
            while True:
                try:
                    header = await reader.readexactly(4)
                except (asyncio.IncompleteReadError, ConnectionError):
                    break
                try:
                    payload = await reader.readexactly(frame_length(header))
                    request = decode_payload(payload)
                except (WireError, ValueError, asyncio.IncompleteReadError):
                    break                   # desynced stream: drop session
                if not isinstance(request, dict):
                    response = {"ok": False, "error": "request must be an "
                                "object", "error_type": "WireError"}
                elif request.get("op") == "close":
                    writer.write(encode_frame({"ok": True, "closed": True}))
                    await writer.drain()
                    break
                else:
                    # run the (GIL-releasing numpy) statement off the loop;
                    # the epoch gate decides who actually runs concurrently
                    response = await loop.run_in_executor(
                        self._pool, self._serve_request, session, request)
                writer.write(encode_frame(response))
                await writer.drain()
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    # -- worker-thread side --------------------------------------------
    def _serve_request(self, session: Session, request: dict) -> dict:
        op = request.get("op")
        try:
            if op == "ping":
                return {"ok": True, "pong": True,
                        "session": session.session_id,
                        "epoch": self.executor.epoch}
            if op == "metrics":
                # the unified telemetry snapshot over the wire — what the
                # CI serve-smoke reconciles and dashboards would scrape
                return {"ok": True,
                        "metrics": self.executor.metrics_snapshot(),
                        "session": session.session_id}
            if op == "refresh":
                # freshness barrier: commit pending DML + refresh every
                # view (or request["view"] + ancestors) in topo order.
                # NOT a statement — it must not skew per-statement
                # telemetry the serve benchmarks reconcile.
                refreshed = self.executor.refresh_views(request.get("view"))
                return {"ok": True, "refreshed": refreshed,
                        "epoch": self.executor.epoch,
                        "session": session.session_id}
            with trace.span("request", metrics=self.executor.metrics,
                            op=op):
                if op == "query":
                    results = session.execute(request["sql"])
                elif op == "execute":
                    results = [session.execute_prepared(
                        request["name"], request.get("params", ()))]
                else:
                    raise SqlError(f"unknown op {op!r}")
            self.statements_served += len(results)
            if self.log_statements:
                for r in results:
                    self._access_log(session, r)
            return {"ok": True,
                    "results": [_result_payload(r) for r in results],
                    "session": session.session_id,
                    "elapsed_us": sum(r.trace.duration_us for r in results
                                      if r.trace is not None)}
        except Exception as e:              # statement errors keep the
            # session alive; the class name crosses the wire (the client
            # re-raises typed) and the server keeps its own trace
            logger.warning("session %s statement failed: %s: %s",
                           session.session_id, type(e).__name__, e)
            if self.log_statements:
                logger.info(
                    "session=%s op=%s kind=- epoch=%s elapsed_us=- error=%s",
                    session.session_id, op, self.executor.epoch,
                    type(e).__name__)
            return {"ok": False, "error": str(e),
                    "error_type": type(e).__name__,
                    "session": session.session_id}

    def _access_log(self, session: Session, res: Result):
        """One structured line per statement (satellite of the telemetry
        layer): session, statement kind, pinned epoch, span-derived µs."""
        kind = res.trace.attrs.get("kind", "?") if res.trace else "?"
        us = f"{res.trace.duration_us:.1f}" if res.trace else "-"
        logger.info("session=%s op=query kind=%s epoch=%s elapsed_us=%s "
                    "error=-", session.session_id, kind, res.epoch, us)


class ServerHandle:
    """A running SqlServer on a background daemon thread (the sync-world
    entry: tests, the benchmark swarm, `--serve` supervisors)."""

    def __init__(self, server: SqlServer, loop: asyncio.AbstractEventLoop,
                 thread: threading.Thread):
        self.server = server
        self._loop = loop
        self._thread = thread

    @property
    def address(self):
        return self.server.host, self.server.port

    def stop(self, timeout: float = 5.0):
        async def _shutdown():
            await self.server.aclose()
        if self._loop.is_running():
            fut = asyncio.run_coroutine_threadsafe(_shutdown(), self._loop)
            try:
                fut.result(timeout)
            except (concurrent.futures.TimeoutError, RuntimeError):
                pass
            try:
                self._loop.call_soon_threadsafe(self._loop.stop)
            except RuntimeError:
                pass                         # loop already wound down
        self._thread.join(timeout)


def start_server_thread(executor: Optional[Executor] = None, *,
                        host: str = "127.0.0.1", port: int = 0,
                        max_workers: Optional[int] = None,
                        log_statements: bool = False,
                        bind_timeout: float = 10.0) -> ServerHandle:
    """Start a SqlServer on its own event loop + daemon thread; returns
    once the socket is bound (raises if binding fails)."""
    server = SqlServer(executor, host=host, port=port,
                       max_workers=max_workers,
                       log_statements=log_statements)
    loop = asyncio.new_event_loop()
    bound = threading.Event()
    failure: list = []

    def _run():
        asyncio.set_event_loop(loop)

        async def _main():
            try:
                await server.start()
            except OSError as e:
                failure.append(e)
                return
            finally:
                bound.set()
            try:
                await server.serve_forever()
            except asyncio.CancelledError:
                pass

        try:
            loop.run_until_complete(_main())
        finally:
            loop.close()

    thread = threading.Thread(target=_run, name="sql-server", daemon=True)
    thread.start()
    if not bound.wait(bind_timeout):
        raise RuntimeError(f"SQL server failed to bind within "
                           f"{bind_timeout}s")
    if failure:
        raise RuntimeError(f"SQL server could not bind "
                           f"{host}:{port}: {failure[0]}") from failure[0]
    return ServerHandle(server, loop, thread)
