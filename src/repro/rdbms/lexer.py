"""Tokenizer for the SQL dialect.

Case-insensitive keywords, Python-style numbers (incl. negative and
floats like `0.05`, `1e-4`, `inf`), identifiers, single-quoted strings,
and the punctuation the grammar needs. Statements are `;`-separated; the
lexer keeps positions so errors point at the offending character.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List

from repro.rdbms.ast_nodes import SqlError


class LexError(SqlError):
    pass


# token kinds: KW (keyword), IDENT, NUMBER, STRING, PUNCT, END
KEYWORDS = {
    "create", "table", "classification", "view", "on", "using", "model",
    "with", "from", "corpus", "insert", "into", "values", "update", "set",
    "where", "delete", "commit", "select", "explain", "analyze", "order",
    "by", "limit", "asc", "desc", "and", "in", "count", "show", "tables",
    "views", "storage", "metrics", "cost", "prepare", "execute", "as",
    "alter", "suspend", "resume", "refresh", "schedule",
}

_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<number>[+-]?(?:\d+\.?\d*|\.\d+)(?:[eE][+-]?\d+)?|[+-]?inf(?![A-Za-z_0-9]))
  | (?P<ident>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<string>'[^']*')
  | (?P<punct>[(),=*;?])
""", re.VERBOSE)


@dataclasses.dataclass(slots=True)
class Token:
    kind: str         # KW | IDENT | NUMBER | STRING | PUNCT | END
    value: str        # keywords/idents lowered; punct verbatim
    pos: int

    def __repr__(self):
        return f"{self.kind}:{self.value}"


def tokenize(sql: str) -> List[Token]:
    # finditer + a running end-position check (any gap = an unlexable
    # character) is measurably faster than per-position re.match — the
    # lexer sits on the batched-DML hot path, where statement parsing is
    # the whole front-end overhead the benchmarks report.
    out: List[Token] = []
    append = out.append
    keywords = KEYWORDS
    end = 0
    for m in _TOKEN_RE.finditer(sql):
        if m.start() != end:
            raise LexError(f"unexpected character {sql[end]!r} at {end}")
        end = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        text = m.group()
        if kind == "number":
            append(Token("NUMBER", text, m.start()))
        elif kind == "ident":
            low = text.lower()
            append(Token("KW" if low in keywords else "IDENT", low,
                         m.start()))
        elif kind == "string":
            append(Token("STRING", text[1:-1], m.start()))
        else:
            append(Token("PUNCT", text, m.start()))
    if end != len(sql):
        raise LexError(f"unexpected character {sql[end]!r} at {end}")
    append(Token("END", "", len(sql)))
    return out
