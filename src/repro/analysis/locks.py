"""LCK — the lock-order pass over `repro.rdbms` / `repro.storage`.

The three system locks and their declared partial order (the runtime
witness in `repro.analysis.witness` enforces the same table live):

    gate (0)        `EpochGate.read()/.write()` — acquired via
                    `with <...>gate.read():` / `.write()`; NOT reentrant.
    wal_commit (1)  `UpdateLog._commit_lock` — any `._commit_lock`
                    attribute; RLock, self-reacquisition legal
                    (`append` -> `flush`).
    pool (2)        `BufferPool._lock` — any `._lock` attribute in the
                    scanned packages (the only `._lock` there is the
                    pool's); RLock, self-reacquisition legal
                    (`repin_rows` -> `pin_rows` -> `_admit`).

Rules:

    LCK001  order inversion — acquiring a lower-level lock (directly or
            transitively through resolved calls) while a higher-level
            one is held, or re-entering the non-reentrant gate.
    LCK002  bare `.acquire()` on a known lock without the
            acquire/try/finally-release shape (`with` is the blessed
            form).
    LCK003  a blocking operation while holding the POOL lock: `open()`,
            `os.fsync`/`os.read`/`os.write`, `time.sleep`, file-handle
            `.write()`/`.flush()`/`.read()`/`.seek()`, socket
            send/recv/accept/connect, or a `.wait()` on any condition —
            the pool lock is the innermost, hottest lock; parking on it
            stalls every concurrent probe.
    LCK004  disk I/O under the pool lock: `.read_page()`/`.read_pages()`
            (the `EntityStore` cold-read surface — matched by attribute
            name, wherever the receiver came from) called, directly or
            transitively, while the pool lock is held. The async read
            path (pool.py's latch/in-flight protocol) exists precisely
            so every cold mmap copy runs OFF that lock; re-inlining one
            is a build error here and a `LockOrderError` under the
            armed witness (`EntityStore` calls
            `witness.assert_unlocked("pool", ...)` before each copy).

Acquisition is resolved through helpers with the typed-receiver call
graph (`repro.analysis.callgraph`), so `repin_rows` holding the pool
lock "sees" everything `pin_rows` and `_admit` may do.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import CallGraph, FunctionInfo
from repro.analysis.common import Finding, ModuleSet, trailing_name
from repro.analysis.witness import LOCK_ORDER, REENTRANT

_FILE_HANDLES = {"_fh", "fh"}
_FILE_OPS = {"write", "flush", "read", "seek", "truncate"}
_SOCKET_OPS = {"sendall", "send", "recv", "accept", "connect", "listen"}
_OS_BLOCKING = {"fsync", "fdatasync", "read", "write", "sendfile"}


def _lock_of(expr: ast.AST, graph: CallGraph) -> Optional[str]:
    """The lock id a `with`-item context expression acquires, if any."""
    if isinstance(expr, ast.Attribute):
        if expr.attr == "_commit_lock":
            return "wal_commit"
        if expr.attr == "_lock":
            return "pool"
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Attribute):
        if expr.func.attr in ("read", "write"):
            recv = trailing_name(expr.func.value)
            if recv == "gate" or graph.receiver_types.get(recv) == "EpochGate":
                return "gate"
    return None


def _lock_of_method_call(call: ast.Call,
                         graph: CallGraph) -> Optional[Tuple[str, str]]:
    """(lock_id, method) for `.acquire()`/`.release()` on a known lock."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr in ("acquire", "release"):
        lock = _lock_of(f.value, graph)
        if lock is not None:
            return lock, f.attr
    return None


def _blocking_op(call: ast.Call) -> Optional[Tuple[str, str]]:
    """(rule, descriptor) if `call` is a known blocking primitive under
    the pool lock, else None. LCK004 tags the disk-read surface, LCK003
    every other blocking primitive — the rule id rides the effect sets
    through the call-graph fixpoint so via-callee findings keep it."""
    f = call.func
    if isinstance(f, ast.Name) and f.id == "open":
        return ("LCK003", "open()")
    if isinstance(f, ast.Attribute):
        recv = trailing_name(f.value)
        if f.attr in ("read_page", "read_pages"):
            # matched by NAME: the only read_page/read_pages surface in
            # the scanned packages is the EntityStore cold read, and
            # receiver-type resolution is too coarse to rely on here
            return ("LCK004", f"{recv or '<expr>'}.{f.attr}() disk page "
                              f"read")
        if recv == "os" and f.attr in _OS_BLOCKING:
            return ("LCK003", f"os.{f.attr}()")
        if recv == "time" and f.attr == "sleep":
            return ("LCK003", "time.sleep()")
        if recv in _FILE_HANDLES and f.attr in _FILE_OPS:
            return ("LCK003", f"{recv}.{f.attr}() file I/O")
        if recv is not None and "sock" in recv and f.attr in _SOCKET_OPS:
            return ("LCK003", f"{recv}.{f.attr}() socket I/O")
        if f.attr == "wait":
            return ("LCK003", f"{recv}.wait()")
    return None


def check_locks(modules: ModuleSet, graph: CallGraph) -> List[Finding]:
    findings: List[Finding] = []

    # -- per-function direct effect sets -------------------------------
    direct_acquires: Dict[str, Set[str]] = {}
    direct_blocks: Dict[str, Set[Tuple[str, str]]] = {}   # (rule, op)
    for qual, info in graph.functions.items():
        acq: Set[str] = set()
        blk: Set[Tuple[str, str]] = set()
        for node in ast.walk(info.node):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    lock = _lock_of(item.context_expr, graph)
                    if lock is not None:
                        acq.add(lock)
            elif isinstance(node, ast.Call):
                lm = _lock_of_method_call(node, graph)
                if lm is not None and lm[1] == "acquire":
                    acq.add(lm[0])
                op = _blocking_op(node)
                if op is not None:
                    blk.add(op)
        direct_acquires[qual] = acq
        direct_blocks[qual] = blk

    may_acquire = graph.fixpoint(direct_acquires)
    may_block = graph.fixpoint(direct_blocks)

    # -- walk each function with the held-lock stack -------------------
    for info in graph.functions.values():
        findings.extend(_walk_function(info, graph, may_acquire,
                                       may_block, modules))
    return findings


def _check_acquire(lock: str, held: List[Tuple[str, int]], node: ast.AST,
                   info: FunctionInfo, modules: ModuleSet,
                   via: Optional[str] = None) -> List[Finding]:
    out = []
    suffix = f" (via call to {via})" if via else ""
    for held_lock, held_line in held:
        if LOCK_ORDER[held_lock] > LOCK_ORDER[lock]:
            out.append(modules.finding(
                info.path, node, "LCK001",
                f"lock-order inversion: acquires {lock!r} (level "
                f"{LOCK_ORDER[lock]}) while holding {held_lock!r} (level "
                f"{LOCK_ORDER[held_lock]}, taken at line {held_line})"
                f"{suffix}"))
        elif held_lock == lock and lock not in REENTRANT:
            out.append(modules.finding(
                info.path, node, "LCK001",
                f"non-reentrant {lock!r} reacquired while already held "
                f"(taken at line {held_line}){suffix}"))
    return out


def _walk_function(info: FunctionInfo, graph: CallGraph,
                   may_acquire: Dict[str, Set[str]],
                   may_block: Dict[str, Set[Tuple[str, str]]],
                   modules: ModuleSet) -> List[Finding]:
    findings: List[Finding] = []

    def pool_held(held):
        return next((ln for lk, ln in held if lk == "pool"), None)

    def visit(node: ast.AST, held: List[Tuple[str, int]]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not info.node:
            return                     # nested defs are separate functions
        if isinstance(node, (ast.With, ast.AsyncWith)):
            locks_here = []
            for item in node.items:
                lock = _lock_of(item.context_expr, graph)
                if lock is not None:
                    findings.extend(_check_acquire(
                        lock, held, item.context_expr, info, modules))
                    locks_here.append((lock, node.lineno))
                else:
                    # non-lock context (e.g. `with open(...)`): its
                    # expression can itself block under an outer lock
                    visit(item.context_expr, held + locks_here)
            for child in node.body:
                visit(child, held + locks_here)
            return
        if isinstance(node, ast.Call):
            lm = _lock_of_method_call(node, graph)
            if lm is not None and lm[1] == "acquire":
                findings.extend(_check_acquire(lm[0], held, node, info,
                                               modules))
                if not _acquire_release_shape(node, info):
                    findings.append(modules.finding(
                        info.path, node, "LCK002",
                        f"bare .acquire() of {lm[0]!r} without the "
                        f"try/finally release shape — use `with`"))
            rule_op = _blocking_op(node)
            pl = pool_held(held)
            if rule_op is not None and pl is not None:
                rule, op = rule_op
                findings.append(modules.finding(
                    info.path, node, rule,
                    f"blocking operation {op} while holding the pool "
                    f"lock (taken at line {pl})"))
            for callee in set(graph.callees_of_call(info, node)):
                for lock in sorted(may_acquire[callee.qualname]):
                    findings.extend(_check_acquire(
                        lock, held, node, info, modules,
                        via=callee.qualname))
                if pl is not None:
                    for rule, op in sorted(may_block[callee.qualname]):
                        findings.append(modules.finding(
                            info.path, node, rule,
                            f"blocking operation {op} reachable via "
                            f"{callee.qualname} while holding the pool "
                            f"lock (taken at line {pl})"))
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for child in ast.iter_child_nodes(info.node):
        visit(child, [])
    return findings


def _acquire_release_shape(call: ast.Call, info: FunctionInfo) -> bool:
    """True iff `call` (a lock `.acquire()`) is paired with a
    try/finally `.release()`: either the statement right before a Try
    whose finalbody releases, or inside such a Try's body."""
    target = trailing_name(call.func.value)

    def releases(try_node: ast.Try) -> bool:
        for stmt in try_node.finalbody:
            for sub in ast.walk(stmt):
                if (isinstance(sub, ast.Call)
                        and isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "release"
                        and trailing_name(sub.func.value) == target):
                    return True
        return False

    for node in ast.walk(info.node):
        if not isinstance(node, ast.Try) or not releases(node):
            continue
        # inside the guarded try body?
        for stmt in node.body:
            if any(sub is call for sub in ast.walk(stmt)):
                return True
    # statement immediately preceding a guarded Try
    for node in ast.walk(info.node):
        body = getattr(node, "body", None)
        if not isinstance(body, list):
            continue
        for i, stmt in enumerate(body[:-1]):
            if any(sub is call for sub in ast.walk(stmt)):
                nxt = body[i + 1]
                if isinstance(nxt, ast.Try) and releases(nxt):
                    return True
    return False
