"""FRS — the single-source-freshness pass (lands WITH the scheduler).

The freshness scheduler's correctness argument is topological: refreshes
replay commit batches down the view DAG in `Catalog.topo_order()`, and
every piece of per-view freshness state (`ViewRuntime`: the inbox of
committed-but-unapplied batches, the staleness / last-refresh stamps, the
SUSPEND flag) is mutated inside the scheduler's gate-exclusive refresh
section and nowhere else. A module that re-derives DAG order from the raw
edges, or flips freshness state on its own, forks those semantics
silently — labels would stop being bit-identical to the immediate replay.

    FRS001  (a) direct access to the catalog's DAG-edge attributes
            (`.upstreams` / `.downstreams`) outside `repro.rdbms.catalog`
            — consume `Catalog.topo_order()` / `parents_of()` /
            `children_of()` / `subtree_of()` instead of re-deriving
            refresh order;
            (b) mutation of view freshness state (an assignment /
            aug-assignment to a `ViewRuntime` field, or an in-place call
            like `.inbox.append(...)`) outside `repro.scheduler` — route
            the change through the scheduler's refresh/suspend/resume
            functions, which run under the executor's exclusive gate.

Exemptions: `repro/scheduler/` (it IS the scheduler) for both shapes, and
`repro/rdbms/catalog.py` for the edge attributes (it owns them and serves
the sanctioned accessors).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from repro.analysis.common import Finding, ModuleSet

#: the catalog's DAG-edge attributes — owned by catalog.py.
_EDGE_ATTRS = {"upstreams", "downstreams"}

#: `ViewRuntime` fields distinctive enough to flag by name alone.
_STATE_FIELDS = {"suspended", "inbox", "stale_since", "last_refresh_at",
                 "upstream_version_seen", "batches_applied", "rows_applied"}

#: in-place mutators — `.inbox.append(...)` is as much a write as `=`.
_MUTATOR_CALLS = {"append", "extend", "clear", "insert", "pop", "remove"}


def _in_scheduler(path: Path) -> bool:
    return "scheduler" in path.parts


def _is_catalog(path: Path) -> bool:
    return path.name == "catalog.py" and "rdbms" in path.parts


def _chain_attrs(node: ast.AST) -> set:
    """Attribute names along one value chain: `vd.runtime.inbox` ->
    {runtime, inbox}."""
    out = set()
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if isinstance(node, ast.Attribute):
            out.add(node.attr)
        node = node.value
    return out


def _touches_state(node: ast.AST) -> bool:
    attrs = _chain_attrs(node)
    return bool(attrs & _STATE_FIELDS) or "runtime" in attrs


def check_freshness(modules: ModuleSet) -> List[Finding]:
    findings: List[Finding] = []
    for path, tree in modules.trees.items():
        if _in_scheduler(path):
            continue
        flagged_lines = set()

        def flag(node, message):
            key = (getattr(node, "lineno", 0), message[:24])
            if key in flagged_lines:
                return
            flagged_lines.add(key)
            findings.append(modules.finding(path, node, "FRS001", message))

        for node in ast.walk(tree):
            # (a) raw DAG-edge access — re-deriving refresh order
            if (isinstance(node, ast.Attribute)
                    and node.attr in _EDGE_ATTRS
                    and not _is_catalog(path)):
                flag(node,
                     f"direct DAG-edge access .{node.attr} outside the "
                     f"catalog — refresh order comes from "
                     f"Catalog.topo_order()/parents_of()/children_of(), "
                     f"never from the raw edges")
            # (b) freshness-state writes outside the scheduler
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, (ast.Attribute, ast.Subscript)) \
                            and _touches_state(t):
                        flag(node,
                             "freshness-state mutation outside "
                             "repro.scheduler — ViewRuntime fields change "
                             "only inside the scheduler's gate-exclusive "
                             "refresh section")
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _MUTATOR_CALLS
                    and _touches_state(node.func.value)):
                flag(node,
                     f"in-place freshness-state mutation "
                     f"(.{node.func.attr}) outside repro.scheduler — "
                     f"deliver batches through the scheduler's offer/"
                     f"refresh path, not by editing inboxes")
    return findings
