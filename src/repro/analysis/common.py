"""Shared plumbing for the `repro.analysis` static passes.

Everything here is pure stdlib `ast` work: findings, module discovery,
parsing, and the small name helpers the rule passes share. The passes
never *import* the code under analysis — they parse it — so the suite
runs identically on the real tree and on the deliberately-broken
fixture corpus in `tests/fixtures/analysis/`.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional

#: `src/` root of the repo this package is installed in.
SRC_ROOT = Path(__file__).resolve().parents[2]
#: the package tree scanned by default (`python -m repro.analysis`).
PKG_ROOT = SRC_ROOT / "repro"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def default_files() -> List[Path]:
    """Every module of the installed `repro` tree except this package
    (the analyzers do not analyze themselves)."""
    return [p for p in sorted(PKG_ROOT.rglob("*.py"))
            if "analysis" not in p.relative_to(PKG_ROOT).parts]


class ModuleSet:
    """Parsed modules keyed by path, with display-relative names."""

    def __init__(self, files: Iterable[Path]):
        self.trees: Dict[Path, ast.Module] = {}
        for path in files:
            path = Path(path).resolve()
            self.trees[path] = ast.parse(path.read_text(),
                                         filename=str(path))

    def display(self, path: Path) -> str:
        try:
            return str(path.relative_to(SRC_ROOT.parent))
        except ValueError:
            return str(path)

    def finding(self, path: Path, node: ast.AST, rule: str,
                message: str) -> Finding:
        return Finding(self.display(path), getattr(node, "lineno", 0),
                       rule, message)


def trailing_name(node: ast.AST) -> Optional[str]:
    """The rightmost identifier of a name-ish expression:
    `hw` -> hw, `self.hw` -> hw, `eng.hw[v]` -> hw, calls -> None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        return trailing_name(node.value)
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """The leftmost identifier of an attribute/subscript chain:
    `self.model.b` -> self, `eng.lw[v]` -> eng."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def names_in(node: ast.AST) -> set:
    """All trailing identifiers mentioned anywhere inside `node`."""
    out = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out
