"""TEL — the single-source-timing pass.

`repro.obs` is the one sanctioned clock: spans, metrics histograms, and
the SKIING measured-cost recorder all read ``repro.obs.clock`` (an alias
of ``time.perf_counter``), so every duration in the tree is mutually
comparable and EXPLAIN ANALYZE / the server's elapsed_us / the REPL
footer can never disagree about what was measured.

    TEL001  raw wall-clock call outside `repro.obs`: `time.perf_counter()`,
            `time.monotonic()`, `time.process_time()`, `time.time()` (or
            their `_ns` variants, or the same names imported bare).
            Route the measurement through `repro.obs.clock`, a span, or a
            registry histogram instead.

Exemptions: the `repro.obs` package itself (it IS the clock), and
benchmark harnesses (`benchmarks/` drives the timing study from outside
the tree). Aliasing without calling — ``clock = time.perf_counter`` —
is fine and is exactly how `repro.obs` wraps the stdlib. ``time.sleep``
is not a measurement and is never flagged.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from repro.analysis.common import Finding, ModuleSet

_TIMING_FNS = {"perf_counter", "perf_counter_ns", "monotonic",
               "monotonic_ns", "process_time", "process_time_ns",
               "time", "time_ns"}
# bare-name calls that only ever mean the stdlib clock ("time(…)" alone is
# too ambiguous to flag; "perf_counter(…)" is not)
_BARE_FNS = _TIMING_FNS - {"time", "time_ns"}


def _exempt(path: Path) -> bool:
    return "obs" in path.parts or "benchmarks" in path.parts


def _is_raw_clock_call(node: ast.Call) -> bool:
    f = node.func
    if isinstance(f, ast.Attribute):
        return (isinstance(f.value, ast.Name) and f.value.id == "time"
                and f.attr in _TIMING_FNS)
    if isinstance(f, ast.Name):
        return f.id in _BARE_FNS
    return False


def check_telemetry(modules: ModuleSet) -> List[Finding]:
    findings: List[Finding] = []
    for path, tree in modules.trees.items():
        if _exempt(path):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_raw_clock_call(node):
                name = ast.unparse(node.func)
                findings.append(modules.finding(
                    path, node, "TEL001",
                    f"raw clock call {name}() outside repro.obs — use "
                    f"repro.obs.clock / a span / a registry histogram "
                    f"so every duration shares one clock"))
    return findings
