"""Invariant analysis for the repro tree: static passes + lock witness.

Static suite (CLI: `python -m repro.analysis`, CI job `static-analysis`):

  * LCK001-3  lock order / acquire shape / blocking-under-pool-lock
              (`repro.analysis.locks`)
  * SRC001-2  single-source algorithm rules (`.single_source`)
  * PUR001-4  core purity + EngineState immutability (`.purity`)
  * TEL001    single-source timing: raw clock calls outside `repro.obs`
              (`.telemetry`)
  * FRS001    single-source freshness: DAG order from the catalog's
              topological sort only; view freshness state mutated only
              inside `repro.scheduler` (`.freshness`)

Runtime witness (`repro.analysis.witness`, `REPRO_LOCK_WITNESS=1`):
asserts the same gate < wal_commit < pool order live, per thread, with
zero overhead when disabled.

This module keeps imports lazy: `repro.rdbms`/`repro.storage` import
`repro.analysis.witness` on their hot construction paths, and must not
drag the `ast` machinery in with it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence


def run(files: Optional[Sequence] = None,
        rules: Sequence[str] = ("LCK", "SRC", "PUR", "TEL", "FRS")) -> List:
    """Run the selected pass families; returns sorted `Finding`s."""
    from repro.analysis.callgraph import CallGraph
    from repro.analysis.common import ModuleSet, default_files
    from repro.analysis.freshness import check_freshness
    from repro.analysis.locks import check_locks
    from repro.analysis.purity import check_purity
    from repro.analysis.single_source import check_single_source
    from repro.analysis.telemetry import check_telemetry

    modules = ModuleSet(default_files() if files is None else files)
    findings = []
    if "LCK" in rules:
        findings += check_locks(modules, CallGraph(modules))
    if "SRC" in rules:
        findings += check_single_source(modules)
    if "PUR" in rules:
        findings += check_purity(modules)
    if "TEL" in rules:
        findings += check_telemetry(modules)
    if "FRS" in rules:
        findings += check_freshness(modules)
    return sorted(findings)
