"""SRC — the single-source-rule pass.

Every algorithm rule lives exactly once in `core/engine.py` (the
contract `tests/test_engine_core.py` pins by import identity for the
shells). This pass generalizes it repo-wide by looking for
RE-DERIVATIONS of those rules — the raw arithmetic — outside engine.py:

    SRC001  Lemma 3.1 band / Eq. 2 waters logic re-derived: a
            comparison whose operand is an eps/waters bound (`lw`, `hw`,
            anything named `*water*`), e.g. `eps >= hw`, `eps < lw`,
            band masks like `(eps >= lw) & (eps < hw)` — or a
            `searchsorted` probing a sorted-eps array AT a waters bound.
            Use `band_partition` / `probe_partition` / `band_mask` /
            `waters_update` instead.
    SRC002  SKIING charging re-derived: accumulator arithmetic
            (`acc += cost`, `acc = acc + ...`) or a reorganization
            trigger comparing the accumulator (`acc >= alpha * S`).
            Use `skiing_charge` / `skiing_due` instead.

Passing bounds *through* to the engine rules is of course fine:
`band_partition(eps, lw, hw)` mentions `lw`/`hw` as call arguments,
not comparison operands. `_topk_from_sorted`'s `searchsorted(eps_sorted,
c - slack)` probes at a top-margin cutoff, not a waters bound — only
probes whose NEEDLE references a bound are findings.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List

from repro.analysis.common import (Finding, ModuleSet, names_in,
                                   trailing_name)

_BOUND_NAMES = {"lw", "hw"}
_ACC_NAMES = {"acc"}


def _is_bound(node: ast.AST) -> bool:
    name = trailing_name(node)
    if name is None:
        return False
    return name in _BOUND_NAMES or "water" in name


def _is_engine(path: Path) -> bool:
    return path.name == "engine.py" and path.parent.name == "core"


def check_single_source(modules: ModuleSet) -> List[Finding]:
    findings: List[Finding] = []
    for path, tree in modules.trees.items():
        if _is_engine(path):
            continue
        for node in ast.walk(tree):
            # SRC001: comparisons against a waters bound
            if isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                if any(_is_bound(op) for op in operands):
                    findings.append(modules.finding(
                        path, node, "SRC001",
                        "band/waters comparison outside core/engine.py "
                        "— use band_partition/probe_partition/band_mask/"
                        "waters rules"))
                elif any(tn in _ACC_NAMES
                         for op in operands
                         if (tn := trailing_name(op)) is not None) \
                        and "alpha" in names_in(node):
                    findings.append(modules.finding(
                        path, node, "SRC002",
                        "SKIING trigger re-derived outside "
                        "core/engine.py — use skiing_due"))
            # SRC001: searchsorted probing at a waters bound
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, (ast.Attribute, ast.Name))
                  and trailing_name(node.func) == "searchsorted"):
                needles = node.args[1:] + [k.value for k in node.keywords]
                hit = {n for needle in needles for n in names_in(needle)
                       if n in _BOUND_NAMES or "water" in n}
                if hit:
                    findings.append(modules.finding(
                        path, node, "SRC001",
                        f"searchsorted at waters bound(s) "
                        f"{sorted(hit)} outside core/engine.py — use "
                        f"band_partition"))
            # SRC002: accumulator charging arithmetic
            elif isinstance(node, ast.AugAssign) \
                    and trailing_name(node.target) in _ACC_NAMES:
                findings.append(modules.finding(
                    path, node, "SRC002",
                    "SKIING charge accumulation re-derived outside "
                    "core/engine.py — use skiing_charge"))
    return findings
