"""Intra-package call graph with typed-receiver resolution.

The lock pass needs to see *through* helper calls: `UpdateLog.append`
holds the commit lock and calls `self.flush(...)`, which re-takes it;
`BufferPool.repin_rows` holds the pool lock and calls `pin_rows` ->
`_admit`. A name-only call graph would also resolve `history.append(...)`
(a list) to `UpdateLog.append` and invent lock acquisitions that never
happen, so calls are resolved by RECEIVER:

  * `name(...)`            -> functions named `name` in the same module
                              (module-level or nested helpers);
  * `self.m(...)`          -> method `m` of the enclosing class;
  * `recv.m(...)`          -> method `m` of class C only when the
                              receiver's trailing name is *typed*: some
                              scanned assignment `x.recv = C(...)` or
                              `recv = C(...)` binds that name to C;
  * anything else          -> unresolved (no edge). Conservative in the
                              direction of silence for foreign objects
                              (lists, numpy arrays, file handles) whose
                              methods shadow ours by name.

`fixpoint` then propagates per-function effect sets (locks that may be
acquired, blocking operations that may run) from callees to callers
until stable, giving each function a transitive summary the lock pass
checks against the held stack at every call site.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterator, List, Set, Tuple

from repro.analysis.common import ModuleSet, trailing_name


@dataclasses.dataclass(eq=False)      # identity hash: used in sets
class FunctionInfo:
    qualname: str              # module:Class.method or module:func
    path: Path
    cls: str                   # enclosing class name, "" for module level
    name: str                  # bare function name
    node: ast.AST              # FunctionDef / AsyncFunctionDef
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    # calls: (receiver_kind, method). receiver_kind is "" for bare-name
    # calls, "self" for self calls, else the receiver's trailing name.


def _call_sites(fn: ast.AST) -> List[Tuple[str, str]]:
    out = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Name):
            out.append(("", f.id))
        elif isinstance(f, ast.Attribute):
            recv = trailing_name(f.value)
            if recv is not None:
                out.append((recv, f.attr))
    return out


class CallGraph:
    def __init__(self, modules: ModuleSet):
        self.modules = modules
        self.functions: Dict[str, FunctionInfo] = {}
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        self.methods: Dict[Tuple[str, str], List[FunctionInfo]] = {}
        #: receiver trailing name -> class name, inferred from scanned
        #: `<target> = ClassName(...)` assignments.
        self.receiver_types: Dict[str, str] = {}
        for path, tree in modules.trees.items():
            self._collect(path, tree)

    # -- construction --------------------------------------------------
    def _collect(self, path: Path, tree: ast.Module):
        mod = path.stem

        def add(fn: ast.AST, cls: str, prefix: str):
            qual = f"{mod}:{prefix}{fn.name}"
            info = FunctionInfo(qual, path, cls, fn.name, fn,
                                _call_sites(fn))
            self.functions[qual] = info
            self.by_name.setdefault(fn.name, []).append(info)
            if cls:
                self.methods.setdefault((cls, fn.name), []).append(info)
            for sub in ast.walk(fn):
                if (sub is not fn and isinstance(
                        sub, (ast.FunctionDef, ast.AsyncFunctionDef))):
                    # nested helpers resolve as bare-name calls
                    nested = FunctionInfo(f"{qual}.{sub.name}", path, cls,
                                          sub.name, sub, _call_sites(sub))
                    self.functions[nested.qualname] = nested
                    self.by_name.setdefault(sub.name, []).append(nested)

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                add(node, "", "")
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        add(item, node.name, f"{node.name}.")

        classes = {n.name for n in ast.walk(tree)
                   if isinstance(n, ast.ClassDef)}
        self._known_classes = getattr(self, "_known_classes", set())
        self._known_classes |= classes
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                ctor = node.value.func
                cname = (ctor.id if isinstance(ctor, ast.Name)
                         else ctor.attr if isinstance(ctor, ast.Attribute)
                         else None)
                if cname is None:
                    continue
                for tgt in node.targets:
                    recv = trailing_name(tgt)
                    if recv:
                        self.receiver_types[recv] = cname

    # -- resolution ----------------------------------------------------
    def _resolve(self, info: FunctionInfo, recv: str,
                 meth: str) -> Iterator[FunctionInfo]:
        if recv == "":
            for cand in self.by_name.get(meth, []):
                if cand.path == info.path:
                    yield cand
        elif recv in ("self", "cls"):
            yield from self.methods.get((info.cls, meth), [])
        else:
            cname = self.receiver_types.get(recv)
            if cname is not None:
                yield from self.methods.get((cname, meth), [])

    def callees(self, info: FunctionInfo) -> Iterator[FunctionInfo]:
        for recv, meth in info.calls:
            yield from self._resolve(info, recv, meth)

    def callees_of_call(self, info: FunctionInfo,
                        call: ast.Call) -> Iterator[FunctionInfo]:
        """Resolve ONE call node (same receiver rules as `callees`)."""
        f = call.func
        if isinstance(f, ast.Name):
            yield from self._resolve(info, "", f.id)
        elif isinstance(f, ast.Attribute):
            recv = trailing_name(f.value)
            if recv is not None:
                yield from self._resolve(info, recv, f.attr)

    def fixpoint(self, direct: Dict[str, Set]) -> Dict[str, Set]:
        """Propagate effect sets callee -> caller until stable.
        `direct[qualname]` holds a function's own effects; the result
        adds everything reachable through resolved calls."""
        summary = {q: set(direct.get(q, ())) for q in self.functions}
        changed = True
        while changed:
            changed = False
            for qual, info in self.functions.items():
                for callee in self.callees(info):
                    extra = summary[callee.qualname] - summary[qual]
                    if extra:
                        summary[qual] |= extra
                        changed = True
        return summary
