"""PUR — the core-purity pass.

Layer-2 pure steps are the functions that DECLARE an `xp` backend
parameter (engine.py's `apply_model`, `reorganize`, `catch_up`,
`hybrid_probe`, ... — the rule is self-applying, so a fixture or a new
module claiming purity via `xp` is held to the same standard), plus
every Pallas kernel module (`kernels/*/kernel.py`). For those:

    PUR001  direct `np.` use — the backend must come in through `xp`.
            The ONE blessed exception is a backend dispatch guarded by
            `if xp is np:` (numpy-only fast paths like stable argsort);
            kernels get no exception (jnp/lax/pl only).
    PUR002  Python side effects: `print`, `global`/`nonlocal`
            statements, `.item()` host syncs, `time.*`, `input`,
            `os.*` — a jitted step must be a pure function of its
            arguments.
    PUR003  in-place mutation of a parameter (`state_arr[...] = x`,
            `param += y`) — pure steps return new values; mutating an
            argument breaks jit tracing and value semantics. Writes to
            LOCAL arrays and to Pallas `*_ref` output references are
            fine.

Shells (everything outside engine.py in `core/`, `rdbms/`, `storage/`):

    PUR004  in-place mutation of an `EngineState` field on a non-`self`
            object (`state.labels[i] = y`, `eng.lw[v] = 0`).
            `EngineState` is an immutable pytree; shells own their OWN
            mirrors (`self.lw[...] = ...` is their state, fine) but must
            never reach into an engine state they were handed. The field
            list is read from engine.py's `EngineState` class at scan
            time, not hardcoded.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set

from repro.analysis.common import (Finding, ModuleSet, PKG_ROOT, root_name,
                                   trailing_name)

_SIDE_EFFECT_MODULES = {"time", "os", "sys"}


def _is_engine(path: Path) -> bool:
    return path.name == "engine.py" and path.parent.name == "core"


def _is_kernel(path: Path) -> bool:
    return path.name == "kernel.py" and "kernels" in path.parts


def engine_state_fields() -> Set[str]:
    """`EngineState._fields`, read from the real engine.py's AST."""
    engine = PKG_ROOT / "core" / "engine.py"
    if not engine.exists():
        return set()
    tree = ast.parse(engine.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EngineState":
            return {item.target.id for item in node.body
                    if isinstance(item, ast.AnnAssign)
                    and isinstance(item.target, ast.Name)}
    return set()


def _xp_functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            names = [a.arg for a in args.args + args.kwonlyargs
                     + args.posonlyargs]
            if "xp" in names:
                yield node


def _np_guarded_lines(fn: ast.AST) -> Set[int]:
    """Line numbers inside `if xp is np:` bodies (the blessed numpy
    fast-path dispatch) — `np.` use there is allowed."""
    lines: Set[int] = set()

    def is_xp_is_np(test: ast.AST) -> Optional[bool]:
        # returns True for `xp is np`, False for `xp is not np`
        if (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.left, ast.Name)
                and test.left.id == "xp"
                and isinstance(test.comparators[0], ast.Name)
                and test.comparators[0].id == "np"):
            if isinstance(test.ops[0], ast.Is):
                return True
            if isinstance(test.ops[0], ast.IsNot):
                return False
        return None

    for node in ast.walk(fn):
        if not isinstance(node, ast.If):
            continue
        guard = is_xp_is_np(node.test)
        if guard is None:
            continue
        branch = node.body if guard else node.orelse
        for stmt in branch:
            for sub in ast.walk(stmt):
                if hasattr(sub, "lineno"):
                    lines.add(sub.lineno)
    return lines


def _check_pure_function(modules: ModuleSet, path: Path, fn: ast.AST,
                         kernel: bool) -> List[Finding]:
    findings: List[Finding] = []
    where = "Pallas kernel" if kernel else f"pure step {fn.name!r}"
    guarded = set() if kernel else _np_guarded_lines(fn)
    args = fn.args
    params = {a.arg for a in args.args + args.kwonlyargs + args.posonlyargs}

    for node in ast.walk(fn):
        # PUR001: host numpy outside the xp seam
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "np"
                and node.lineno not in guarded):
            findings.append(modules.finding(
                path, node, "PUR001",
                f"direct np.{node.attr} in {where} — use the xp backend "
                f"parameter (or guard with `if xp is np:`)"))
        # PUR002: side effects
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            findings.append(modules.finding(
                path, node, "PUR002",
                f"{type(node).__name__.lower()} statement in {where}"))
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in ("print", "input"):
                findings.append(modules.finding(
                    path, node, "PUR002", f"{f.id}() call in {where}"))
            elif isinstance(f, ast.Attribute):
                if f.attr == "item":
                    findings.append(modules.finding(
                        path, node, "PUR002",
                        f".item() host sync in {where}"))
                elif (isinstance(f.value, ast.Name)
                      and f.value.id in _SIDE_EFFECT_MODULES):
                    findings.append(modules.finding(
                        path, node, "PUR002",
                        f"{f.value.id}.{f.attr}() call in {where}"))
        # PUR003: in-place parameter mutation
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                if isinstance(tgt, (ast.Subscript, ast.Attribute)):
                    root = root_name(tgt)
                    name = trailing_name(tgt)
                    if root in params and not (
                            kernel and name and name.endswith("_ref")):
                        findings.append(modules.finding(
                            path, tgt, "PUR003",
                            f"in-place mutation of parameter {root!r} "
                            f"in {where} — return a new value"))
    return findings


def check_purity(modules: ModuleSet) -> List[Finding]:
    findings: List[Finding] = []
    fields = engine_state_fields()
    shell_dirs = {"core", "rdbms", "storage"}

    for path, tree in modules.trees.items():
        kernel = _is_kernel(path)
        if kernel:
            for node in ast.walk(tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    findings.extend(_check_pure_function(
                        modules, path, node, kernel=True))
            continue
        for fn in _xp_functions(tree):
            findings.extend(_check_pure_function(modules, path, fn,
                                                 kernel=False))
        # PUR004: shells mutating EngineState fields on non-self objects.
        # Applies to core/rdbms/storage modules (engine.py excepted) and
        # to out-of-tree files (the fixture corpus simulates shells);
        # models/launch/data are not EngineState shells.
        if _is_engine(path):
            continue
        try:
            rel_parts = set(path.relative_to(PKG_ROOT).parts[:-1])
        except ValueError:
            rel_parts = None               # outside the package: a shell
        if rel_parts is not None and not (shell_dirs & rel_parts):
            continue
        for node in ast.walk(tree):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for tgt in targets:
                base = tgt.value if isinstance(tgt, ast.Subscript) else tgt
                if not isinstance(base, ast.Attribute):
                    continue
                if base.attr in fields and root_name(base) != "self":
                    findings.append(modules.finding(
                        path, tgt, "PUR004",
                        f"shell mutates EngineState field "
                        f"{base.attr!r} on {root_name(base)!r} — "
                        f"EngineState is immutable; go through an "
                        f"engine rule / _replace"))
    return findings
