"""Runtime lock-order witness (`REPRO_LOCK_WITNESS=1`).

The static pass (`repro.analysis.locks`) proves the declared partial
order over the three system locks *at rest*; this module asserts it
*live*, under real scheduling, during the concurrency tests and the
`serve_concurrent` swarm. The declared order:

    gate (EpochGate, level 0)  <  wal_commit (UpdateLog._commit_lock,
    level 1)  <  pool (BufferPool._lock, level 2)

i.e. a thread holding a higher-level lock must never acquire a
lower-level one. Same-level reacquisition is allowed for the two RLocks
(`wal_commit`, `pool` — WAL `append -> flush` relies on it) and is a
violation for the gate, which is deliberately NOT reentrant.

Zero-overhead when off: `wrap()` returns the *raw* lock unless the
witness is active at construction time, so the production path carries
no wrapper, no branch, nothing. When active, every acquisition pushes
onto a per-thread stack and the order is checked before blocking — the
witness reports the inversion instead of deadlocking on it.

This module is dependency-free (stdlib only) so `repro.rdbms` and
`repro.storage` can import it without layering cycles.
"""
from __future__ import annotations

import contextlib
import os
import threading

#: lock id -> level in the declared partial order (acquire upward only).
LOCK_ORDER = {"gate": 0, "wal_commit": 1, "pool": 2}

#: lock ids that may be reacquired by the holding thread (RLocks).
REENTRANT = frozenset({"wal_commit", "pool"})


class LockOrderError(AssertionError):
    """A thread acquired the three system locks out of declared order."""


class _Witness:
    """Per-thread acquisition stacks + the live order assertion."""

    def __init__(self):
        self.enabled = os.environ.get("REPRO_LOCK_WITNESS") == "1"
        self._tls = threading.local()

    @property
    def active(self) -> bool:
        return self.enabled

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def held(self) -> list:
        """The current thread's held lock ids, acquisition order."""
        return [lock_id for lock_id, _, _ in self._stack()]

    def push(self, lock_id: str, obj: object):
        """Record an acquisition about to happen; raise on inversion.

        Called BEFORE the underlying acquire blocks, so an inversion is
        reported as a `LockOrderError` naming the held stack instead of
        surfacing as a deadlock + test timeout.
        """
        stack = self._stack()
        level = LOCK_ORDER[lock_id]
        for held_id, held_level, held_obj in stack:
            if held_level > level:
                raise LockOrderError(
                    f"lock-order inversion: acquiring {lock_id!r} "
                    f"(level {level}) while holding {held_id!r} "
                    f"(level {held_level}); held stack: {self.held()}")
            if (held_level == level and held_obj == id(obj)
                    and lock_id not in REENTRANT):
                raise LockOrderError(
                    f"non-reentrant {lock_id!r} reacquired by its own "
                    f"holder; held stack: {self.held()}")
        stack.append((lock_id, level, id(obj)))

    def pop(self, lock_id: str, obj: object):
        stack = self._stack()
        key = (lock_id, LOCK_ORDER[lock_id], id(obj))
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == key:
                del stack[i]
                return


#: process-wide singleton; `wrap()` and the EpochGate seam consult it.
WITNESS = _Witness()


def assert_unlocked(lock_id: str, what: str):
    """Witness-armed blocking-I/O guard: raise if the CURRENT thread holds
    `lock_id` while about to run `what` (a blocking operation that must
    stay outside that lock). This is the live twin of the static LCK004
    rule — `EntityStore.read_page`/`read_pages` call it so a disk read
    accidentally re-inlined under the pool lock fails loudly in the
    witness-armed jobs instead of silently re-serializing every probe.
    Free when the witness is off (one attribute check)."""
    if WITNESS.active and lock_id in WITNESS.held():
        raise LockOrderError(
            f"{what} while holding {lock_id!r}; held stack: "
            f"{WITNESS.held()}")


@contextlib.contextmanager
def enabled():
    """Force the witness on for a scope (tests). Locks must be
    *constructed* inside this scope to be wrapped — `wrap` decides at
    construction time."""
    prev = WITNESS.enabled
    WITNESS.enabled = True
    try:
        yield WITNESS
    finally:
        WITNESS.enabled = prev


class WitnessedLock:
    """Thin proxy over a Lock/RLock recording acquisitions with WITNESS.

    Supports the `with` protocol and explicit acquire/release, which is
    all the instrumented call sites use.
    """

    __slots__ = ("_lock", "_lock_id")

    def __init__(self, lock, lock_id: str):
        self._lock = lock
        self._lock_id = lock_id

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        WITNESS.push(self._lock_id, self._lock)
        ok = self._lock.acquire(blocking, timeout)
        if not ok:
            WITNESS.pop(self._lock_id, self._lock)
        return ok

    def release(self):
        self._lock.release()
        WITNESS.pop(self._lock_id, self._lock)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def wrap(lock, lock_id: str):
    """Wrap `lock` for witnessing iff the witness is active NOW.

    The decision is taken at construction time so the disabled path is
    the raw `threading` lock — zero wrapper overhead in production.
    """
    if lock_id not in LOCK_ORDER:
        raise ValueError(f"unknown lock id {lock_id!r}")
    if WITNESS.active:
        return WitnessedLock(lock, lock_id)
    return lock
