"""CLI: `python -m repro.analysis [paths...] [--rules LCK,SRC,PUR]`.

Runs the three invariant pass families over the installed `repro` tree
(or over explicit files/directories — the fixture tests use this),
prints findings as `file:line: RULE-ID message`, and exits non-zero if
there are any. This is the `static-analysis` CI gate.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import run


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant checks: lock order (LCK), "
                    "single-source rules (SRC), core purity (PUR), "
                    "single-source timing (TEL), single-source "
                    "freshness (FRS)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files or directories to scan (default: the "
                         "installed repro tree)")
    ap.add_argument("--rules", default="LCK,SRC,PUR,TEL,FRS",
                    help="comma-separated rule families to run")
    args = ap.parse_args(argv)

    files = None
    if args.paths:
        files = []
        for p in args.paths:
            files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    findings = run(files, rules=args.rules.split(","))
    for f in findings:
        print(f.render())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
