from repro.data.lm import TokenStream
from repro.data.corpora import (forest_like, dblife_like, citeseer_like,
                                synthetic_corpus, example_stream, Corpus)
