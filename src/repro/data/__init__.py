from repro.data.lm import TokenStream
from repro.data.corpora import (forest_like, dblife_like, citeseer_like,
                                cora_like, multiclass_corpus,
                                multiclass_example_stream, MulticlassCorpus,
                                synthetic_corpus, example_stream, Corpus)
