"""Synthetic clones of the paper's data sets (Figure 3 statistics).

  Forest  (FC): 582k entities, 54 dense features        [UCI covtype]
  DBLife  (DB): 124k entities, 41k vocab, ~7 nnz/doc    [bag-of-words, title]
  Citeseer(CS): 721k entities, 682k vocab, ~60 nnz/doc  [bag-of-words, abstract]

The paper stores sparse vectors; TPUs want dense tiles, so sparse corpora go
through the hashing trick into a dense `hash_dim` (documented hardware
adaptation — the Hölder machinery is representation-agnostic as long as
M = max ||f||_q is computed on the *hashed* vectors, which we do).

Labels come from a hidden ground-truth halfspace + flip noise, so SGD
convergence behaves like real data (margin distribution is realistic), and
a training-example stream is available for update benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class Corpus:
    name: str
    features: np.ndarray      # (n, d) float32, row-normalized
    labels: np.ndarray        # (n,) ±1 ground truth
    true_w: np.ndarray        # hidden model (for quality eval)
    true_b: float
    norm: str                 # "l1" | "l2" — which normalization rows carry


def _normalize(x: np.ndarray, norm: str) -> np.ndarray:
    if norm == "l1":
        s = np.sum(np.abs(x), axis=1, keepdims=True)
    else:
        s = np.linalg.norm(x, axis=1, keepdims=True)
    return x / np.maximum(s, 1e-12)


def synthetic_corpus(name: str, n: int, d: int, *, nnz: int = 0, norm: str = "l2",
                     noise: float = 0.02, seed: int = 0,
                     separation: float = 2.5) -> Corpus:
    """Two class-conditional clusters pushed `separation` apart along a
    hidden direction — real corpora (Forest, DBLife) have low margin density
    at the decision boundary after convergence, which is what makes the
    paper's steady-state band ~1% (Fig. 13); an unstructured gaussian cloud
    would not reproduce that."""
    r = np.random.default_rng(seed)
    y = np.where(r.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    if nnz and nnz < d:
        # sparse bag-of-words via hashing trick: nnz active hashed buckets,
        # plus class-specific "topic" columns (db-papers use db words)
        x = np.zeros((n, d), np.float32)
        cols = r.integers(0, d, size=(n, nnz))
        vals = r.exponential(1.0, size=(n, nnz)).astype(np.float32)
        np.put_along_axis(x, cols, vals, axis=1)
        n_topic = max(2, nnz // 3)
        pos_cols = np.arange(n_topic)
        neg_cols = np.arange(n_topic, 2 * n_topic)
        topic = r.exponential(separation, size=(n, n_topic)).astype(np.float32)
        pos = y > 0
        x[np.ix_(pos, pos_cols)] += topic[pos]
        x[np.ix_(~pos, neg_cols)] += topic[~pos]
        u = np.zeros(d, np.float32)
        u[pos_cols] = 1.0
        u[neg_cols] = -1.0
        u /= np.linalg.norm(u)
    else:
        u = r.normal(size=d).astype(np.float32)
        u /= np.linalg.norm(u)
        x = r.normal(size=(n, d)).astype(np.float32) + 0.1
        x += np.outer(y * separation, u)
    x = _normalize(x, norm).astype(np.float32)
    w = u
    b = 0.0
    flip = r.random(n) < noise
    y = y.copy()
    y[flip] *= -1
    return Corpus(name, x, y, w, b, norm)


def forest_like(scale: float = 1.0, seed: int = 0) -> Corpus:
    return synthetic_corpus("FC", max(1000, int(582_000 * scale)), 54,
                            norm="l2", seed=seed)


def dblife_like(scale: float = 1.0, hash_dim: int = 1024, seed: int = 1) -> Corpus:
    return synthetic_corpus("DB", max(1000, int(124_000 * scale)), hash_dim,
                            nnz=7, norm="l1", seed=seed)


def citeseer_like(scale: float = 1.0, hash_dim: int = 4096, seed: int = 2) -> Corpus:
    return synthetic_corpus("CS", max(1000, int(721_000 * scale)), hash_dim,
                            nnz=60, norm="l1", seed=seed)


@dataclasses.dataclass
class MulticlassCorpus:
    name: str
    features: np.ndarray      # (n, d) float32, row-normalized
    classes: np.ndarray       # (n,) int class ids
    num_classes: int


def multiclass_corpus(name: str, n: int, d: int, num_classes: int, *,
                      separation: float = 2.5, norm: str = "l2",
                      seed: int = 0) -> MulticlassCorpus:
    """k class-conditional clusters — the one-vs-all workload of the
    paper's multiclass experiments (App. B.5.4 / C.3)."""
    r = np.random.default_rng(seed)
    centers = (r.normal(size=(num_classes, d)) * separation).astype(np.float32)
    cls = r.integers(0, num_classes, n)
    x = centers[cls] + r.normal(size=(n, d)).astype(np.float32)
    x = _normalize(x, norm).astype(np.float32)
    return MulticlassCorpus(name, x, cls.astype(np.int64), num_classes)


def cora_like(scale: float = 1.0, num_classes: int = 7, hash_dim: int = 64,
              seed: int = 5) -> MulticlassCorpus:
    """Cora: 2708 papers, 7 topics. The binary word vectors go through the
    hashing trick into `hash_dim` dense dims (same adaptation as DB/CS)."""
    return multiclass_corpus("CORA", max(256, int(2708 * scale)), hash_dim,
                             num_classes, seed=seed)


def multiclass_example_stream(corpus: MulticlassCorpus, *, seed: int = 0
                              ) -> Iterator[Tuple[int, int]]:
    """Infinite stream of (entity_id, class) training inserts."""
    r = np.random.default_rng(seed)
    n = corpus.features.shape[0]
    while True:
        i = int(r.integers(0, n))
        yield i, int(corpus.classes[i])


def example_stream(corpus: Corpus, *, seed: int = 0,
                   label_noise: float = 0.02) -> Iterator[Tuple[int, np.ndarray, float]]:
    """Infinite stream of (id, feature, label) training examples — the
    paper's `INSERT INTO Example_Papers` workload."""
    r = np.random.default_rng(seed)
    n = corpus.features.shape[0]
    while True:
        i = int(r.integers(0, n))
        y = corpus.labels[i]
        if r.random() < label_noise:
            y = -y
        yield i, corpus.features[i], float(y)
