"""Deterministic synthetic LM token pipeline.

Tokens follow a first-order Markov chain over a Zipf-distributed vocabulary,
so a language model has real structure to learn (loss decreases) while the
stream stays fully deterministic given (seed, step, shard) — the property
that makes restart-after-failure and straggler shard-reassignment exact:
any host can regenerate any shard of any step without coordination.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    batch: int            # per-host batch
    seq_len: int
    seed: int = 0
    shard: int = 0        # this host's shard index
    num_shards: int = 1

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse Markov structure: each token has a few likely successors
        self._succ = r.integers(0, v, size=(v, 4))
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._zipf = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int):
        """Batch for `step` on this shard. Pure function of its arguments."""
        r = np.random.default_rng(
            (self.seed, step, self.shard, self.num_shards))
        b, s, v = self.batch, self.seq_len, self.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = r.choice(v, size=b, p=self._zipf)
        follow = r.random((b, s)) < 0.8
        succ_pick = r.integers(0, 4, size=(b, s))
        rand_tok = r.choice(v, size=(b, s), p=self._zipf)
        for t in range(s):
            nxt = self._succ[toks[:, t], succ_pick[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, rand_tok[:, t])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
