"""Gradient compression: int8 quantized all-reduce with error feedback.

Cross-pod (DCI) gradient sync is the bandwidth-critical collective at
multi-pod scale; int8 cuts wire bytes 4x vs f32 (2x vs bf16). Scheme:

  scale  = pmax(max|g + err|) / 127          (shared per-tensor scale)
  q      = round((g + err) / scale)  ∈ int8  (stochastic-free, deterministic)
  g_hat  = psum(q) * scale / n_workers
  err'   = (g + err) − q·scale               (error feedback, keeps SGD unbiased
                                              to first order; Karimireddy et al.)

Used on the "pod" axis where link bandwidth is scarcest; the within-pod
reduction stays full-precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x, err, axis_name: str):
    g = x.astype(jnp.float32) + err
    local_max = jnp.max(jnp.abs(g))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_err = g - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress_int8(q_sum, scale, n_workers: int):
    return q_sum.astype(jnp.float32) * scale / n_workers


def error_feedback_init(grads):
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def make_compressed_grad_allreduce(axis_name: str, n_workers: int):
    """Returns fn(grads, err_state) -> (mean_grads, err_state'); call inside
    shard_map with `axis_name` unreduced."""
    def allreduce(grads, err_state):
        def one(g, err):
            q, scale, new_err = compress_int8(g, err, axis_name)
            q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            return decompress_int8(q_sum, scale, n_workers), new_err
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(err_state)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            gh, ne = one(g, e)
            out_g.append(gh.astype(g.dtype))
            out_e.append(ne)
        return (jax.tree_util.tree_unflatten(tdef, out_g),
                jax.tree_util.tree_unflatten(tdef, out_e))
    return allreduce
