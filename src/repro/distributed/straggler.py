"""Straggler mitigation: detection + deterministic data-shard reassignment.

At 1000+ nodes, persistent stragglers (thermal throttling, failing NICs)
stretch every synchronous step. Two pieces, both pure logic (unit-tested
without hardware):

  * StragglerDetector — per-worker EMA of step times; a worker whose EMA
    exceeds `threshold` x the fleet median for `patience` consecutive
    checks is flagged.
  * ShardAssigner — maps data shards -> workers. Because the data pipeline
    is a pure function of (seed, step, shard) [see data/lm.py], moving a
    shard to another worker needs zero data movement: the new owner just
    generates/reads that shard's stream. Flagged workers get their shards
    reassigned to the fastest workers (who run 2 shards — better a 2x load
    on a fast node than a 5x-slow critical path), and the slow worker is
    marked for eviction at the next checkpoint boundary (elastic re-mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerDetector:
    n_workers: int
    ema_alpha: float = 0.2
    threshold: float = 1.5
    patience: int = 3

    def __post_init__(self):
        self.ema: List[Optional[float]] = [None] * self.n_workers
        self.strikes: List[int] = [0] * self.n_workers

    def observe(self, step_times: Dict[int, float]) -> List[int]:
        """Feed per-worker step times; returns list of flagged workers."""
        for w, t in step_times.items():
            e = self.ema[w]
            self.ema[w] = t if e is None else (1 - self.ema_alpha) * e + self.ema_alpha * t
        known = sorted(e for e in self.ema if e is not None)
        if not known:
            return []
        median = known[len(known) // 2]
        flagged = []
        for w in range(self.n_workers):
            e = self.ema[w]
            if e is not None and median > 0 and e > self.threshold * median:
                self.strikes[w] += 1
                if self.strikes[w] >= self.patience:
                    flagged.append(w)
            else:
                self.strikes[w] = 0
        return flagged


@dataclasses.dataclass
class ShardAssigner:
    n_shards: int
    n_workers: int

    def __post_init__(self):
        assert self.n_shards >= self.n_workers
        self.assignment: Dict[int, List[int]] = {
            w: [s for s in range(self.n_shards) if s % self.n_workers == w]
            for w in range(self.n_workers)
        }
        self.evicted: List[int] = []

    def reassign(self, flagged: List[int], detector: StragglerDetector):
        """Move flagged workers' shards to the fastest healthy workers."""
        healthy = [w for w in range(self.n_workers)
                   if w not in flagged and w not in self.evicted]
        if not healthy:
            return self.assignment
        healthy.sort(key=lambda w: detector.ema[w] or 0.0)
        for w in flagged:
            if w in self.evicted:
                continue
            shards = self.assignment.pop(w, [])
            for i, s in enumerate(shards):
                dst = healthy[i % len(healthy)]
                self.assignment[dst].append(s)
            self.evicted.append(w)
        return self.assignment

    def owner_of(self, shard: int) -> int:
        for w, shards in self.assignment.items():
            if shard in shards:
                return w
        raise KeyError(shard)
