from repro.distributed.compression import (compress_int8, decompress_int8,
                                           make_compressed_grad_allreduce,
                                           error_feedback_init)
from repro.distributed.straggler import StragglerDetector, ShardAssigner
