"""Parameter descriptor system.

Models are declared as pytrees of `ParamSpec` (shape, dtype, logical axes,
init). From the same declaration we derive:
  * `abstract_params`  — ShapeDtypeStruct tree (dry-run: no allocation),
  * `init_params`      — materialized arrays (smoke tests / real training),
  * `partition_specs`  — PartitionSpec tree via logical→mesh rules with
                         divisibility fallback (non-divisible dim → replicated).

The divisibility fallback is what makes one rule set serve whisper-tiny
(6 heads) and dbrx (48 heads) alike.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"  # normal | zeros | ones | fan_in
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_spec)


def abstract_params(tree):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree
    )


def _path_seed(path: str, base: int) -> int:
    h = hashlib.md5(f"{base}:{path}".encode()).digest()
    return int.from_bytes(h[:4], "little")


def init_params(tree, seed: int = 0):
    """Materialize parameters deterministically (per-path derived seeds)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=_is_spec)
    leaves = []
    for path, spec in flat:
        pstr = jax.tree_util.keystr(path)
        key = jax.random.PRNGKey(_path_seed(pstr, seed))
        if spec.init == "zeros":
            v = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            v = jnp.ones(spec.shape, spec.dtype)
        elif spec.init == "fan_in":
            fan_in = spec.shape[0] if len(spec.shape) <= 2 else int(np.prod(spec.shape[:-1]))
            std = spec.scale / max(1.0, float(fan_in)) ** 0.5
            v = (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
        else:  # normal
            v = (jax.random.normal(key, spec.shape, jnp.float32) * 0.02 * spec.scale).astype(spec.dtype)
        leaves.append(v)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Logical axis -> mesh axis rules
# ---------------------------------------------------------------------------

# Default "tp" rules: TP on `model`, FSDP on `data`, DP over `pod`+`data`.
LOGICAL_RULES_TP: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),            # d_model: FSDP-sharded on weights
    "heads": ("model",),
    "kv_heads": (),                # GQA kv head count rarely divides tp; see kv_hd
    "head_dim": (),
    "kv_head_dim": ("model",),     # kv projections shard the head_dim instead
    "mlp": ("model",),
    "experts": ("model",),
    "expert_in": ("data",),
    "mamba_inner": ("model",),
    "rwkv_heads": ("model",),
    "layers": (),
    "conv": (),
    "state": (),
    "batch": ("pod", "data"),
    "act_heads": ("model",),
    "act_kv": (),
    "seq": (),
    # Megatron-style sequence parallelism: the residual stream (and therefore
    # the scan's saved per-layer stack) lives seq-sharded over the model axis;
    # mixers/FFNs gather on entry and reduce-scatter on exit. Falls back to
    # replicated automatically when seq doesn't divide (e.g. decode, s=1).
    "seq_sp": ("model",),
    "kv_seq": ("model",),          # decode KV cache: flash-decoding style
    "long_kv_seq": ("data", "model"),
    "entity": ("pod", "data"),     # hazy view rows
    "feature": ("model",),         # hazy view feature dim
    None: (),
}

# "fsdp" rules for tiny models: no TP; params fully sharded over (data, model),
# batch over everything.
LOGICAL_RULES_FSDP: Dict[str, Tuple[str, ...]] = dict(
    LOGICAL_RULES_TP,
    **{
        "vocab": ("model",),
        "embed": ("data",),
        "heads": ("model",),
        "mlp": ("model",),
    },
)

RULE_SETS = {"tp": LOGICAL_RULES_TP, "fsdp": LOGICAL_RULES_FSDP}


def resolve_axes(
    logical: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Dict[str, Tuple[str, ...]],
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible shardings."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    out = []
    for dim, name in zip(shape, logical):
        cand = rules.get(name, ())
        picked = []
        size = 1
        for ax in cand:
            if ax not in mesh_axes or ax in used:
                continue
            if dim % (size * mesh_axes[ax]) == 0:
                picked.append(ax)
                size *= mesh_axes[ax]
        for ax in picked:
            used.add(ax)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    return P(*out)


def partition_specs(tree, mesh: Mesh, rule_set: str = "tp"):
    rules = RULE_SETS[rule_set]
    return tree_map_specs(
        lambda s: resolve_axes(s.axes, s.shape, mesh, rules), tree
    )


def named_shardings(tree, mesh: Mesh, rule_set: str = "tp"):
    return tree_map_specs(
        lambda s: NamedSharding(mesh, resolve_axes(s.axes, s.shape, mesh, rules=RULE_SETS[rule_set])),
        tree,
    )


def logical_sharding(x, logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None,
                     rule_set: str = "tp"):
    """with_sharding_constraint by logical axes. No-op outside a mesh."""
    if mesh is None:
        mesh = current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve_axes(tuple(logical), x.shape, mesh, RULE_SETS[rule_set])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def current_mesh() -> Optional[Mesh]:
    """The mesh from the innermost `with mesh:` context, if any."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None
