"""Step functions (train / prefill / decode) and their abstract input specs.

`input_specs(...)` returns ShapeDtypeStructs **with shardings attached** so
`jax.jit(step).lower(*specs)` on the production mesh needs no separate
in_shardings tree, and nothing is ever allocated (dry-run discipline).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers
from repro.models.params import (ParamSpec, init_params,
                                 resolve_axes, RULE_SETS,
                                 tree_map_specs)
from repro.models.transformer import ModelDef
from repro.optim import adamw_update, adamw_init, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import opt_specs


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

LOSS_CHUNK = 512  # seq positions per CE chunk (bounds the fp32 logits buffer)


def lm_loss(mdl: ModelDef, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Chunked + rematerialized cross-entropy: the (tokens × vocab) fp32
    logits tensor never exists whole — each seq chunk's unembed+CE is
    recomputed in the backward pass (cheap vs. the multi-GiB buffer)."""
    cfg = mdl.cfg
    hidden, aux = mdl.forward(params, batch, return_hidden=True)
    if cfg.family == "vlm" and cfg.num_image_tokens:
        hidden = hidden[:, cfg.num_image_tokens:]
    targets = batch["targets"]
    b, s, _ = hidden.shape
    vp = cfg.padded_vocab()
    pad_mask = (jnp.arange(vp) < cfg.vocab_size)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_nll(h_c, t_c):
        logits = layers.unembed(params["tok"], h_c).astype(jnp.float32)
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, t_c[..., None], axis=-1)[..., 0]

    chunk = min(LOSS_CHUNK, s)
    if s % chunk:
        chunk = s
    n_chunks = s // chunk
    if n_chunks > 1:
        h_c = hidden.reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
        t_c = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)
        if cfg.unroll_inner_scans:
            nll = jnp.stack([chunk_nll(h_c[i], t_c[i]) for i in range(n_chunks)])
        else:
            _, nll = jax.lax.scan(lambda c, ht: (c, chunk_nll(*ht)), 0, (h_c, t_c))
        nll_mean = jnp.mean(nll)
    else:
        nll_mean = jnp.mean(chunk_nll(hidden, targets))
    loss = nll_mean + 0.01 * aux
    return loss, {"nll": nll_mean, "aux": aux}


def make_train_step(mdl: ModelDef, *, lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, clip: float = 1.0,
                    weight_decay: float = 0.1):
    k = mdl.cfg.microbatches

    def train_step(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]

        def lf(p, mb):
            return lm_loss(mdl, p, mb)

        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
        else:
            # gradient accumulation over k microbatches (scan keeps HLO small
            # and bounds the live activation set to one microbatch)
            mb_batch = jax.tree_util.tree_map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def micro(acc, mb):
                (l, m), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc, g)
                return acc, (l, m)

            grads, (losses, metrics_k) = jax.lax.scan(micro, g0, mb_batch)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, metrics_k)

        grads, gnorm = clip_by_global_norm(grads, clip)
        lr_t = warmup_cosine(step, peak_lr=lr, warmup_steps=warmup,
                             total_steps=total_steps)
        params, opt = adamw_update(params, grads, opt, lr_t,
                                   weight_decay=weight_decay)
        new_state = {"params": params, "opt": opt, "step": step + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr_t, **metrics}
        return new_state, out_metrics

    return train_step


def make_prefill_step(mdl: ModelDef):
    """Forward over the prompt; returns last-position logits (cache write is
    exercised in the decode step, which takes the cache as input)."""
    def prefill_step(params, batch):
        logits, _ = mdl.forward(params, batch)
        return logits[:, -1]
    return prefill_step


def make_decode_step(mdl: ModelDef):
    def decode_step(params, cache, token, index):
        logits, cache = mdl.decode(params, cache, token, index)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return next_token, cache
    return decode_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, axes, mesh: Optional[Mesh], rules: str = "tp"):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = resolve_axes(tuple(axes), tuple(shape), mesh, RULE_SETS[rules])
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Optional[Mesh] = None):
    """Abstract batch for the given shape cell."""
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    out: Dict[str, Any] = {}
    if kind in ("train", "prefill"):
        s_text = s - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
        out["tokens"] = _sds((b, s_text), jnp.int32, ("batch", None), mesh)
        if cfg.family == "vlm":
            out["img_embeds"] = _sds((b, cfg.num_image_tokens, cfg.d_model),
                                     jnp.bfloat16, ("batch", None, None), mesh)
        if cfg.family == "audio":
            out["frames"] = _sds((b, cfg.encoder_seq_len, cfg.d_model),
                                 jnp.bfloat16, ("batch", None, None), mesh)
        if kind == "train":
            out["targets"] = _sds((b, s_text if cfg.family != "vlm" else s_text),
                                  jnp.int32, ("batch", None), mesh)
    return out


def abstract_tree(spec_tree, mesh: Optional[Mesh], rules: str = "tp"):
    def conv(s: ParamSpec):
        return _sds(s.shape, s.dtype, s.axes, mesh, rules)
    return tree_map_specs(conv, spec_tree)


def train_state_specs(mdl: ModelDef, mesh: Optional[Mesh] = None):
    params = abstract_tree(mdl.param_tree, mesh)
    opt = abstract_tree(opt_specs(mdl.param_tree), mesh)
    step = _sds((), jnp.int32, (), mesh)
    return {"params": params, "opt": opt, "step": step}


def decode_input_specs(mdl: ModelDef, shape: ShapeConfig, mesh: Optional[Mesh] = None):
    cfg = mdl.cfg
    b = shape.global_batch
    long_ctx = shape.seq_len >= (1 << 18)
    cache = abstract_tree(mdl.cache_specs(b, shape.seq_len, long_ctx=long_ctx), mesh)
    token = _sds((b, 1), jnp.int32, ("batch", None), mesh)
    index = _sds((), jnp.int32, (), mesh)
    return cache, token, index


# ---------------------------------------------------------------------------
# Concrete init (smoke tests / real runs)
# ---------------------------------------------------------------------------

def init_train_state(mdl: ModelDef, seed: int = 0):
    params = init_params(mdl.param_tree, seed)
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def init_cache(mdl: ModelDef, batch: int, cache_len: int, long_ctx: bool = False):
    return init_params(mdl.cache_specs(batch, cache_len, long_ctx=long_ctx), 0)
