"""Shared transformer layers: norms, RoPE, attention (GQA/MHA/cross), MLP.

Sharding strategy (see DESIGN.md §5):
  * activations: batch over ("pod","data"); attention heads over "model"
    (q heads zero-padded in-step to `cfg.padded_heads` when the real head
    count does not divide the model axis — math-exact: padded head outputs
    are contracted against zero-padded `wo` rows);
  * kv projections: replicated head count (GQA kv rarely divides tp), the
    per-head kv tensors are small and broadcast;
  * mlp hidden over "model"; weights FSDP over "data" ("embed" rule).

Causal attention over long sequences uses a *python-unrolled chunked* form:
query chunk i attends to keys[: (i+1)*chunk] — static shapes per chunk, and
HLO FLOPs stay ~N²/2 (near causal-optimal) instead of the N² a fully masked
rectangle would burn. This matters for the roofline compute term.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, logical_sharding

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-5):
    """No x-shaped f32 materialization: the sum-of-squares comes from a
    bf16×bf16 dot with f32 accumulation, and the (b, s, 1) rescale factor is
    applied in the input dtype. Outputs are bf16 regardless, so this loses
    no output precision — and it prevents XLA from hoisting an f32 convert
    of the entire scan residual stack (2x memory) in the backward pass."""
    if x.dtype == jnp.float32:
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    d = x.shape[-1]
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    rs = jax.lax.rsqrt(ss / d + eps)[..., None]
    return x * rs.astype(x.dtype) * scale.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    d = x.shape[-1]
    ones = jnp.ones((d,), x.dtype)
    mu = (jnp.einsum("...d,d->...", x, ones,
                     preferred_element_type=jnp.float32) / d)[..., None]
    ss = (jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / d)[..., None]
    var = jnp.maximum(ss - jnp.square(mu), 0.0)
    rs = jax.lax.rsqrt(var + eps)
    y = (x - mu.astype(x.dtype)) * rs.astype(x.dtype)
    return y * scale.astype(x.dtype) + bias.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    exps = jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2)
    return theta ** -exps  # (hd/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_params(cfg: ModelConfig, cross: bool = False) -> Params:
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    # MHA-padded: kv projections partition by (padded) output heads like q —
    # head_dim stays whole, so no kv all-gather is ever needed (§Perf H3).
    kv_axes = (("embed", "kv_heads", "head_dim") if cfg.mha_padded
               else ("embed", "kv_heads", "kv_head_dim"))
    p: Params = {
        "wq": ParamSpec((d, nq, hd), cfg.param_dtype, ("embed", "heads", "head_dim"), "fan_in"),
        "wk": ParamSpec((d, nkv, hd), cfg.param_dtype, kv_axes, "fan_in"),
        "wv": ParamSpec((d, nkv, hd), cfg.param_dtype, kv_axes, "fan_in"),
        "wo": ParamSpec((nq, hd, d), cfg.param_dtype, ("heads", "head_dim", "embed"), "fan_in"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = ParamSpec((nq, hd), cfg.param_dtype, ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((nkv, hd), cfg.param_dtype, ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((nkv, hd), cfg.param_dtype, ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm and not cross:
        p["q_norm"] = ParamSpec((hd,), cfg.param_dtype, (None,), "ones")
        p["k_norm"] = ParamSpec((hd,), cfg.param_dtype, (None,), "ones")
    return p


def _kv_repeat_idx(cfg: ModelConfig) -> np.ndarray:
    """Index of the kv head used by each (padded) q head."""
    nq, nkv, npad = cfg.num_heads, cfg.num_kv_heads, cfg.padded_heads
    if cfg.mha_padded:
        return np.arange(npad, dtype=np.int32)  # kv padded alongside q
    qpk = nq // nkv
    idx = [min(j // qpk, nkv - 1) if j < nq else 0 for j in range(npad)]
    return np.asarray(idx, dtype=np.int32)


def _pad_heads_act(x, npad: int):
    """Zero-pad the head axis (axis=-2) of an activation to `npad`."""
    n = x.shape[-2]
    if n == npad:
        return x
    pad = [(0, 0)] * x.ndim
    pad[-2] = (0, npad - n)
    return jnp.pad(x, pad)


def _pad_wo(wo, npad: int):
    n = wo.shape[0]
    if n == npad:
        return wo
    return jnp.pad(wo, ((0, npad - n), (0, 0), (0, 0)))


def project_qkv(p: Params, cfg: ModelConfig, x, positions, *, rope: bool = True):
    """Returns q (padded heads, sharded), k, v (true kv heads, replicated)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = _pad_heads_act(q, cfg.padded_heads)
    q = logical_sharding(q, ("batch", None, "act_heads", None), None)
    if cfg.mha_padded:
        k = _pad_heads_act(k, cfg.padded_heads)
        v = _pad_heads_act(v, cfg.padded_heads)
        k = logical_sharding(k, ("batch", None, "act_heads", None), None)
        v = logical_sharding(v, ("batch", None, "act_heads", None), None)
    else:
        k = logical_sharding(k, ("batch", None, "act_kv", None), None)
        v = logical_sharding(v, ("batch", None, "act_kv", None), None)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (b, sq, h, hd); k/v: (b, sk, h, hd); mask broadcast (b, 1, sq, sk)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def causal_attention(p: Params, cfg: ModelConfig, x, positions,
                     chunk: int = 1024, return_kv: bool = False):
    """Full causal self-attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = project_qkv(p, cfg, x, positions)
    if cfg.mha_padded:
        k_rep, v_rep = k, v  # already padded + head-sharded; no repeat needed
    else:
        idx = _kv_repeat_idx(cfg)
        k_rep = jnp.take(k, idx, axis=2)
        v_rep = jnp.take(v, idx, axis=2)
        k_rep = logical_sharding(k_rep, ("batch", None, "act_heads", None), None)
        v_rep = logical_sharding(v_rep, ("batch", None, "act_heads", None), None)
    scale = cfg.head_dim ** -0.5

    if s <= chunk:
        mask = jnp.tril(jnp.ones((s, s), bool))[None, None]
        out = _sdpa(q, k_rep, v_rep, mask, scale)
    else:
        assert s % chunk == 0, (s, chunk)
        outs = []
        for i in range(s // chunk):
            qi = q[:, i * chunk:(i + 1) * chunk]
            kl = k_rep[:, : (i + 1) * chunk]
            vl = v_rep[:, : (i + 1) * chunk]
            qpos = jnp.arange(i * chunk, (i + 1) * chunk)
            kpos = jnp.arange((i + 1) * chunk)
            mask = (kpos[None, :] <= qpos[:, None])[None, None]
            outs.append(_sdpa(qi, kl, vl, mask, scale))
        out = jnp.concatenate(outs, axis=1)

    wo = _pad_wo(p["wo"], cfg.padded_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    y = logical_sharding(y, ("batch", None, None), None)
    if return_kv:
        return y, (k, v)
    return y


def cache_axes(cfg: ModelConfig, long_ctx: bool = False):
    """Logical axes of the KV cache (b, S, heads, hd). MHA-padded archs
    shard heads on `model` (no seq sharding needed); GQA archs shard the
    seq dim flash-decoding style."""
    if cfg.mha_padded:
        return ("batch", None, "act_heads", None)
    return ("batch", "long_kv_seq" if long_ctx else "kv_seq", "act_kv", None)


def decode_attention(p: Params, cfg: ModelConfig, x, cache_k, cache_v,
                     cache_index, *, long_ctx: bool = False):
    """Single-token decode. cache_{k,v}: (b, S, n, hd) per `cache_axes`.

    Writes the new k/v at `cache_index`, computes flash-decoding-style
    attention (partial softmax over any sharded seq dim is handled by GSPMD
    max/sum all-reduces).
    """
    b, one, _ = x.shape
    S = cache_k.shape[1]
    positions = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q, k, v = project_qkv(p, cfg, x, positions)
    # (sharding propagates from the cache operands through the update —
    # the cache layout is pinned by cache_specs / the caller's in_shardings)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, cache_index, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, cache_index, 0, 0))

    scale = cfg.head_dim ** -0.5
    if cfg.mha_padded:
        kg, vg = cache_k, cache_v  # cache already in padded-head layout
    else:
        # (b, 1, P, hd) x (b, S, nkv, hd): repeat kv along the head dim
        idx = _kv_repeat_idx(cfg)
        kg = jnp.take(cache_k, idx, axis=2)  # gather along replicated kv heads
        vg = jnp.take(cache_v, idx, axis=2)
    kg = kg.astype(q.dtype)  # dequant (f8 KV cache) / no-op otherwise
    vg = vg.astype(q.dtype)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kg).astype(jnp.float32) * scale
    valid = (jnp.arange(S) <= cache_index)[None, None, None, :]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vg.dtype), vg)
    wo = _pad_wo(p["wo"], cfg.padded_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, cache_k, cache_v


def cross_attention(p: Params, cfg: ModelConfig, x, enc_kv):
    """Decoder cross-attention (whisper). enc_kv = (k, v) precomputed."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = _pad_heads_act(q, cfg.padded_heads)
    q = logical_sharding(q, ("batch", None, "act_heads", None), None)
    k, v = enc_kv
    if cfg.mha_padded:
        kg, vg = k, v
    else:
        idx = _kv_repeat_idx(cfg)
        kg = jnp.take(k, idx, axis=2)
        vg = jnp.take(v, idx, axis=2)
    out = _sdpa(q, kg, vg, None, cfg.head_dim ** -0.5)
    wo = _pad_wo(p["wo"], cfg.padded_heads)
    return jnp.einsum("bshk,hkd->bsd", out, wo)


def encode_kv(p: Params, cfg: ModelConfig, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.mha_padded:
        k = _pad_heads_act(k, cfg.padded_heads)
        v = _pad_heads_act(v, cfg.padded_heads)
        k = logical_sharding(k, ("batch", None, "act_heads", None), None)
        v = logical_sharding(v, ("batch", None, "act_heads", None), None)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, gated: bool = True, d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    p: Params = {
        "w_in": ParamSpec((d, ff), cfg.param_dtype, ("embed", "mlp"), "fan_in"),
        "w_out": ParamSpec((ff, d), cfg.param_dtype, ("mlp", "embed"), "fan_in"),
    }
    if gated:
        p["w_gate"] = ParamSpec((d, ff), cfg.param_dtype, ("embed", "mlp"), "fan_in")
    return p


def mlp(p: Params, x, act=jax.nn.silu):
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = logical_sharding(h, ("batch", None, "mlp"), None)
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"])
    return logical_sharding(y, ("batch", None, None), None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_params(cfg: ModelConfig) -> Params:
    vp, d = cfg.padded_vocab(), cfg.d_model
    return {
        "embedding": ParamSpec((vp, d), cfg.param_dtype, ("vocab", "embed"), "normal"),
        "lm_head": ParamSpec((vp, d), cfg.param_dtype, ("vocab", "embed"), "fan_in"),
    }


def embed(p: Params, tokens):
    x = jnp.take(p["embedding"], tokens, axis=0)
    return logical_sharding(x, ("batch", None, None), None)


def unembed(p: Params, x):
    logits = jnp.einsum("bsd,vd->bsv", x, p["lm_head"])
    return logical_sharding(logits, ("batch", None, "vocab"), None)
