"""Model assembly for all assigned architectures.

One `ModelDef` per architecture family:
  dense | moe | vlm  -> decoder-only stack (scan over identical layers)
  hybrid (jamba)     -> scan over blocks of `attn_every` heterogeneous layers
  ssm (rwkv6)        -> scan over rwkv blocks
  audio (whisper)    -> encoder (bidirectional) + decoder (causal + cross)

Layers are stacked along a leading "layers" axis and traversed with
`jax.lax.scan` — this keeps HLO size (and compile time for the 512-device
dry-run) independent of depth. Remat is applied per layer body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mamba, moe, rwkv6
from repro.models.params import ParamSpec, logical_sharding, tree_map_specs

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Norm helpers (rms for LM-family, ln for whisper/rwkv)
# ---------------------------------------------------------------------------

def norm_params(cfg: ModelConfig, norm_type: Optional[str] = None) -> Params:
    nt = norm_type or ("ln" if cfg.family in ("audio", "ssm") else "rms")
    p = {"scale": ParamSpec((cfg.d_model,), cfg.param_dtype, (None,), "ones")}
    if nt == "ln":
        p["bias"] = ParamSpec((cfg.d_model,), cfg.param_dtype, (None,), "zeros")
    return p


def apply_norm(cfg: ModelConfig, p: Params, x):
    if "bias" in p:
        return layers.layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return layers.rms_norm(x, p["scale"], cfg.norm_eps)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _stack(layer_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked leading dim to every ParamSpec in a layer tree."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, s.dtype, (axis_name,) + s.axes, s.init, s.scale),
        layer_tree,
    )


# ---------------------------------------------------------------------------
# Per-layer param trees / apply
# ---------------------------------------------------------------------------

def _layer_params(cfg: ModelConfig, kind: str, ffn: str) -> Params:
    p: Params = {"ln1": norm_params(cfg), "ln2": norm_params(cfg)}
    if kind == "attn":
        p["attn"] = layers.attention_params(cfg)
    elif kind == "mamba":
        p["mixer"] = mamba.mamba_params(cfg)
    elif kind == "rwkv":
        p["tm"] = rwkv6.time_mix_params(cfg)
    if ffn == "dense":
        p["mlp"] = layers.mlp_params(cfg, gated=cfg.family != "audio")
    elif ffn == "moe":
        p["moe"] = moe.moe_params(cfg)
    elif ffn == "rwkv_cm":
        p["cm"] = rwkv6.channel_mix_params(cfg)
    return p


_SP = ("batch", "seq_sp", None)     # residual stream: seq-sharded over model
_FULL = ("batch", None, None)       # gathered for mixer/FFN compute


def _layer_apply(cfg: ModelConfig, p: Params, x, positions, kind: str, ffn: str):
    """x arrives (and leaves) seq-sharded (`_SP`); norms run sharded, the
    mixer/FFN input is all-gathered and its output reduce-scattered back —
    Megatron sequence parallelism, which also keeps the scan's saved
    residual stack 1/TP-sized (the dominant train memory term)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["ln1"], x)
    h = logical_sharding(h, _FULL)
    if kind == "attn":
        y = layers.causal_attention(p["attn"], cfg, h, positions)
    elif kind == "mamba":
        y = mamba.mamba(p["mixer"], cfg, h)
    elif kind == "rwkv":
        y = rwkv6.time_mix(p["tm"], cfg, h)
    x = x + logical_sharding(y, _SP)
    h = apply_norm(cfg, p["ln2"], x)
    h = logical_sharding(h, _FULL)
    if ffn == "dense":
        act = jax.nn.gelu if cfg.family == "audio" else jax.nn.silu
        y = layers.mlp(p["mlp"], h, act=act)
    elif ffn == "moe":
        y, aux = moe.moe(p["moe"], cfg, h)
    elif ffn == "rwkv_cm":
        y = rwkv6.channel_mix(p["cm"], h)
    x = x + logical_sharding(y, _SP)
    return x, aux


def _layer_plan(cfg: ModelConfig):
    """List of (kind, ffn) per scan position; scan length."""
    if cfg.family == "hybrid":
        period = cfg.attn_every
        assert cfg.num_layers % period == 0
        plan = []
        for pos in range(period):
            kind = "attn" if pos % cfg.attn_every == cfg.attn_offset else "mamba"
            ffn = "moe" if cfg.is_moe_layer(pos) else "dense"
            plan.append((kind, ffn))
        return plan, cfg.num_layers // period
    if cfg.family == "ssm":
        return [("rwkv", "rwkv_cm")], cfg.num_layers
    ffn = "moe" if (cfg.num_experts and cfg.moe_every == 1) else "dense"
    return [("attn", ffn)], cfg.num_layers


# ---------------------------------------------------------------------------
# ModelDef
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelDef:
    cfg: ModelConfig
    param_tree: Any

    # ---------------- forward (train / prefill) ----------------

    def forward(self, params: Params, batch: Dict[str, Any],
                return_hidden: bool = False):
        """Returns (logits | final hidden, aux_loss). Handles all families."""
        cfg = self.cfg
        if cfg.family == "audio":
            return self._forward_encdec(params, batch, return_hidden)
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x = logical_sharding(x, _SP)
        x, aux = self._run_stack(params, x, positions)
        x = apply_norm(cfg, params["final_norm"], x)
        x = logical_sharding(x, _FULL)
        if return_hidden:
            return x, aux
        logits = layers.unembed(params["tok"], x)
        return logits, aux

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = layers.embed(params["tok"], batch["tokens"])
        if cfg.family == "vlm" and "img_embeds" in batch:
            img = batch["img_embeds"].astype(x.dtype)
            img = logical_sharding(img, ("batch", None, None), None)
            x = jnp.concatenate([img, x], axis=1)
        if cfg.family == "ssm":
            x = apply_norm(cfg, params["ln0"], x)
        return x

    def _run_stack(self, params, x, positions):
        cfg = self.cfg
        plan, n_scan = _layer_plan(cfg)

        # remat at SUB-layer granularity for multi-sublayer blocks (hybrid):
        # the backward then recomputes one sublayer at a time instead of
        # keeping all 8 sublayers' internals live (§Perf H2).
        def sub(i, kind, ffn):
            def f(x, p_layer):
                return _layer_apply(cfg, p_layer, x, positions, kind, ffn)
            return _remat(cfg, f)

        subs = [sub(i, kind, ffn) for i, (kind, ffn) in enumerate(plan)]

        def block(x, block_params):
            aux = jnp.zeros((), jnp.float32)
            for i in range(len(plan)):
                x, a = subs[i](x, block_params[f"pos{i}"])
                aux = aux + a
            return x, aux

        if cfg.scan_layers:
            def scan_body(carry, block_params):
                x, aux = carry
                x, a = block(x, block_params)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(
                scan_body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for l in range(n_scan):
                bp = jax.tree_util.tree_map(lambda a: a[l], params["blocks"])
                x, a = block(x, bp)
                aux = aux + a
        return x, aux

    def _forward_encdec(self, params, batch, return_hidden: bool = False):
        cfg = self.cfg
        enc = batch["frames"].astype(cfg.dtype)  # stub frontend: precomputed embeddings
        enc = logical_sharding(enc, ("batch", None, None), None)
        enc_pos = jnp.arange(enc.shape[1])[None, :]
        enc = enc + _sinusoidal(enc.shape[1], cfg.d_model, cfg.dtype)[None]

        def enc_block(x, p):
            h = logical_sharding(apply_norm(cfg, p["ln1"], x), _FULL)
            x = x + logical_sharding(_bidir_attention(p["attn"], cfg, h), _SP)
            h = logical_sharding(apply_norm(cfg, p["ln2"], x), _FULL)
            x = x + logical_sharding(layers.mlp(p["mlp"], h, act=jax.nn.gelu), _SP)
            return x, None

        enc = logical_sharding(enc, _SP)
        enc, _ = jax.lax.scan(lambda c, p: enc_block(c, p), enc, params["enc_blocks"])
        enc = apply_norm(cfg, params["enc_norm"], enc)
        enc = logical_sharding(enc, _FULL)

        x = layers.embed(params["tok"], batch["tokens"])
        x = x + _sinusoidal(x.shape[1], cfg.d_model, cfg.dtype)[None]
        x = logical_sharding(x, _SP)
        positions = jnp.arange(x.shape[1])[None, :]

        def dec_block(x, p):
            h = logical_sharding(apply_norm(cfg, p["ln1"], x), _FULL)
            x = x + logical_sharding(
                layers.causal_attention(p["attn"], cfg, h, positions), _SP)
            h = logical_sharding(apply_norm(cfg, p["ln_x"], x), _FULL)
            enc_kv = layers.encode_kv(p["xattn"], cfg, enc)
            x = x + logical_sharding(
                layers.cross_attention(p["xattn"], cfg, h, enc_kv), _SP)
            h = logical_sharding(apply_norm(cfg, p["ln2"], x), _FULL)
            x = x + logical_sharding(layers.mlp(p["mlp"], h, act=jax.nn.gelu), _SP)
            return x, None

        dec_block = _remat(cfg, dec_block)
        x, _ = jax.lax.scan(lambda c, p: dec_block(c, p), x, params["dec_blocks"])
        x = apply_norm(cfg, params["final_norm"], x)
        x = logical_sharding(x, _FULL)
        if return_hidden:
            return x, jnp.zeros((), jnp.float32)
        logits = layers.unembed(params["tok"], x)
        return logits, jnp.zeros((), jnp.float32)

    # ---------------- decode ----------------

    def cache_specs(self, batch: int, cache_len: int, long_ctx: bool = False):
        """ParamSpec tree for the decode cache."""
        cfg = self.cfg
        axes = layers.cache_axes(cfg, long_ctx)
        nkv = cfg.padded_heads if cfg.mha_padded else cfg.num_kv_heads
        hd = cfg.head_dim

        def kv_spec():
            return {
                "k": ParamSpec((batch, cache_len, nkv, hd), cfg.cache_dtype, axes, "zeros"),
                "v": ParamSpec((batch, cache_len, nkv, hd), cfg.cache_dtype, axes, "zeros"),
            }

        if cfg.family == "audio":
            enc_len = cfg.encoder_seq_len
            cross_axes = ("batch", None, axes[2], None)
            cross = {
                "k": ParamSpec((batch, enc_len, nkv, hd), cfg.dtype, cross_axes, "zeros"),
                "v": ParamSpec((batch, enc_len, nkv, hd), cfg.dtype, cross_axes, "zeros"),
            }
            layer = {"self": kv_spec(), "cross": cross}
            return {"dec": _stack(layer, cfg.num_layers)}

        plan, n_scan = _layer_plan(cfg)
        block = {}
        for i, (kind, _ffn) in enumerate(plan):
            if kind == "attn":
                block[f"pos{i}"] = kv_spec()
            elif kind == "mamba":
                block[f"pos{i}"] = mamba.mamba_state_specs(cfg, batch)
            elif kind == "rwkv":
                block[f"pos{i}"] = rwkv6.rwkv_state_specs(cfg, batch)
        return {"blocks": _stack(block, n_scan)}

    def decode(self, params: Params, cache, token, index):
        """One decode step. token: (b, 1) int32; index: scalar int32 position.

        Returns (logits, new_cache)."""
        cfg = self.cfg
        x = layers.embed(params["tok"], token)
        if cfg.family == "ssm":
            x = apply_norm(cfg, params["ln0"], x)
        if cfg.family == "audio":
            return self._decode_encdec(params, cache, x, index)

        plan, n_scan = _layer_plan(cfg)

        # fori_loop with the FULL cache as carry: per-layer slices are
        # updated in place (donated buffer), avoiding the 2x cache
        # double-buffering a scan-with-stacked-ys would cost (§Perf H3).
        def body(l, carry):
            x, full_cache = carry
            take = lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
            bp = jax.tree_util.tree_map(take, params["blocks"])
            bc = jax.tree_util.tree_map(take, full_cache)
            x, new_bc = _decode_block_apply(cfg, plan, index, x, bp, bc)
            put = lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), l, 0)
            full_cache = jax.tree_util.tree_map(put, full_cache, new_bc)
            return x, full_cache

        x, new_cache = jax.lax.fori_loop(0, n_scan, body,
                                         (x, cache["blocks"]))
        x = apply_norm(cfg, params["final_norm"], x)
        logits = layers.unembed(params["tok"], x)
        return logits, {"blocks": new_cache}

    def _decode_encdec(self, params, cache, x, index):
        cfg = self.cfg
        pos_emb = _sinusoidal_at(index, cfg.d_model, cfg.dtype)
        x = x + pos_emb

        def body(l, carry):
            x, full_cache = carry
            take = lambda a: jax.lax.dynamic_index_in_dim(a, l, 0, keepdims=False)
            p = jax.tree_util.tree_map(take, params["dec_blocks"])
            c = jax.tree_util.tree_map(take, full_cache)
            h = apply_norm(cfg, p["ln1"], x)
            y, ck, cv = layers.decode_attention(p["attn"], cfg, h, c["self"]["k"],
                                                c["self"]["v"], index)
            x = x + y
            h = apply_norm(cfg, p["ln_x"], x)
            x = x + layers.cross_attention(p["xattn"], cfg, h,
                                           (c["cross"]["k"], c["cross"]["v"]))
            h = apply_norm(cfg, p["ln2"], x)
            x = x + layers.mlp(p["mlp"], h, act=jax.nn.gelu)
            new_c = {"self": {"k": ck, "v": cv}, "cross": c["cross"]}
            put = lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), l, 0)
            return x, jax.tree_util.tree_map(put, full_cache, new_c)

        x, new_dec = jax.lax.fori_loop(0, cfg.num_layers, body,
                                       (x, cache["dec"]))
        x = apply_norm(cfg, params["final_norm"], x)
        logits = layers.unembed(params["tok"], x)
        return logits, {"dec": new_dec}


def _decode_block_apply(cfg: ModelConfig, plan, index, x, block_params,
                        block_cache):
    """One decode scan-block: returns (x, new_cache)."""
    new_cache = {}
    for i, (kind, ffn) in enumerate(plan):
        p = block_params[f"pos{i}"]
        h = apply_norm(cfg, p["ln1"], x)
        if kind == "attn":
            c = block_cache[f"pos{i}"]
            y, ck, cv = layers.decode_attention(
                p["attn"], cfg, h, c["k"], c["v"], index)
            x = x + y
            new_cache[f"pos{i}"] = {"k": ck, "v": cv}
        elif kind == "mamba":
            y, st = mamba.mamba_decode(p["mixer"], cfg, h, block_cache[f"pos{i}"])
            x = x + y
            new_cache[f"pos{i}"] = st
        elif kind == "rwkv":
            st = dict(block_cache[f"pos{i}"])
            cm_last = st.pop("cm_last")
            y, st2 = rwkv6.time_mix_decode(p["tm"], cfg, h, st)
            x = x + y
            new_cache[f"pos{i}"] = st2
        h = apply_norm(cfg, p["ln2"], x)
        if ffn == "dense":
            act = jax.nn.gelu if cfg.family == "audio" else jax.nn.silu
            x = x + layers.mlp(p["mlp"], h, act=act)
        elif ffn == "moe":
            y, _ = moe.moe(p["moe"], cfg, h)
            x = x + y
        elif ffn == "rwkv_cm":
            x = x + rwkv6.channel_mix(p["cm"], h, last=cm_last)
            new_cache[f"pos{i}"]["cm_last"] = h
    return x, new_cache


def _scan_unit_list(mdl: "ModelDef"):
    """Scan units for flop-correction analysis: list of dicts with
    name, n_trips, param_tree (one block, unstacked), apply(bp, x, ctx)."""
    cfg = mdl.cfg
    if cfg.family == "audio":
        enc_layer = {
            "ln1": norm_params(cfg), "ln2": norm_params(cfg),
            "attn": layers.attention_params(cfg),
            "mlp": layers.mlp_params(cfg, gated=False),
        }
        dec_layer = {
            "ln1": norm_params(cfg), "ln_x": norm_params(cfg), "ln2": norm_params(cfg),
            "attn": layers.attention_params(cfg),
            "xattn": layers.attention_params(cfg, cross=True),
            "mlp": layers.mlp_params(cfg, gated=False),
        }

        def enc_apply(bp, x, ctx):
            h = logical_sharding(apply_norm(cfg, bp["ln1"], x), _FULL)
            x = x + logical_sharding(_bidir_attention(bp["attn"], cfg, h), _SP)
            h = logical_sharding(apply_norm(cfg, bp["ln2"], x), _FULL)
            return x + logical_sharding(layers.mlp(bp["mlp"], h, act=jax.nn.gelu), _SP)

        def dec_apply(bp, x, ctx):
            positions = jnp.arange(x.shape[1])[None, :]
            h = logical_sharding(apply_norm(cfg, bp["ln1"], x), _FULL)
            x = x + logical_sharding(
                layers.causal_attention(bp["attn"], cfg, h, positions), _SP)
            h = logical_sharding(apply_norm(cfg, bp["ln_x"], x), _FULL)
            enc_kv = layers.encode_kv(bp["xattn"], cfg, ctx["enc"])
            x = x + logical_sharding(
                layers.cross_attention(bp["xattn"], cfg, h, enc_kv), _SP)
            h = logical_sharding(apply_norm(cfg, bp["ln2"], x), _FULL)
            return x + logical_sharding(layers.mlp(bp["mlp"], h, act=jax.nn.gelu), _SP)

        return [
            {"name": "enc_blocks", "n": cfg.num_encoder_layers,
             "params": enc_layer, "apply": enc_apply, "needs_enc": False},
            {"name": "dec_blocks", "n": cfg.num_layers,
             "params": dec_layer, "apply": dec_apply, "needs_enc": True},
        ]

    plan, n_scan = _layer_plan(cfg)
    block = {f"pos{i}": _layer_params(cfg, kind, ffn)
             for i, (kind, ffn) in enumerate(plan)}

    def apply(bp, x, ctx):
        positions = jnp.arange(x.shape[1])[None, :]
        # mirror _run_stack's per-sublayer remat so block-level analysis
        # lowers count the same recompute flops as the deployed model
        for i, (kind, ffn) in enumerate(plan):
            def f(x_, p_layer, kind=kind, ffn=ffn):
                return _layer_apply(cfg, p_layer, x_, positions, kind, ffn)
            x, _ = _remat(cfg, f)(x, bp[f"pos{i}"])
        return x

    return [{"name": "blocks", "n": n_scan, "params": block, "apply": apply,
             "needs_enc": False}]


def _sinusoidal(length: int, d: int, dtype):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    pe = jnp.zeros((length, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def _sinusoidal_at(index, d: int, dtype):
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = index.astype(jnp.float32) / (10000.0 ** (dim / d))
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(ang))
    pe = pe.at[1::2].set(jnp.cos(ang))
    return pe.astype(dtype)


def _bidir_attention(p, cfg, x):
    """Non-causal self-attention (whisper encoder)."""
    positions = jnp.arange(x.shape[1])[None, :]
    q, k, v = layers.project_qkv(p, cfg, x, positions, rope=False)
    if cfg.mha_padded:
        kg, vg = k, v
    else:
        idx = layers._kv_repeat_idx(cfg)
        kg = jnp.take(k, idx, axis=2)
        vg = jnp.take(v, idx, axis=2)
    out = layers._sdpa(q, kg, vg, None, cfg.head_dim ** -0.5)
    wo = layers._pad_wo(p["wo"], cfg.padded_heads)
    return jnp.einsum("bshk,hkd->bsd", out, wo)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

def build(cfg: ModelConfig) -> ModelDef:
    if cfg.family == "audio":
        enc_layer = {
            "ln1": norm_params(cfg), "ln2": norm_params(cfg),
            "attn": layers.attention_params(cfg),
            "mlp": layers.mlp_params(cfg, gated=False),
        }
        dec_layer = {
            "ln1": norm_params(cfg), "ln_x": norm_params(cfg), "ln2": norm_params(cfg),
            "attn": layers.attention_params(cfg),
            "xattn": layers.attention_params(cfg, cross=True),
            "mlp": layers.mlp_params(cfg, gated=False),
        }
        tree = {
            "tok": layers.embed_params(cfg),
            "enc_blocks": _stack(enc_layer, cfg.num_encoder_layers),
            "enc_norm": norm_params(cfg),
            "dec_blocks": _stack(dec_layer, cfg.num_layers),
            "final_norm": norm_params(cfg),
        }
        return ModelDef(cfg, tree)

    plan, n_scan = _layer_plan(cfg)
    block = {f"pos{i}": _layer_params(cfg, kind, ffn) for i, (kind, ffn) in enumerate(plan)}
    tree = {
        "tok": layers.embed_params(cfg),
        "blocks": _stack(block, n_scan),
        "final_norm": norm_params(cfg),
    }
    if cfg.family == "ssm":
        tree["ln0"] = norm_params(cfg)
    return ModelDef(cfg, tree)
