from repro.models.transformer import ModelDef, build
