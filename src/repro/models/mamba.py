"""Mamba (selective SSM) block — used by the jamba hybrid architecture.

Training uses a chunked selective scan: an outer `lax.scan` over sequence
chunks carrying the SSM state, with a `lax.associative_scan` inside each
chunk. This bounds the materialized (b, chunk, d_inner, d_state) tensor so
long sequences fit HBM. Decode is the single-step recurrence.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, logical_sharding

Params = Dict[str, Any]


def mamba_params(cfg: ModelConfig) -> Params:
    d, di, ds, k, dtr = (cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state,
                         cfg.mamba_d_conv, cfg.dt_rank)
    return {
        "in_proj": ParamSpec((d, 2 * di), cfg.param_dtype, ("embed", "mamba_inner"), "fan_in"),
        "conv_w": ParamSpec((k, di), cfg.param_dtype, ("conv", "mamba_inner"), "fan_in"),
        "conv_b": ParamSpec((di,), cfg.param_dtype, ("mamba_inner",), "zeros"),
        "x_proj": ParamSpec((di, dtr + 2 * ds), cfg.param_dtype, ("mamba_inner", None), "fan_in"),
        "dt_proj": ParamSpec((dtr, di), cfg.param_dtype, (None, "mamba_inner"), "fan_in"),
        "dt_bias": ParamSpec((di,), "float32", ("mamba_inner",), "zeros"),
        "A_log": ParamSpec((di, ds), "float32", ("mamba_inner", "state"), "ones"),
        "D": ParamSpec((di,), "float32", ("mamba_inner",), "ones"),
        "out_proj": ParamSpec((di, d), cfg.param_dtype, ("mamba_inner", "embed"), "fan_in"),
    }


def _ssm_inputs(p: Params, cfg: ModelConfig, u):
    """u: (b, s, di) post-conv activations. Returns dA, dBx, Cmat."""
    ds, dtr = cfg.mamba_d_state, cfg.dt_rank
    xdbl = jnp.einsum("bsi,ir->bsr", u, p["x_proj"]).astype(jnp.float32)
    dt, B, C = jnp.split(xdbl, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"].astype(jnp.float32))
                         + p["dt_bias"])                       # (b, s, di)
    A = -jnp.exp(p["A_log"])                                    # (di, ds)
    dA = jnp.exp(dt[..., None] * A)                             # (b, s, di, ds)
    dBx = dt[..., None] * B[:, :, None, :] * u.astype(jnp.float32)[..., None]
    return dA, dBx, C


def _conv(p: Params, u, conv_state=None):
    """Causal depthwise conv1d. u: (b, s, di)."""
    k = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(u[:, : k - 1])
    else:
        pad = conv_state
    ext = jnp.concatenate([pad, u], axis=1)                     # (b, s+k-1, di)
    out = sum(ext[:, i: i + u.shape[1]] * p["conv_w"][i] for i in range(k))
    new_state = ext[:, -(k - 1):]
    return jax.nn.silu(out + p["conv_b"]), new_state


def mamba(p: Params, cfg: ModelConfig, x, chunk: int = 256):
    """Training/prefill forward. x: (b, s, d) -> (b, s, d)."""
    b, s, _ = x.shape
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    xz = logical_sharding(xz, ("batch", None, "mamba_inner"), None)
    u, z = jnp.split(xz, 2, axis=-1)
    u, _ = _conv(p, u)

    dA, dBx, C = _ssm_inputs(p, cfg, u)

    if cfg.unroll_inner_scans:
        # analysis mode: chunk size is FLOP-irrelevant (the scan is
        # elementwise, ~0.01% of block matmul flops) — keep the unrolled
        # python loop short so the analysis lower compiles quickly
        chunk = max(chunk, s // 8)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    # checkpoint the chunk body: associative_scan's backward otherwise saves
    # ~log2(chunk) tree levels of (b, c, di, ds) per chunk (§Perf H2)
    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def scan_chunk(h, inputs):
        dA_c, dBx_c, C_c = inputs                               # (b, c, di, ds)
        # associative scan within the chunk: pairs (a, v) compose as
        # (a2*a1, a2*v1 + v2)
        def combine(l, r):
            al, vl = l
            ar, vr = r
            return al * ar, vl * ar + vr
        a_cum, v_cum = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        hs = v_cum + a_cum * h[:, None]                         # (b, c, di, ds)
        hs = logical_sharding(hs, ("batch", None, "mamba_inner", None), None)
        # contract the state dim per chunk: the (b, s, di, ds) state history
        # never materializes (16x memory; §Perf H2)
        y_c = jnp.einsum("bcin,bcn->bci", hs, C_c)
        return hs[:, -1], y_c

    dA_c = dA.reshape(b, n_chunks, chunk, di, ds).swapaxes(0, 1)
    dBx_c = dBx.reshape(b, n_chunks, chunk, di, ds).swapaxes(0, 1)
    C_c = C.reshape(b, n_chunks, chunk, ds).swapaxes(0, 1)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    if cfg.unroll_inner_scans:
        h, outs = h0, []
        for ci in range(n_chunks):
            h, y_i = scan_chunk(h, (dA_c[ci], dBx_c[ci], C_c[ci]))
            outs.append(y_i)
        y = jnp.stack(outs)
    else:
        _, y = jax.lax.scan(scan_chunk, h0, (dA_c, dBx_c, C_c))
    y = y.swapaxes(0, 1).reshape(b, s, di)
    y = logical_sharding(y, ("batch", None, "mamba_inner"), None)
    y = y + p["D"] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return logical_sharding(out, ("batch", None, None), None)


def mamba_decode(p: Params, cfg: ModelConfig, x, state) -> Tuple[jnp.ndarray, Dict]:
    """Single-token step. x: (b, 1, d); state = {"h": (b, di, ds), "conv": (b, k-1, di)}."""
    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv(p, u, state["conv"])
    dA, dBx, C = _ssm_inputs(p, cfg, u)
    h = state["h"] * dA[:, 0] + dBx[:, 0]                       # (b, di, ds)
    y = jnp.einsum("bin,bn->bi", h, C[:, 0])[:, None]
    y = y + p["D"] * u.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h, "conv": conv_state}


def mamba_state_specs(cfg: ModelConfig, batch: int):
    di, ds, k = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "h": ParamSpec((batch, di, ds), "float32", ("batch", "mamba_inner", "state"), "zeros"),
        "conv": ParamSpec((batch, k - 1, di), cfg.param_dtype, ("batch", None, "mamba_inner"), "zeros"),
    }
