"""GShard-style capacity-based Mixture-of-Experts (top-k dispatch einsums).

Experts are sharded over the `model` mesh axis (16 experts <-> 16-way model
axis on the production mesh). The dispatch/combine one-hot einsums are the
*paper-faithful-to-GShard* baseline; their FLOP overhead is visible in the
roofline MODEL_FLOPS/HLO_FLOPs ratio and is one of the hillclimb subjects
(EXPERIMENTS.md §Perf: gather-based dispatch).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, logical_sharding
from repro.models import layers

Params = Dict[str, Any]


def moe_params(cfg: ModelConfig) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p: Params = {
        "router": ParamSpec((d, E), "float32", ("embed", None), "fan_in"),
        "we_in": ParamSpec((E, d, ff), cfg.param_dtype, ("experts", "expert_in", None), "fan_in"),
        "we_gate": ParamSpec((E, d, ff), cfg.param_dtype, ("experts", "expert_in", None), "fan_in"),
        "we_out": ParamSpec((E, ff, d), cfg.param_dtype, ("experts", None, "expert_in"), "fan_in"),
    }
    for i in range(cfg.num_shared_experts):
        p[f"shared_{i}"] = layers.mlp_params(cfg)
    return p


def _capacity(cfg: ModelConfig, s: int) -> int:
    c = int(s * cfg.num_experts_per_tok * cfg.moe_capacity_factor / cfg.num_experts)
    return max(1, -(-c // 4) * 4) if s > 4 else max(1, c)


def moe(p: Params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d). Groups = sequences (b). Returns (y, aux_loss)."""
    b, s, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    C = _capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (b, s, E)

    # Sequential top-k dispatch with per-expert capacity (GShard).
    remaining = probs
    counts = jnp.zeros((b, E), jnp.int32)
    combine = jnp.zeros((b, s, E, C), jnp.float32)
    gates_sum = jnp.zeros((b, s), jnp.float32)
    first_choice_mask = None
    for j in range(k):
        gate = jnp.max(remaining, axis=-1)            # (b, s)
        choice = jnp.argmax(remaining, axis=-1)        # (b, s)
        onehot = jax.nn.one_hot(choice, E, dtype=jnp.float32)  # (b, s, E)
        if j == 0:
            first_choice_mask = onehot
        # position of this token within its chosen expert's buffer
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]  # (b, s, E)
        pos_tok = jnp.sum(pos * onehot, axis=-1)       # (b, s)
        fits = pos_tok < C
        gate = jnp.where(fits, gate, 0.0)
        pos_oh = jax.nn.one_hot(jnp.where(fits, pos_tok, C).astype(jnp.int32), C,
                                dtype=jnp.float32)     # (b, s, C); overflow -> dropped
        combine = combine + gate[..., None, None] * (onehot[..., :, None] * pos_oh[..., None, :])
        gates_sum = gates_sum + gate
        counts = counts + jnp.sum(onehot * fits[..., None], axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)

    # Renormalize combine weights over the selected experts.
    combine = combine / jnp.maximum(gates_sum[..., None, None], 1e-9)
    combine = logical_sharding(combine, ("batch", None, "experts", None), None)
    dispatch = (combine > 0.0).astype(x.dtype)

    xe = jnp.einsum("bsec,bsd->becd", dispatch, x)    # (b, E, C, d)
    xe = logical_sharding(xe, ("batch", "experts", None, None), None)
    h = jnp.einsum("becd,edf->becf", xe, p["we_in"])
    g = jnp.einsum("becd,edf->becf", xe, p["we_gate"])
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("becf,efd->becd", h, p["we_out"])
    ye = logical_sharding(ye, ("batch", "experts", None, None), None)
    y = jnp.einsum("becd,bsec->bsd", ye, combine.astype(x.dtype))
    y = logical_sharding(y, ("batch", None, None), None)

    for i in range(cfg.num_shared_experts):
        y = y + layers.mlp(p[f"shared_{i}"], x)

    # Switch-style load-balancing auxiliary loss.
    me = jnp.mean(first_choice_mask, axis=(0, 1))      # fraction routed per expert
    pe = jnp.mean(probs, axis=(0, 1))                  # mean router prob per expert
    aux = E * jnp.sum(me * pe)
    return y, aux
