"""RWKV-6 "Finch" block (attention-free, data-dependent decay).

Time-mix uses the chunked-parallel WKV form: within a chunk the pairwise
decay matrix `M[t,i] = exp(a[t-1] - a[i])` (a = cumulative log-decay, always
<= 1) is factored into `(r ⊙ exp(a)) · (k ⊙ exp(-a))` with exponents
clipped at ±40. The factorization is exact while the cumulative in-chunk
log-decay stays within the clip (true for trained RWKV decay ranges at
chunk=64: typical per-token log-decay is -0.01..-0.3); channels that decay
faster than e^-40 within one chunk have their ancient-pair contributions
approximated. The sequential form in tests/ref is the exact oracle; decode
is the exact one-step recurrence.

State per head: S ∈ R^{K×V} (K = V = head_size). Update:
    out_t = r_t · (S + (u ⊙ k_t) v_t^T)
    S    <- diag(w_t) S + k_t v_t^T,   w_t = exp(-exp(ww_t))  (per-channel!)

Heads are zero-padded to `cfg.padded_heads`-equivalent via `rwkv_head_pad`
so they shard over the model axis (40 -> 48 on the production mesh); padded
channels carry exact zeros through the recurrence.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamSpec, logical_sharding

Params = Dict[str, Any]

_LORA = 64          # rank of the data-dependent decay LoRA
_CLIP = 40.0        # exponent clip for the factored intra-chunk form


def rwkv_head_pad(cfg: ModelConfig) -> int:
    h = cfg.rwkv_num_heads
    return cfg.head_pad_to if cfg.head_pad_to else h


def time_mix_params(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    dp = rwkv_head_pad(cfg) * hd  # padded inner width
    p: Params = {
        # token-shift interpolation factors
        "mu_r": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "mu_k": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "mu_v": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "mu_w": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "mu_g": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        # projections (outputs in padded head layout)
        "wr": ParamSpec((d, dp), cfg.param_dtype, ("embed", "rwkv_heads"), "fan_in"),
        "wk": ParamSpec((d, dp), cfg.param_dtype, ("embed", "rwkv_heads"), "fan_in"),
        "wv": ParamSpec((d, dp), cfg.param_dtype, ("embed", "rwkv_heads"), "fan_in"),
        "wg": ParamSpec((d, dp), cfg.param_dtype, ("embed", "rwkv_heads"), "fan_in"),
        "wo": ParamSpec((dp, d), cfg.param_dtype, ("rwkv_heads", "embed"), "fan_in"),
        # data-dependent decay: ww = w0 + tanh(x @ w1) @ w2
        "w0": ParamSpec((dp,), "float32", ("rwkv_heads",), "zeros"),
        "w1": ParamSpec((d, _LORA), cfg.param_dtype, ("embed", None), "fan_in"),
        "w2": ParamSpec((_LORA, dp), cfg.param_dtype, (None, "rwkv_heads"), "fan_in"),
        # per-channel bonus
        "u": ParamSpec((dp,), "float32", ("rwkv_heads",), "zeros"),
        # per-head group norm
        "ln_scale": ParamSpec((dp,), cfg.param_dtype, ("rwkv_heads",), "ones"),
        "ln_bias": ParamSpec((dp,), cfg.param_dtype, ("rwkv_heads",), "zeros"),
    }
    return p


def channel_mix_params(cfg: ModelConfig) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "mu_r": ParamSpec((d,), cfg.param_dtype, (None,), "zeros"),
        "wk": ParamSpec((d, ff), cfg.param_dtype, ("embed", "mlp"), "fan_in"),
        "wr": ParamSpec((d, d), cfg.param_dtype, ("embed", None), "fan_in"),
        "wv": ParamSpec((ff, d), cfg.param_dtype, ("mlp", "embed"), "fan_in"),
    }


def _token_shift(x, last=None):
    """Previous-token x (zeros / `last` for the first position)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _tm_inputs(p: Params, cfg: ModelConfig, x, xs):
    """Project r, k, v, g, log-decay la. Shapes: (b, s, H, hd) fp32 for wkv."""
    H = rwkv_head_pad(cfg)
    hd = cfg.rwkv_head_size

    def lerp(mu):
        return x + (xs - x) * mu

    r = jnp.einsum("bsd,dk->bsk", lerp(p["mu_r"]), p["wr"])
    k = jnp.einsum("bsd,dk->bsk", lerp(p["mu_k"]), p["wk"])
    v = jnp.einsum("bsd,dk->bsk", lerp(p["mu_v"]), p["wv"])
    g = jnp.einsum("bsd,dk->bsk", lerp(p["mu_g"]), p["wg"])
    ww = p["w0"] + jnp.einsum(
        "bsr,rk->bsk", jnp.tanh(jnp.einsum("bsd,dr->bsr", lerp(p["mu_w"]), p["w1"])), p["w2"]
    ).astype(jnp.float32)
    la = -jnp.exp(jnp.clip(ww, -8.0, 6.0))  # log-decay, la <= 0
    shp = x.shape[:2] + (H, hd)
    r, k, v, g = (t.reshape(shp) for t in (r, k, v, g))
    la = la.reshape(shp)
    r = logical_sharding(r, ("batch", None, "act_heads", None), None)
    k = logical_sharding(k, ("batch", None, "act_heads", None), None)
    v = logical_sharding(v, ("batch", None, "act_heads", None), None)
    u = p["u"].reshape(H, hd)
    return (r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            g, la, u)


def _group_norm(p: Params, cfg: ModelConfig, o):
    """Per-head layer norm over hd. o: (b, s, H, hd) fp32."""
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    H, hd = o.shape[-2], o.shape[-1]
    scale = p["ln_scale"].astype(jnp.float32).reshape(H, hd)
    bias = p["ln_bias"].astype(jnp.float32).reshape(H, hd)
    return (o - mu) * jax.lax.rsqrt(var + 64e-5) * scale + bias


def wkv_chunked(r, k, v, la, u, s_in, chunk: int = 64, unroll: bool = False):
    """Chunked-parallel WKV6. All inputs fp32.

    r/k/v/la: (b, s, H, K); u: (H, K); s_in: (b, H, K, V).
    Returns out (b, s, H, V), s_out.
    """
    b, s, H, K = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk

    rc = r.reshape(b, n, chunk, H, K).swapaxes(0, 1)
    kc = k.reshape(b, n, chunk, H, K).swapaxes(0, 1)
    vc = v.reshape(b, n, chunk, H, K).swapaxes(0, 1)
    lc = la.reshape(b, n, chunk, H, K).swapaxes(0, 1)

    def step(S, inp):
        rr, kk, vv, ll = inp                      # (b, c, H, K)
        a = jnp.cumsum(ll, axis=1)                # cumulative log decay (<=0, decreasing)
        a_prev = a - ll                           # a[t-1] (0 for t=0)
        # inter-chunk: r_t ⊙ exp(a_prev) applied to carried state
        r_in = rr * jnp.exp(a_prev)
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_in, S)
        # intra-chunk: factored pairwise decays, strictly-lower-triangular
        r_f = rr * jnp.exp(jnp.clip(a_prev, -_CLIP, _CLIP))
        k_f = kk * jnp.exp(jnp.clip(-a, -_CLIP, _CLIP))
        att = jnp.einsum("bchk,bdhk->bhcd", r_f, k_f)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
        att = att * tri[None, None]
        o_intra = jnp.einsum("bhcd,bdhv->bchv", att, vv)
        # current-token bonus
        o_bonus = jnp.einsum("bchk,bchk->bch", rr * u[None, None], kk)[..., None] * vv
        # state update: S' = diag(exp(a_last)) S + Σ_i (k_i ⊙ exp(a_last - a_i)) v_i^T
        a_last = a[:, -1:]
        k_dec = kk * jnp.exp(a_last - a)
        S_new = S * jnp.exp(a_last.squeeze(1))[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_dec, vv)
        return S_new, o_inter + o_intra + o_bonus

    if unroll:
        S, outs = s_in, []
        for ci in range(n):
            S, oc_i = step(S, (rc[ci], kc[ci], vc[ci], lc[ci]))
            outs.append(oc_i)
        s_out, oc = S, jnp.stack(outs)
    else:
        s_out, oc = jax.lax.scan(step, s_in, (rc, kc, vc, lc))
    out = oc.swapaxes(0, 1).reshape(b, s, H, K)
    return out, s_out


def time_mix(p: Params, cfg: ModelConfig, x, chunk: int = 64):
    xs = _token_shift(x)
    r, k, v, g, la, u = _tm_inputs(p, cfg, x, xs)
    b, s, H, hd = r.shape
    s0 = jnp.zeros((b, H, hd, hd), jnp.float32)
    out, _ = wkv_chunked(r, k, v, la, u, s0, chunk=chunk,
                         unroll=cfg.unroll_inner_scans)
    out = _group_norm(p, cfg, out)
    gate = jax.nn.silu(g.astype(jnp.float32)).reshape(b, s, H * hd)
    out = (out.reshape(b, s, H * hd) * gate).astype(x.dtype)
    y = jnp.einsum("bsk,kd->bsd", out, p["wo"])
    return logical_sharding(y, ("batch", None, None), None)


def time_mix_decode(p: Params, cfg: ModelConfig, x, state):
    """state = {"S": (b,H,K,V) fp32, "last": (b,1,d)}. Exact one-step."""
    xs = state["last"]
    r, k, v, g, la, u = _tm_inputs(p, cfg, x, xs)
    S = state["S"]
    rr, kk, vv, ll = r[:, 0], k[:, 0], v[:, 0], la[:, 0]  # (b, H, K)
    wkv = S + jnp.einsum("bhk,bhv->bhkv", u[None] * kk, vv)
    o = jnp.einsum("bhk,bhkv->bhv", rr, wkv)[:, None]
    S_new = S * jnp.exp(ll)[..., None] + jnp.einsum("bhk,bhv->bhkv", kk, vv)
    o = _group_norm(p, cfg, o)
    b = x.shape[0]
    H, hd = rr.shape[-2], rr.shape[-1]
    gate = jax.nn.silu(g.astype(jnp.float32)).reshape(b, 1, H * hd)
    o = (o.reshape(b, 1, H * hd) * gate).astype(x.dtype)
    y = jnp.einsum("bsk,kd->bsd", o, p["wo"])
    return y, {"S": S_new, "last": x}


def channel_mix(p: Params, x, last=None):
    xs = _token_shift(x, last)
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    k = logical_sharding(k, ("batch", None, "mlp"), None)
    r = jax.nn.sigmoid(jnp.einsum("bsd,dk->bsk", xr, p["wr"]))
    y = r * jnp.einsum("bsf,fd->bsd", k, p["wv"])
    return logical_sharding(y, ("batch", None, None), None)


def rwkv_state_specs(cfg: ModelConfig, batch: int):
    H, hd, d = rwkv_head_pad(cfg), cfg.rwkv_head_size, cfg.d_model
    return {
        "S": ParamSpec((batch, H, hd, hd), "float32", ("batch", "act_heads", None, None), "zeros"),
        "last": ParamSpec((batch, 1, d), cfg.dtype, ("batch", None, None), "zeros"),
        "cm_last": ParamSpec((batch, 1, d), cfg.dtype, ("batch", None, None), "zeros"),
    }
