"""`ClassificationView` — the `CREATE CLASSIFICATION VIEW` abstraction.

Ties together: a corpus of entities (raw features or an encoder feature
function = any assigned backbone), an incrementally-trained linear model,
and a HazyEngine per §3. Reads are always exact w.r.t. the current model —
policy only moves *when* maintenance work happens (eager/lazy/hybrid).

Architecture (PR 3): this is the top of a three-layer stack. The view owns
training (SGD on the example stream) and the SQL-ish read API; the engine
shell (`HazyEngine`, k = 1) owns storage layout and cost accounting; every
algorithm rule the shell executes — Lemma 3.1 partition, Eq. 2 waters,
SKIING — lives once in `core/engine.py`.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.linear_model import sgd_step, zero_model


class ClassificationView:
    def __init__(self, entities: np.ndarray, *,
                 feature_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 method: str = "svm", policy: str = "eager",
                 norm: Tuple[float, float] = (float("inf"), 1.0),
                 lr: float = 0.1, l2: float = 1e-4, alpha: float = 1.0,
                 buffer_frac: float = 0.01, engine: str = "hazy",
                 cost_mode: str = "measured", touch_ns: float = 0.0,
                 store=None):
        self.feature_fn = feature_fn
        F = feature_fn(entities) if feature_fn is not None else entities
        self.F = np.asarray(F, np.float32)
        self._entities = entities
        self.method = method
        self.lr, self.l2 = lr, l2
        self.model = zero_model(self.F.shape[1])
        p, q = norm
        self.hybrid = policy == "hybrid"
        # ctor parameters are stored ONCE and reused verbatim whenever the
        # engine is rebuilt (refresh_features) — nothing silently reverts.
        self._engine_kind = engine
        if engine == "hazy":
            # hybrid is a first-class HazyEngine policy (lazy maintenance +
            # §3.5.2 read tier) — no silent rewrite to eager.
            self._engine_kwargs = dict(
                p=p, q=q, alpha=alpha, policy=policy, cost_mode=cost_mode,
                touch_ns=touch_ns,
                buffer_frac=buffer_frac if self.hybrid else 0.0,
                store=store)
        else:
            if store is not None:
                raise ValueError("the storage tier (store=) requires "
                                 "engine='hazy'")
            self._engine_kwargs = dict(
                policy="lazy" if self.hybrid else policy, touch_ns=touch_ns)
        self.engine = self._make_engine()
        self.examples: list = []

    def _make_engine(self):
        if self._engine_kind == "hazy":
            return HazyEngine(self.F, **self._engine_kwargs)
        return NaiveEngine(self.F, **self._engine_kwargs)

    # ------------------------------------------------------------------
    # Updates ("INSERT INTO Example_Papers ...")
    # ------------------------------------------------------------------

    def insert_example(self, entity_id: Optional[int], label: float,
                       feature: Optional[np.ndarray] = None):
        f = self.F[entity_id] if feature is None else np.asarray(feature, np.float32)
        self.examples.append((f, float(label)))
        self.model = sgd_step(self.model, f, float(label), lr=self.lr,
                              l2=self.l2, method=self.method)
        self.engine.apply_model(self.model)

    def insert_examples(self, ids: Sequence[int], labels: Sequence[float], *,
                        batched: bool = True,
                        features: Optional[np.ndarray] = None):
        """Insert a batch of training examples.

        `batched=True` is the fast path: SGD still runs example-by-example
        (identical model trajectory to k `insert_example` calls), but view
        maintenance is amortized to ONE `apply_model` round at the end —
        reads after the batch observe only the batch-final model, and the
        view stays exact w.r.t. it. `batched=False` reproduces the seed's
        per-example maintenance (one HAZY round per insert).

        `features` (a `(len(ids), d)` matrix) overrides the row lookup in
        `self.F` — the freshness scheduler uses this to train derived
        views on inputs pinned at emission time."""
        if not batched:
            for j, (i, y) in enumerate(zip(ids, labels)):
                self.insert_example(
                    i, y, None if features is None else features[j])
            return
        for j, (i, y) in enumerate(zip(ids, labels)):
            f = self.F[i] if features is None else np.asarray(features[j],
                                                             np.float32)
            self.examples.append((f, float(y)))
            self.model = sgd_step(self.model, f, float(y), lr=self.lr,
                                  l2=self.l2, method=self.method)
        self.engine.apply_model(self.model)

    def retrain_from_scratch(self):
        """Paper footnote 2: deletions/label-changes retrain non-incrementally."""
        self.model = zero_model(self.F.shape[1])
        for f, y in self.examples:
            self.model = sgd_step(self.model, f, y, lr=self.lr, l2=self.l2,
                                  method=self.method)
        self.engine.apply_model(self.model)
        if isinstance(self.engine, HazyEngine):
            self.engine.reorganize()

    def refresh_features(self, entities: Optional[np.ndarray] = None):
        """Feature function (backbone) changed: recompute F and recluster."""
        if entities is not None:
            self._entities = entities
        F = self.feature_fn(self._entities) if self.feature_fn else self._entities
        self.F = np.asarray(F, np.float32)
        old_pool = self._engine_kwargs.get("store")
        if old_pool is not None:
            # the storage tier mirrors F on disk: rebuild it over the new
            # rows at the SAME budget/page geometry. Only the POOL is
            # dropped here — its EntityStore may be shared with sibling
            # views on the same base table (the catalog hands every
            # budgeted view one store per table), so closing it is the
            # owner's job; an orphaned temp-file store cleans itself up
            # when garbage-collected.
            from repro.storage import BufferPool, EntityStore
            self._engine_kwargs["store"] = BufferPool(
                EntityStore.from_array(self.F,
                                       page_bytes=old_pool.store.page_bytes),
                old_pool.budget_bytes)
            old_pool.close()
        self.engine = self._make_engine()   # same ctor kwargs: q, touch_ns,
        self.engine.apply_model(self.model)  # alpha … all survive the rebuild

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def label(self, entity_id: int) -> int:
        if self.hybrid and isinstance(self.engine, HazyEngine):
            lab, _ = self.engine.hybrid_label(entity_id)
            return lab
        return self.engine.label(entity_id)

    def all_members(self) -> int:
        return self.engine.all_members()

    def members(self) -> np.ndarray:
        return self.engine.members()
