"""The SKIING strategy (paper §3.2.1, Fig. 7) + offline OPT for tests.

SKIING: accumulate incremental-step costs a += c_i; when a ≥ αS, reorganize
and reset a. α is the positive root of x² + σx − 1 (σ = scan/reorg ratio);
the paper proves competitive ratio exactly 1 + α + σ (Lemma 3.2) and that
this is optimal among deterministic online strategies.

`opt_cost` is the O(N²) offline dynamic program over monotone cost
matrices — the hypothesis property tests check
    cost(SKIING) ≤ (1 + α + σ) · cost(OPT) + O(S)
on random inputs (the additive S covers edge effects of finite runs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Tuple

from repro.core.engine import skiing_charge, skiing_due


def alpha_star(sigma: float) -> float:
    """Positive root of x² + σx − 1."""
    return (-sigma + math.sqrt(sigma * sigma + 4.0)) / 2.0


@dataclasses.dataclass
class Skiing:
    S: float                  # reorganization cost (seconds); updated on reorg
    alpha: float = 1.0
    a: float = 0.0            # accumulated incremental cost
    reorgs: int = 0
    total_incremental: float = 0.0

    def should_reorganize(self) -> bool:
        return bool(skiing_due(self.a, self.alpha, self.S))

    def record_incremental(self, c: float) -> bool:
        """Add one incremental-step cost; returns True if a reorg is due."""
        self.a = skiing_charge(self.a, c)
        self.total_incremental += c
        return self.should_reorganize()

    def record_reorg(self, measured_S: float = None):
        self.a = 0.0
        self.reorgs += 1
        if measured_S is not None and measured_S > 0:
            self.S = measured_S

    @property
    def total_cost(self) -> float:
        return self.total_incremental + self.reorgs * self.S


def skiing_schedule(costs: Callable[[int, int], float], n: int, S: float,
                    alpha: float = 1.0) -> Tuple[List[int], float]:
    """Run SKIING over rounds 1..n with cost oracle costs(s, i) (cost of an
    incremental step at round i when last reorg was at s). Returns
    (reorg rounds, total cost)."""
    sk = Skiing(S=S, alpha=alpha)
    s = 0
    schedule = []
    total = 0.0
    for i in range(1, n + 1):
        c = costs(s, i)
        # decision per Fig. 7: reorganize when accumulated cost has reached αS
        if skiing_due(sk.a, alpha, S):
            schedule.append(i)
            sk.record_reorg()
            s = i
            total += S
        else:
            sk.record_incremental(c)
            total += c
    return schedule, total


def opt_cost(costs: Callable[[int, int], float], n: int, S: float) -> float:
    """Offline optimum via DP. f[t] = best cost of rounds 1..t with a
    reorganization at round t (round t costs S). Answer considers a last
    segment with no further reorgs."""
    INF = float("inf")
    # pref[s][t] = sum_{i=s+1..t} costs(s, i), computed lazily per s
    f = [INF] * (n + 1)
    f[0] = 0.0
    seg = [[0.0] * (n + 1) for _ in range(n + 1)]
    for s in range(n + 1):
        run = 0.0
        for i in range(s + 1, n + 1):
            run += costs(s, i)
            seg[s][i] = run
    for t in range(1, n + 1):
        best = INF
        for s in range(t):
            c = f[s] + (seg[s][t - 1] if t - 1 >= s + 1 else 0.0) + S
            if c < best:
                best = c
        f[t] = best
    ans = seg[0][n]  # never reorganize
    for s in range(1, n + 1):
        tail = seg[s][n] if n >= s + 1 else 0.0
        ans = min(ans, f[s] + tail)
    return ans
