"""Vectorized multi-view HAZY maintenance: k one-vs-all views, ONE table.

The paper's multiclass experiments (App. B.5.4 / C.3) run k independent
binary HAZY views — our seed reproduced that literally with k `HazyEngine`s,
each holding its *own copy* of the feature table (`F_sorted`) and re-scanning
it per update. Following F-IVM's observation that many model-based views
over the same relation should share the underlying relational state, this
engine keeps

  * the feature table `F` exactly once, in fixed entity order — it is never
    gathered into per-view sorted copies (k·n·d bytes -> n·d bytes);
  * all k models stacked as a `(k, d)` matrix `W` plus `(k,)` biases, so one
    training insert updates every view with a single rank-1 update and one
    matrix-vector product;
  * the eps-clustered scratch state per view as *rows of arrays*:
    `eps_sorted`/`perm`/`inv_perm`/`labels_sorted` are `(k, n)`, Hölder
    waters `lw`/`hw` are `(k,)`, and the SKIING accumulators are `(k,)` —
    no per-view Python objects on the hot path.

One maintenance round then costs: a vectorized waters update (row norms of
`W − W_stored`), k binary searches to locate the per-view bands, ONE gather
of the union band's feature rows, ONE matmul `F[union] @ W.T` that
reclassifies every view's band simultaneously, and a per-view scatter of
band-sized label slices. Reorganizations batch the same way: all due views
re-sort from one `F @ W[due].T` product. HBM/cache traffic is proportional
to the union band, not k times the table.

Laziness is PER VIEW: `pending` is a `(k,)` mask, so a read that touches
only view v (`label`, `members`, `hybrid_label`) catches up that view alone
while the cold k−1 views keep deferring; the paper's §3.4 lazy waste
accounting is charged per view (`lazy_waste`).

The §3.5.2/Fig. 8 hybrid read tier is also per-view rows of shared arrays:
`(k,)` hot-buffer windows `buffer_lo`/`buffer_hi` around each view's zero
boundary (with the buffered feature rows materialized per view, the "stored
in memory" fraction), and `hybrid_label` / `hybrid_labels_of` resolving
eps-map -> waters short-circuit -> buffer -> "disk". A pending model only
needs the monotone waters update for the short-circuit to stay exact, so
hybrid reads never force a catch-up; the batched probe touches the shared
`F[entity_id]` row at most ONCE for all k views that miss, instead of k
feature reads.

Cost accounting mirrors `hazy.py`: `cost_mode="measured"` splits the round's
wall time across views by band width; `"modeled"` charges `S_v · width_v/n`
(deterministic, used by the equivalence tests). Each view keeps its own
SKIING accumulator, so per-view reorg cadence matches the k-engine seed.

This class is a stateful shell over the functional core in
`core/engine.py`: it owns storage (rows-of-arrays layout), wall-clock
timing and the per-tier instrumentation counters, while every algorithm
rule — the Lemma 3.1 partition (`band_partition`/`probe_partition`), the
Eq. 2 waters update (`waters_update`), the SKIING charge rule
(`skiing_charge`/`skiing_due`), sign labels (`classify`) and the hot-buffer
window — is imported from `core/engine.py`. The pure `EngineState` steps in
engine.py are the executable specification of this shell's modeled-cost
behaviour; the property tests assert the two trajectories are identical.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core.engine import (PROBE_TIERS, TIER_BUFFER,
                               TIER_DISK, TIER_POOL, TIER_WATER,
                               band_partition, classify, hot_buffer_window,
                               probe_partition, skiing_charge,
                               skiing_due, waters_update)
from repro.core.hazy import Stats
from repro.obs import clock
from repro.obs.cost import ViewCostRecorder
from repro.core.skiing import alpha_star
from repro.core.waters import holder_M


class MultiViewEngine:
    """Eager/lazy/hybrid maintenance of k binary views over one shared table."""

    def __init__(self, features: np.ndarray, num_views: int, *,
                 p: float = float("inf"), q: float = 1.0, alpha: float = 1.0,
                 policy: str = "eager", cost_mode: str = "measured",
                 touch_ns: float = 0.0, buffer_frac: float = 0.0,
                 store=None):
        assert policy in ("eager", "lazy", "hybrid")
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.k = int(num_views)
        self.p = p
        self.policy = policy
        self._defers = policy in ("lazy", "hybrid")
        self.cost_mode = cost_mode
        self.touch_ns = touch_ns
        self.M = holder_M(self.F, q)

        k, n = self.k, self.n
        self.W = np.zeros((k, self.d), np.float32)
        self.b = np.zeros(k, np.float64)
        self.W_stored = np.zeros((k, self.d), np.float32)
        self.b_stored = np.zeros(k, np.float64)
        self.lw = np.zeros(k, np.float64)
        self.hw = np.zeros(k, np.float64)
        self.perm = np.zeros((k, n), np.int64)
        self.inv_perm = np.zeros((k, n), np.int64)
        self.eps_sorted = np.zeros((k, n), np.float32)
        self.labels_sorted = np.zeros((k, n), np.int8)
        self.pos_count = np.zeros(k, np.int64)
        self.pending = np.zeros(k, bool)        # per-view deferred maintenance
        self._waters_stale = np.zeros(k, bool)  # waters behind current model
        self._waters_dirty = False              # scalar mirror of .any()
        self.lazy_waste = np.zeros(k, np.float64)  # §3.4 waste, per view
        # §3.5.2 hot buffer, per view: [buffer_lo, buffer_hi) positions of
        # the eps-sorted order, with the feature rows materialized (the
        # fraction of entities "stored in memory"; F is the disk tier).
        self.buffer_frac = buffer_frac
        self.buffer_cap = max(1, int(buffer_frac * n)) if buffer_frac else 0
        self.buffer_lo = np.zeros(k, np.int64)
        self.buffer_hi = np.zeros(k, np.int64)
        # optional memory-budgeted storage tier (repro.storage.BufferPool):
        # when set, the hot buffers are PINNED pool pages (no materialized
        # buffer_F copies) and probe misses read through the pool, which
        # subdivides the "disk" touch into pool hit vs cold page read.
        self.store = store
        self._eps_order = None   # boundary-outward eps order (readahead)
        self._eps_pos = None     # entity id -> position in _eps_order
        self.buffer_F: Optional[np.ndarray] = (
            np.zeros((k, self.buffer_cap, self.d), np.float32)
            if self.buffer_cap and store is None else None)
        self.hybrid_hits = np.zeros(len(PROBE_TIERS), np.int64)  # per-tier probes
        self.disk_touches = 0        # COLD shared F-row reads by probes
        self._arange_k = np.arange(k)

        # Initial organization of all k views; the measured wall time seeds
        # the per-view SKIING S (one view's share of the batched reorg).
        # stats/S/acc are created only afterwards (guarded by hasattr below)
        # so the free init round is never charged.
        # measured-cost telemetry, created BEFORE the free init round but
        # only fed once S exists (same hasattr guard as the stats): wall
        # timings recorded alongside modeled charges, never altering them.
        self.cost = ViewCostRecorder(k)
        t0 = clock()
        self._reorganize_views(np.ones(k, bool))
        S0 = max(clock() - t0, 1e-9) / k
        t0 = clock()
        float(np.sum(self.eps_sorted[0]))
        scan = max(clock() - t0, 1e-12)
        self.sigma = min(1.0, scan / S0)
        self.alpha = alpha if alpha else alpha_star(self.sigma)
        # modeled mode pins S to 1.0 (S-invariant dimensionless charges,
        # exactly the Layer 2 pure-step contract) so SKIING trajectories
        # are bitwise deterministic; measured mode uses wall-time S.
        self.S = np.full(k, 1.0 if cost_mode == "modeled" else S0,
                         np.float64)              # per-view reorg cost
        self.acc = np.zeros(k, np.float64)        # SKIING accumulators
        self.stats = Stats()
        self.reorg_counts = np.zeros(k, np.int64)

    # ------------------------------------------------------------------
    # Organization
    # ------------------------------------------------------------------

    def _reorganize_views(self, mask: np.ndarray):
        """Re-sort the scratch state of every view in `mask` from one
        shared `F @ W[mask].T` product. F itself never moves."""
        views = np.flatnonzero(mask)
        if views.size == 0:
            return
        t0 = clock()
        Z = self.F @ self.W[views].T - self.b[views].astype(np.float32)
        for j, v in enumerate(views):
            e = Z[:, j]
            order = np.argsort(e, kind="stable")
            self.perm[v] = order
            self.inv_perm[v, order] = np.arange(self.n)
            self.eps_sorted[v] = e[order]
            lab = classify(self.eps_sorted[v])
            self.labels_sorted[v] = lab
            self.pos_count[v] = int(np.count_nonzero(lab == 1))
            if self.buffer_cap:
                blo, bhi = hot_buffer_window(self.eps_sorted[v], self.buffer_cap)
                self.buffer_lo[v], self.buffer_hi[v] = blo, bhi
                if self.buffer_F is not None:
                    self.buffer_F[v, :bhi - blo] = self.F[order[blo:bhi]]
        if self.store is not None:
            self._rewarm_store()
        self.W_stored[views] = self.W[views]
        self.b_stored[views] = self.b[views]
        self.lw[views] = 0.0
        self.hw[views] = 0.0
        self._waters_stale[views] = False
        self.pending[views] = False
        wall = (clock() - t0
                + self.touch_ns * 1e-9 * self.n * views.size)
        if hasattr(self, "S"):   # absent only during the free init round
            if self.cost_mode != "modeled":   # modeled: S stays pinned at 1.0
                self.S[views] = wall / views.size
            self.acc[views] = 0.0
            self.stats.reorgs += int(views.size)
            self.reorg_counts[views] += 1
            self.stats.reorg_seconds += wall
            for v in views:   # one view's share of the batched reorg
                self.cost.record_reorg(int(v), wall / views.size)

    def _rewarm_store(self):
        """Re-warm the pool along the new clustering order: pin the pages
        of every view's hot-buffer window, then prefetch pages of entities
        in the SHARED boundary-outward order (ascending min_v |eps_v| —
        the same locality order the sharded scratch table clusters by)
        until the budget is full."""
        if self.buffer_cap:
            hot = np.concatenate(
                [self.perm[v, self.buffer_lo[v]:self.buffer_hi[v]]
                 for v in range(self.k)])
        else:
            hot = np.empty(0, np.int64)
        self.store.repin_rows(hot)
        eps_entity = np.take_along_axis(self.eps_sorted, self.inv_perm, axis=1)
        order = np.argsort(np.min(np.abs(eps_entity), axis=0), kind="stable")
        # cache the boundary-outward order for per-miss readahead hints;
        # with a Prefetcher attached the warm-up overlaps serving.
        self._eps_order = order
        pos = np.empty(self.n, np.int64)
        pos[order] = np.arange(self.n)
        self._eps_pos = pos
        pre = getattr(self.store, "prefetcher", None)
        if pre is not None:
            pre.enqueue(order)
        else:
            self.store.warm(order)

    def _hint_readahead(self, entity_id: int, window: int = 64):
        """Probe miss at shared eps-position p: enqueue the next `window`
        entities boundary-outward (eps order is locality order, so these
        are the NEXT pages). No-op without an attached prefetcher."""
        pre = getattr(self.store, "prefetcher", None)
        if pre is None or self._eps_order is None:
            return
        p = int(self._eps_pos[entity_id])
        nxt = self._eps_order[p + 1:p + 1 + window]
        if nxt.size:
            pre.enqueue(nxt, evict=True)

    # ------------------------------------------------------------------
    # One maintenance round (all k views)
    # ------------------------------------------------------------------

    def apply_models(self, W: np.ndarray, b: np.ndarray):
        """The k views must reflect the stacked model (W, b): eager does the
        banded reclassify now; lazy/hybrid defer it to the next read that
        actually touches each view (per-view pending mask)."""
        self.W = np.asarray(W, np.float32).copy()
        self.b = np.asarray(b, np.float64).copy()
        self.stats.rounds += 1
        if self._defers:
            self.pending[:] = True
            self._waters_stale[:] = True
            self._waters_dirty = True
            if self.policy == "hybrid":
                # §3.5.2: band relabels stay deferred per view, but the
                # eps-map must stay tight or probes degrade to the disk
                # tier — SKIING still reorganizes due views on updates,
                # charging the expected probe miss rate (band fraction).
                self._update_waters(np.arange(self.k))
                lo, hi = self._bands(np.arange(self.k))
                self.acc = skiing_charge(
                    self.acc, self.S * ((hi - lo) / max(1, self.n)))
                due = skiing_due(self.acc, self.alpha, self.S)
                self._reorganize_views(due)   # clears pending for due views
            return
        # SKIING, check-first (Fig. 7), independently per view.
        due = skiing_due(self.acc, self.alpha, self.S)
        self._reorganize_views(due)
        self._incremental_step(~due)

    def _update_waters(self, views: np.ndarray):
        """Vectorized Eq. 2 for the given views via the shared engine core
        (monotone, idempotent)."""
        self.lw[views], self.hw[views] = waters_update(
            self.lw[views], self.hw[views], self.W[views], self.b[views],
            self.W_stored[views], self.b_stored[views], self.M, self.p)
        self._waters_stale[views] = False
        self._waters_dirty = bool(self._waters_stale.any())

    def _bands(self, views: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        # [lw, hw) per view — THE shared Lemma 3.1 partition, the same
        # helper the hybrid probe short-circuits with (probe_partition).
        lo = np.empty(views.size, np.int64)
        hi = np.empty(views.size, np.int64)
        eps, lw, hw = self.eps_sorted, self.lw, self.hw
        for j, v in enumerate(views):
            lo[j], hi[j] = band_partition(eps[v], lw[v], hw[v])
        return lo, hi

    def _relabel_bands(self, views: np.ndarray):
        """The shared banded-reclassify core: vectorized waters update
        (Eq. 2), per-view band location, ONE gather of the union band's
        feature rows and ONE matmul that classifies every view's band.
        Returns (lo, widths, total, wall) for the caller's cost model."""
        t0 = clock()
        self._update_waters(views)
        lo, hi = self._bands(views)
        widths = hi - lo
        total = int(widths.sum())
        if total > 0:
            band_ids = [self.perm[v, lo[j]:hi[j]] for j, v in enumerate(views)]
            uids = np.unique(np.concatenate(band_ids))
            # ONE matmul classifies every view's band under its own model.
            Z = self.F[uids] @ self.W[views].T - self.b[views].astype(np.float32)
            for j, v in enumerate(views):
                if widths[j] == 0:
                    continue
                z = Z[np.searchsorted(uids, band_ids[j]), j]  # union-id lookup
                new = classify(z)
                old = self.labels_sorted[v, lo[j]:hi[j]]
                self.pos_count[v] += (int(np.count_nonzero(new == 1))
                                      - int(np.count_nonzero(old == 1)))
                self.labels_sorted[v, lo[j]:hi[j]] = new
        wall = clock() - t0 + self.touch_ns * 1e-9 * total
        self.stats.tuples_reclassified += total
        self.stats.tuples_total_possible += self.n * views.size
        return lo, widths, total, wall

    def _incremental_step(self, mask: np.ndarray):
        views = np.flatnonzero(mask)
        if views.size == 0:
            return
        lo, widths, total, wall = self._relabel_bands(views)
        measured = wall * (widths / max(1, total))   # per-view wall share
        if self.cost_mode == "modeled":
            costs = self.S[views] * (widths / max(1, self.n))
        else:
            costs = measured
        for j, v in enumerate(views):
            self.cost.record_step(int(v), float(measured[j]), float(costs[j]))
        self.acc[views] = skiing_charge(self.acc[views], costs)
        self.stats.band_fraction_last = float(widths.mean()) / max(1, self.n)
        self.stats.incremental_seconds += wall

    def _catch_up(self, views: Optional[np.ndarray] = None):
        """Catch up the PENDING subset of `views` (default: every view).
        Views outside `views` keep deferring — per-view laziness — and the
        paper's §3.4 lazy waste is charged only to the views read now."""
        if not self._defers:
            return
        if views is None:
            todo = np.flatnonzero(self.pending)
        else:
            todo = np.asarray(views)[self.pending[np.asarray(views)]]
        if todo.size == 0:
            return
        lo, widths, total, wall = self._relabel_bands(todo)
        self.pending[todo] = False
        # §3.4 lazy waste per view: (N_R − N_+)/N_R of the tuples a lazy
        # All-Members read scans are wasted (read but not returned).
        n_read = np.maximum(1, self.n - lo)
        waste = np.maximum(0.0, (n_read - self.pos_count[todo]) / n_read)
        self.lazy_waste[todo] += waste
        measured = wall * (widths / max(1, total))   # per-view wall share
        if self.cost_mode == "modeled":
            costs = self.S[todo] * waste
        else:
            costs = measured
        for j, v in enumerate(todo):
            self.cost.record_step(int(v), float(measured[j]), float(costs[j]))
        self.acc[todo] = skiing_charge(self.acc[todo], costs)
        self.stats.incremental_seconds += wall
        due = np.zeros(self.k, bool)
        due[todo] = skiing_due(self.acc[todo], self.alpha, self.S[todo])
        self._reorganize_views(due)

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def all_members(self) -> np.ndarray:
        """Per-view positive-member counts, (k,) — the All Members probe
        answered for every one-vs-all view at once (touches all k views)."""
        self._catch_up()
        return self.pos_count.copy()

    def members(self, view: int) -> np.ndarray:
        self._catch_up(np.array([view]))
        return self.perm[view, self.labels_sorted[view] == 1]

    def label(self, view: int, entity_id: int) -> int:
        """Hot read of ONE view: catches up only that view; the other k−1
        pending views keep deferring."""
        self._catch_up(np.array([view]))
        return int(self.labels_sorted[view, self.inv_perm[view, entity_id]])

    def labels_of(self, entity_id: int) -> np.ndarray:
        """All k view labels of one entity, (k,) int8 (one eps-map probe
        per view; no feature access). Touches — and catches up — all views."""
        self._catch_up()
        pos = self.inv_perm[:, entity_id]
        return self.labels_sorted[self._arange_k, pos]

    def band_fractions(self) -> np.ndarray:
        self._catch_up()   # stale waters would report pre-catch-up bands
        lo, hi = self._bands(np.arange(self.k))
        return (hi - lo) / max(1, self.n)

    # ------------------------------------------------------------------
    # Hybrid single-entity reads (paper §3.5.2, Fig. 8) — per-view tier
    # ------------------------------------------------------------------

    def hybrid_label(self, view: int, entity_id: int) -> Tuple[int, str]:
        """One view's §3.5.2 read: eps-map probe -> waters short-circuit ->
        hot buffer -> "disk" (the shared F row). Exact under every policy:
        a pending model needs only the monotone waters update, never a
        catch-up relabel, so cold views stay deferred."""
        if self._waters_dirty:
            self._update_waters(np.flatnonzero(self._waters_stale))
        pos = self.inv_perm[view, entity_id]
        e = self.eps_sorted[view, pos]
        # THE Lemma 3.1 point-probe (shared with _bands / band_partition)
        t = int(probe_partition(e, self.lw[view], self.hw[view]))
        if t != 0:
            self.hybrid_hits[TIER_WATER] += 1
            return t, "water"
        if self.buffer_cap \
                and self.buffer_lo[view] <= pos < self.buffer_hi[view] \
                and (self.store is None or self.store.resident(entity_id)):
            # with a storage tier the hot buffer is a PINNED pool page; a
            # window wider than the budget leaves its tail unpinned — those
            # rows are NOT "in the buffer" and fall to the pool/disk tiers
            f = (self.store.get_row(entity_id) if self.store is not None
                 else self.buffer_F[view, pos - self.buffer_lo[view]])
            z = f @ self.W[view] - np.float32(self.b[view])
            self.hybrid_hits[TIER_BUFFER] += 1
            return int(classify(z)), "buffer"
        if self.store is not None:           # probe miss -> the buffer pool
            f, how = self.store.touch(entity_id)
            tier = TIER_POOL if how == "pool" else TIER_DISK
            if tier == TIER_DISK:
                self.disk_touches += 1       # cold page reads only
                self._hint_readahead(entity_id)
            z = f @ self.W[view] - np.float32(self.b[view])
            self.hybrid_hits[tier] += 1
            return int(classify(z)), PROBE_TIERS[tier]
        z = self.F[entity_id] @ self.W[view] - np.float32(self.b[view])
        self.disk_touches += 1     # charged as disk_touches * touch_ns by
        self.hybrid_hits[TIER_DISK] += 1   # callers; time.sleep granularity
        return int(classify(z)), "disk"  # (~100us) would swamp it

    def hybrid_labels_of(self, entity_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """All k views' §3.5.2 reads at once: returns ((k,) int8 labels,
        (k,) int8 tier codes indexing HYBRID_TIERS). The waters test is one
        vectorized (k,) compare; the views that miss water AND buffer share
        ONE `F[entity_id]` touch (one matvec against their stacked models)
        instead of k feature reads."""
        if self._waters_dirty:
            self._update_waters(np.flatnonzero(self._waters_stale))
        pos = self.inv_perm[:, entity_id]
        e = self.eps_sorted[self._arange_k, pos]
        # THE Lemma 3.1 point-probe, vectorized over views: ±1 resolved by
        # the waters, 0 = in the band (classify against the current model).
        t = probe_partition(e, self.lw, self.hw)
        miss = t == 0
        if not miss.any():                 # every view water-short-circuited
            self.hybrid_hits[TIER_WATER] += self.k
            return t.copy(), np.zeros(self.k, np.int8)
        labels = t.copy()
        how = np.zeros(self.k, np.int8)
        if self.buffer_cap and (self.store is None
                                or self.store.resident(entity_id)):
            in_buf = miss & (self.buffer_lo <= pos) & (pos < self.buffer_hi)
            bviews = np.flatnonzero(in_buf)
            if bviews.size:
                if self.store is not None:
                    # ONE pinned-pool-page read serves every buffered view
                    f = self.store.get_row(entity_id)
                    z = self.W[bviews] @ f - self.b[bviews].astype(np.float32)
                else:
                    rows = self.buffer_F[bviews,
                                         pos[bviews] - self.buffer_lo[bviews]]
                    z = np.einsum("vd,vd->v", rows, self.W[bviews]) \
                        - self.b[bviews].astype(np.float32)
                labels[bviews] = classify(z)
                how[bviews] = TIER_BUFFER
                miss = miss & ~in_buf
        dviews = np.flatnonzero(miss)
        if dviews.size:
            if self.store is not None:     # the ONE shared touch, via the pool
                f, how_s = self.store.touch(entity_id)
                code = TIER_POOL if how_s == "pool" else TIER_DISK
                if code == TIER_DISK:
                    self.disk_touches += 1        # cold page reads only
                    self._hint_readahead(entity_id)
            else:
                f = self.F[entity_id]      # the ONE shared feature touch
                code = TIER_DISK
                self.disk_touches += 1     # callers charge touch_ns per touch
            z = self.W[dviews] @ f - self.b[dviews].astype(np.float32)
            labels[dviews] = classify(z)
            how[dviews] = code
        n_disk = int(np.count_nonzero(how == TIER_DISK))
        n_pool = int(np.count_nonzero(how == TIER_POOL))
        n_buffer = int(np.count_nonzero(how == TIER_BUFFER))
        self.hybrid_hits[TIER_WATER] += self.k - n_buffer - n_disk - n_pool
        self.hybrid_hits[TIER_BUFFER] += n_buffer
        self.hybrid_hits[TIER_DISK] += n_disk
        self.hybrid_hits[TIER_POOL] += n_pool
        return labels, how

    # ------------------------------------------------------------------

    def check_consistent(self) -> bool:
        """Golden invariant, per view: maintained labels == from-scratch
        relabel of the shared table under that view's current model."""
        self._catch_up()
        Z = self.F @ self.W.T - self.b.astype(np.float32)
        for v in range(self.k):
            truth = classify(Z[self.perm[v], v])
            if not np.array_equal(truth, self.labels_sorted[v]):
                return False
        return True
