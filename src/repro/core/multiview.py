"""Vectorized multi-view HAZY maintenance: k one-vs-all views, ONE table.

The paper's multiclass experiments (App. B.5.4 / C.3) run k independent
binary HAZY views — our seed reproduced that literally with k `HazyEngine`s,
each holding its *own copy* of the feature table (`F_sorted`) and re-scanning
it per update. Following F-IVM's observation that many model-based views
over the same relation should share the underlying relational state, this
engine keeps

  * the feature table `F` exactly once, in fixed entity order — it is never
    gathered into per-view sorted copies (k·n·d bytes -> n·d bytes);
  * all k models stacked as a `(k, d)` matrix `W` plus `(k,)` biases, so one
    training insert updates every view with a single rank-1 update and one
    matrix-vector product;
  * the eps-clustered scratch state per view as *rows of arrays*:
    `eps_sorted`/`perm`/`inv_perm`/`labels_sorted` are `(k, n)`, Hölder
    waters `lw`/`hw` are `(k,)`, and the SKIING accumulators are `(k,)` —
    no per-view Python objects on the hot path.

One maintenance round then costs: a vectorized waters update (row norms of
`W − W_stored`), k binary searches to locate the per-view bands, ONE gather
of the union band's feature rows, ONE matmul `F[union] @ W.T` that
reclassifies every view's band simultaneously, and a per-view scatter of
band-sized label slices. Reorganizations batch the same way: all due views
re-sort from one `F @ W[due].T` product. HBM/cache traffic is proportional
to the union band, not k times the table.

Cost accounting mirrors `hazy.py`: `cost_mode="measured"` splits the round's
wall time across views by band width; `"modeled"` charges `S_v · width_v/n`
(deterministic, used by the equivalence tests). Each view keeps its own
SKIING accumulator, so per-view reorg cadence matches the k-engine seed.
"""
from __future__ import annotations

import time
from typing import Tuple

import numpy as np

from repro.core.hazy import Stats
from repro.core.skiing import alpha_star
from repro.core.waters import holder_M


def row_norms(X: np.ndarray, p: float) -> np.ndarray:
    """`vector_norm` over rows: (k, d) -> (k,)."""
    if X.size == 0:
        return np.zeros(X.shape[0], np.float32)
    if np.isinf(p):
        return np.max(np.abs(X), axis=1)
    if p == 1.0:
        return np.sum(np.abs(X), axis=1)
    return np.sum(np.abs(X) ** p, axis=1) ** (1.0 / p)


class MultiViewEngine:
    """Eager/lazy maintenance of k binary views over one shared table."""

    def __init__(self, features: np.ndarray, num_views: int, *,
                 p: float = float("inf"), q: float = 1.0, alpha: float = 1.0,
                 policy: str = "eager", cost_mode: str = "measured",
                 touch_ns: float = 0.0):
        assert policy in ("eager", "lazy")
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.k = int(num_views)
        self.p = p
        self.policy = policy
        self.cost_mode = cost_mode
        self.touch_ns = touch_ns
        self.M = holder_M(self.F, q)

        k, n = self.k, self.n
        self.W = np.zeros((k, self.d), np.float32)
        self.b = np.zeros(k, np.float64)
        self.W_stored = np.zeros((k, self.d), np.float32)
        self.b_stored = np.zeros(k, np.float64)
        self.lw = np.zeros(k, np.float64)
        self.hw = np.zeros(k, np.float64)
        self.perm = np.zeros((k, n), np.int64)
        self.inv_perm = np.zeros((k, n), np.int64)
        self.eps_sorted = np.zeros((k, n), np.float32)
        self.labels_sorted = np.zeros((k, n), np.int8)
        self.pos_count = np.zeros(k, np.int64)
        self.stats = Stats()
        self.reorg_counts = np.zeros(k, np.int64)
        self._pending = False  # lazy: a model round awaits catch-up

        # Initial organization of all k views; the measured wall time seeds
        # the per-view SKIING S (one view's share of the batched reorg).
        t0 = time.perf_counter()
        self._reorganize_views(np.ones(k, bool))
        S0 = max(time.perf_counter() - t0, 1e-9) / k
        t0 = time.perf_counter()
        float(np.sum(self.eps_sorted[0]))
        scan = max(time.perf_counter() - t0, 1e-12)
        self.sigma = min(1.0, scan / S0)
        self.alpha = alpha if alpha else alpha_star(self.sigma)
        self.S = np.full(k, S0, np.float64)       # per-view reorg cost
        self.acc = np.zeros(k, np.float64)        # SKIING accumulators
        self.stats = Stats()                      # init organization is free
        self.reorg_counts[:] = 0

    # ------------------------------------------------------------------
    # Organization
    # ------------------------------------------------------------------

    def _reorganize_views(self, mask: np.ndarray):
        """Re-sort the scratch state of every view in `mask` from one
        shared `F @ W[mask].T` product. F itself never moves."""
        views = np.flatnonzero(mask)
        if views.size == 0:
            return
        t0 = time.perf_counter()
        Z = self.F @ self.W[views].T - self.b[views].astype(np.float32)
        for j, v in enumerate(views):
            e = Z[:, j]
            order = np.argsort(e, kind="stable")
            self.perm[v] = order
            self.inv_perm[v, order] = np.arange(self.n)
            self.eps_sorted[v] = e[order]
            lab = np.where(self.eps_sorted[v] >= 0, 1, -1).astype(np.int8)
            self.labels_sorted[v] = lab
            self.pos_count[v] = int(np.count_nonzero(lab == 1))
        self.W_stored[views] = self.W[views]
        self.b_stored[views] = self.b[views]
        self.lw[views] = 0.0
        self.hw[views] = 0.0
        wall = (time.perf_counter() - t0
                + self.touch_ns * 1e-9 * self.n * views.size)
        if hasattr(self, "S"):
            self.S[views] = wall / views.size
            self.acc[views] = 0.0
        self.stats.reorgs += int(views.size)
        self.reorg_counts[views] += 1
        self.stats.reorg_seconds += wall

    # ------------------------------------------------------------------
    # One maintenance round (all k views)
    # ------------------------------------------------------------------

    def apply_models(self, W: np.ndarray, b: np.ndarray):
        """The k views must reflect the stacked model (W, b): eager does the
        banded reclassify now, lazy defers it to the next read."""
        self.W = np.asarray(W, np.float32).copy()
        self.b = np.asarray(b, np.float64).copy()
        self.stats.rounds += 1
        if self.policy == "lazy":
            self._pending = True
            return
        # SKIING, check-first (Fig. 7), independently per view.
        due = self.acc >= self.alpha * self.S
        self._reorganize_views(due)
        self._incremental_step(~due)

    def _bands(self, views: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        lo = np.empty(views.size, np.int64)
        hi = np.empty(views.size, np.int64)
        eps, lw, hw = self.eps_sorted, self.lw, self.hw
        for j, v in enumerate(views):
            row = eps[v]
            lo[j] = row.searchsorted(lw[v], "left")    # ndarray method: the
            hi[j] = row.searchsorted(hw[v], "right")   # hot path, no wrapper
        return lo, hi

    def _relabel_bands(self, views: np.ndarray):
        """The shared banded-reclassify core: vectorized waters update
        (Eq. 2), per-view band location, ONE gather of the union band's
        feature rows and ONE matmul that classifies every view's band.
        Returns (lo, widths, total, wall) for the caller's cost model."""
        t0 = time.perf_counter()
        dw = row_norms(self.W[views] - self.W_stored[views], self.p)
        db = self.b[views] - self.b_stored[views]
        self.lw[views] = np.minimum(self.lw[views], -self.M * dw + db)
        self.hw[views] = np.maximum(self.hw[views], self.M * dw + db)
        lo, hi = self._bands(views)
        widths = hi - lo
        total = int(widths.sum())
        if total > 0:
            band_ids = [self.perm[v, lo[j]:hi[j]] for j, v in enumerate(views)]
            uids = np.unique(np.concatenate(band_ids))
            # ONE matmul classifies every view's band under its own model.
            Z = self.F[uids] @ self.W[views].T - self.b[views].astype(np.float32)
            for j, v in enumerate(views):
                if widths[j] == 0:
                    continue
                z = Z[np.searchsorted(uids, band_ids[j]), j]
                new = np.where(z >= 0, 1, -1).astype(np.int8)
                old = self.labels_sorted[v, lo[j]:hi[j]]
                self.pos_count[v] += (int(np.count_nonzero(new == 1))
                                      - int(np.count_nonzero(old == 1)))
                self.labels_sorted[v, lo[j]:hi[j]] = new
        wall = time.perf_counter() - t0 + self.touch_ns * 1e-9 * total
        self.stats.tuples_reclassified += total
        self.stats.tuples_total_possible += self.n * views.size
        return lo, widths, total, wall

    def _incremental_step(self, mask: np.ndarray):
        views = np.flatnonzero(mask)
        if views.size == 0:
            return
        lo, widths, total, wall = self._relabel_bands(views)
        if self.cost_mode == "modeled":
            costs = self.S[views] * (widths / max(1, self.n))
        else:
            costs = wall * (widths / max(1, total))
        self.acc[views] += costs
        self.stats.band_fraction_last = float(widths.mean()) / max(1, self.n)
        self.stats.incremental_seconds += wall

    def _lazy_catch_up(self):
        if not self._pending:
            return
        lo, widths, total, wall = self._relabel_bands(np.arange(self.k))
        self._pending = False
        if self.cost_mode == "modeled":
            # paper §3.4 lazy waste: (N_R − N_+)/N_R per view
            n_read = np.maximum(1, self.n - lo)
            waste = np.maximum(0.0, (n_read - self.pos_count) / n_read)
            costs = self.S * waste
        else:
            costs = wall * (widths / max(1, total))
        self.acc += costs
        due = self.acc >= self.alpha * self.S
        self._reorganize_views(due)

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def all_members(self) -> np.ndarray:
        """Per-view positive-member counts, (k,) — the All Members probe
        answered for every one-vs-all view at once."""
        if self.policy == "lazy":
            self._lazy_catch_up()
        return self.pos_count.copy()

    def members(self, view: int) -> np.ndarray:
        if self.policy == "lazy":
            self._lazy_catch_up()
        return self.perm[view, self.labels_sorted[view] == 1]

    def label(self, view: int, entity_id: int) -> int:
        if self.policy == "lazy":
            self._lazy_catch_up()
        return int(self.labels_sorted[view, self.inv_perm[view, entity_id]])

    def labels_of(self, entity_id: int) -> np.ndarray:
        """All k view labels of one entity, (k,) int8 (one eps-map probe
        per view; no feature access)."""
        if self.policy == "lazy":
            self._lazy_catch_up()
        pos = self.inv_perm[:, entity_id]
        return self.labels_sorted[np.arange(self.k), pos]

    def band_fractions(self) -> np.ndarray:
        lo, hi = self._bands(np.arange(self.k))
        return (hi - lo) / max(1, self.n)

    def check_consistent(self) -> bool:
        """Golden invariant, per view: maintained labels == from-scratch
        relabel of the shared table under that view's current model."""
        if self.policy == "lazy":
            self._lazy_catch_up()
        Z = self.F @ self.W.T - self.b.astype(np.float32)
        for v in range(self.k):
            truth = np.where(Z[self.perm[v], v] >= 0, 1, -1).astype(np.int8)
            if not np.array_equal(truth, self.labels_sorted[v]):
                return False
        return True
