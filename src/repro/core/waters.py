"""Hölder low/high water machinery (paper §3.2.2, Lemma 3.1, Eq. 2).

For stored model (w_s, b_s) and current model (w_j, b_j):

    eps_high = M ||w_j − w_s||_p + (b_j − b_s)
    eps_low  = −M ||w_j − w_s||_p + (b_j − b_s)
    hw = max over rounds since s of eps_high;  lw = min of eps_low

with M = max_t ||f(t)||_q, 1/p + 1/q = 1. Any tuple with stored
eps ≥ hw is certainly positive under the current model (equality included:
z ≥ 0 labels +1); eps < lw certainly negative (at eps == lw the current
margin can be exactly 0, which labels +1); only eps ∈ [lw, hw) needs
reclassification — the partition every band search and hybrid probe uses.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.linear_model import LinearModel


def vector_norm(x: np.ndarray, p: float) -> float:
    if np.isinf(p):
        return float(np.max(np.abs(x))) if x.size else 0.0
    if p == 1.0:
        return float(np.sum(np.abs(x)))
    return float(np.sum(np.abs(x) ** p) ** (1.0 / p))


def holder_M(F: np.ndarray, q: float) -> float:
    """M = max row q-norm of the entity features."""
    if np.isinf(q):
        return float(np.max(np.abs(F)))
    if q == 1.0:
        return float(np.max(np.sum(np.abs(F), axis=1)))
    return float(np.max(np.sum(np.abs(F) ** q, axis=1) ** (1.0 / q)))


def eps_bounds(current: LinearModel, stored: LinearModel, M: float,
               p: float) -> Tuple[float, float]:
    """(eps_low, eps_high) of Lemma 3.1 for this round."""
    dw = vector_norm(current.w - stored.w, p)
    db = current.b - stored.b
    return (-M * dw + db, M * dw + db)


@dataclasses.dataclass
class Waters:
    """Running (lw, hw) per Eq. 2 — monotone between reorganizations."""
    p: float
    M: float
    lw: float = 0.0
    hw: float = 0.0

    def reset(self):
        self.lw = 0.0
        self.hw = 0.0

    def update(self, current: LinearModel, stored: LinearModel) -> Tuple[float, float]:
        lo, hi = eps_bounds(current, stored, self.M, self.p)
        self.lw = min(self.lw, lo)
        self.hw = max(self.hw, hi)
        return self.lw, self.hw
