"""Hölder low/high water machinery (paper §3.2.2, Lemma 3.1, Eq. 2).

For stored model (w_s, b_s) and current model (w_j, b_j):

    eps_high = M ||w_j − w_s||_p + (b_j − b_s)
    eps_low  = −M ||w_j − w_s||_p + (b_j − b_s)
    hw = max over rounds since s of eps_high;  lw = min of eps_low

with M = max_t ||f(t)||_q, 1/p + 1/q = 1. Any tuple with stored
eps ≥ hw is certainly positive under the current model (equality included:
z ≥ 0 labels +1); eps < lw certainly negative (at eps == lw the current
margin can be exactly 0, which labels +1); only eps ∈ [lw, hw) needs
reclassification — the partition every band search and hybrid probe uses.

The update itself lives ONCE in `core/engine.py` (`waters_update` /
`waters_bounds`, the functional core shared by every backend); this module
keeps the scalar `Waters` convenience wrapper the single-view host engine
carries, plus `holder_M` for data preparation.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.engine import row_norms, waters_bounds, waters_update
from repro.core.linear_model import LinearModel


def vector_norm(x: np.ndarray, p: float) -> float:
    """Scalar p-norm of one vector (thin wrapper over the shared
    `engine.row_norms`)."""
    return float(row_norms(np.asarray(x), p))


def holder_M(F: np.ndarray, q: float) -> float:
    """M = max row q-norm of the entity features."""
    return float(np.max(row_norms(np.asarray(F), q)))


def eps_bounds(current: LinearModel, stored: LinearModel, M: float,
               p: float) -> Tuple[float, float]:
    """(eps_low, eps_high) of Lemma 3.1 for this round."""
    lo, hi = waters_bounds(current.w, current.b, stored.w, stored.b, M, p)
    return float(lo), float(hi)


@dataclasses.dataclass
class Waters:
    """Running (lw, hw) per Eq. 2 — monotone between reorganizations.
    Scalar stateful shell over `engine.waters_update`."""
    p: float
    M: float
    lw: float = 0.0
    hw: float = 0.0

    def reset(self):
        self.lw = 0.0
        self.hw = 0.0

    def update(self, current: LinearModel, stored: LinearModel) -> Tuple[float, float]:
        lw, hw = waters_update(self.lw, self.hw, current.w, current.b,
                               stored.w, stored.b, self.M, self.p)
        self.lw, self.hw = float(lw), float(hw)
        return self.lw, self.hw
