"""Rahimi–Recht random features (paper App. B.5.3: linearized kernels).

For a shift-invariant kernel (Gaussian here), z(x) = sqrt(2/D) cos(Wx + u)
with W ~ N(0, 1/σ²) rows and u ~ U[0, 2π) satisfies z(x)ᵀz(y) ≈ K(x, y),
turning the kernel classifier back into a *linear* one — so the entire HAZY
machinery (waters, clustering, SKIING) applies unchanged. Also used by the
Fig. 12 feature-sensitivity benchmark to scale feature dimension."""
from __future__ import annotations

import numpy as np


class RandomFeatures:
    def __init__(self, d_in: int, d_out: int, *, sigma: float = 1.0, seed: int = 0):
        r = np.random.default_rng(seed)
        self.W = (r.normal(size=(d_in, d_out)) / sigma).astype(np.float32)
        self.u = (r.uniform(0, 2 * np.pi, size=d_out)).astype(np.float32)
        self.scale = np.sqrt(2.0 / d_out).astype(np.float32)

    def __call__(self, X: np.ndarray) -> np.ndarray:
        return self.scale * np.cos(X @ self.W + self.u)


def gaussian_kernel(X: np.ndarray, Y: np.ndarray, sigma: float = 1.0) -> np.ndarray:
    d2 = (np.sum(X * X, 1)[:, None] + np.sum(Y * Y, 1)[None, :] - 2 * X @ Y.T)
    return np.exp(-d2 / (2 * sigma * sigma))
