from repro.core.linear_model import (LinearModel, zero_model, sgd_step,
                                     train_batch, full_gradient_train,
                                     precision_recall)
from repro.core.engine import (EngineParams, EngineState, band_mask,
                               band_partition, band_windows, classify,
                               covering_windows, hot_buffer_window,
                               probe_partition, row_norms, skiing_charge,
                               skiing_due, waters_bounds, waters_update)
from repro.core.waters import Waters, holder_M, eps_bounds, vector_norm
from repro.core.skiing import Skiing, alpha_star, skiing_schedule, opt_cost
from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.multiview import MultiViewEngine
from repro.core.view import ClassificationView
from repro.core.multiclass import MulticlassView
from repro.core.facade import (DerivedViewFacade, EngineFacade,
                               SingleViewFacade, MultiViewFacade,
                               ShardedFacade, make_sharded_facade)
from repro.core.random_features import RandomFeatures
