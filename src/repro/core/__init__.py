from repro.core.linear_model import (LinearModel, zero_model, sgd_step,
                                     train_batch, full_gradient_train,
                                     precision_recall)
from repro.core.waters import Waters, holder_M, eps_bounds, vector_norm
from repro.core.skiing import Skiing, alpha_star, skiing_schedule, opt_cost
from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.multiview import MultiViewEngine, row_norms
from repro.core.view import ClassificationView
from repro.core.multiclass import MulticlassView
from repro.core.random_features import RandomFeatures
