"""`EngineFacade` — ONE serving interface over the three engine shells.

The relational front-end (`repro.rdbms`) plans and executes SQL against
whatever engine a view was created with; this module is the seam between
the two layers. Each facade adapts one stateful shell —

  * `SingleViewFacade`   — `ClassificationView` over `HazyEngine` (k = 1)
  * `MultiViewFacade`    — `MulticlassView` over the vectorized
                           `MultiViewEngine` (k one-vs-all views, ONE table)
  * `ShardedFacade`      — `ShardedMultiViewHazy` (device-resident shared
                           clustering order + the Pallas band kernel)

— to the same contract: batched training inserts that amortize into one
maintenance round (`insert_examples`, what the WAL group commit feeds),
tier-instrumented point reads (`point_label` / `point_labels_of` report
which §3.5.2 tier answered: waters short-circuit, hot buffer, or the
feature-table "disk" row), label-predicate scans that ride the Lemma 3.1
partition (`members`), counter reads (`counts`), and the §3.4/§3.5
cost-model inputs the planner's EXPLAIN needs (`band_info` — prospective,
never mutating — and `top_margins` with its touched-tuple count).

`top_margins` is exact under model drift: stored eps bound the current
margin to z ∈ [eps + lw, eps + hw] (Eq. 2), so the candidate set only needs
stored eps ≥ c − (hw − lw) where c is the limit-th largest stored eps —
the same slack argument as the Lemma 3.1 band, applied to ranking.

Every facade keeps a uniform `tier_hits` counter dict ("water" / "buffer" /
"disk" / "map") so the executor can expose — and the tests can assert —
that hybrid point reads never touch the feature table except on probe
misses.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import (PROBE_TIERS, band_partition, covering_windows,
                               probe_partition, waters_update)
from repro.core.multiclass import MulticlassView, sgd_all_views
from repro.core.view import ClassificationView

# "pool" = probe miss answered by a resident page of the memory-budgeted
# storage tier (repro.storage.BufferPool); "disk" = a COLD page read. For
# views without a storage tier the pool counter simply stays at zero and
# "disk" keeps meaning "touched the in-RAM feature table".
TIERS = ("water", "buffer", "pool", "disk", "map")


def _new_tier_hits() -> Dict[str, int]:
    return {t: 0 for t in TIERS}


class EngineFacade:
    """Shared contract + shared helpers; subclasses bind one engine shell."""

    num_views: int
    n: int
    d: int
    policy: str
    supports_delete = False     # footnote-2 retrain; single-view only

    def __init__(self):
        self.tier_hits = _new_tier_hits()
        # consumed only by the footnote-2 retrain; facades with
        # supports_delete=False leave it empty (unbounded growth otherwise)
        self.example_log: List[Tuple[int, float]] = []

    # -- updates -------------------------------------------------------
    def insert_examples(self, ids: Sequence[int], labels: Sequence[float]):
        raise NotImplementedError

    def force_round(self):
        """UPDATE MODEL: one maintenance round under the current model."""
        raise NotImplementedError

    def delete_examples(self, entity_id: int) -> int:
        raise NotImplementedError(
            "DELETE retrains from scratch (paper footnote 2); only "
            "single-view views support it")

    # -- reads ---------------------------------------------------------
    def label(self, entity_id: int, view: int = 0) -> int:
        raise NotImplementedError

    def point_label(self, entity_id: int, view: int = 0) -> Tuple[int, str]:
        raise NotImplementedError

    def point_labels_of(self, entity_id: int) -> Tuple[np.ndarray, List[str]]:
        raise NotImplementedError

    def labels_of(self, entity_id: int) -> np.ndarray:
        raise NotImplementedError

    def counts(self) -> np.ndarray:
        raise NotImplementedError

    def members(self, view: int = 0, positive: bool = True) -> np.ndarray:
        raise NotImplementedError

    def predict(self, entity_id: int) -> int:
        raise NotImplementedError

    def margin(self, entity_id: int, view: int = 0) -> float:
        """Current-model margin of one entity (touches its feature row)."""
        raise NotImplementedError

    def margins_of(self, ids: Sequence[int],
                   rows: Optional[np.ndarray] = None,
                   view: int = 0) -> np.ndarray:
        """Current-model margins of `ids`, as a float32 `(len(ids), 1)`
        column — the feature rows a derived view trains/labels on. `rows`
        overrides the facade's own feature lookup: the freshness scheduler
        passes the PINNED inputs of an in-flight batch so emitted features
        don't depend on when downstream consumption happens."""
        raise NotImplementedError

    # -- state the planner reads --------------------------------------
    def waters(self) -> Tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError

    def pending(self) -> np.ndarray:
        raise NotImplementedError

    def band_info(self, view: int = 0) -> Tuple[int, int, int]:
        """(band width, certainly-positive count, n) under PROSPECTIVE
        waters (what the next read would see) — pure, never mutates."""
        raise NotImplementedError

    @property
    def disk_touches(self) -> int:
        raise NotImplementedError

    def storage_stats(self) -> Optional[dict]:
        """Buffer-pool residency/counter snapshot of the view's storage
        tier (`BufferPool.stats()`), or None when the feature table is
        fully in RAM. `SHOW STORAGE` renders this."""
        return None

    def prefetcher_stats(self) -> Optional[dict]:
        """Background prefetcher counters (queue depth, enqueued, dropped),
        or None when the view has no storage tier / prefetcher."""
        eng = getattr(self, "engine", None)
        pre = getattr(getattr(eng, "store", None), "prefetcher", None)
        return pre.stats() if pre is not None else None

    def cost_stats(self) -> Optional[List[dict]]:
        """Per-view modeled-vs-measured SKIING cost rows (`SHOW COST ON`),
        or None when the engine records no cost telemetry."""
        return None

    def telemetry_snapshot(self) -> dict:
        """Collector payload for the metrics registry (`view.<name>` key):
        tier hits + storage + prefetcher + per-view cost, one locked read
        per component so the counters reconcile within themselves."""
        out = {
            "policy": self.policy,
            "num_views": int(self.num_views),
            "tier_hits": dict(self.tier_hits),
            "disk_touches": int(self.disk_touches),
        }
        st = self.storage_stats()
        if st is not None:
            out["storage"] = st
        pre = self.prefetcher_stats()
        if pre is not None:
            out["prefetcher"] = pre
        cost = self.cost_stats()
        if cost is not None:
            out["cost"] = cost
        return out

    def prefetch_band(self, view: int = 0) -> int:
        """Hand the view's PROSPECTIVE band — the entities a label scan is
        about to classify against the current model — to the storage
        tier's background prefetcher, boundary-outward (the eps order is
        the disk order, §3.5.2). Advisory: returns the number of entities
        scheduled, 0 when there is no storage tier / no prefetcher /
        nothing in the band. Never blocks on I/O."""
        return 0

    def top_margins(self, view: int = 0, limit: int = 10,
                    descending: bool = True
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Top-`limit` entities of `view` by CURRENT-model margin, exact via
        the Eq. 2 candidate slack; returns (ids, margins, tuples_touched)."""
        raise NotImplementedError

    # shared Eq.2-slack candidate selection over one stored-eps-sorted row
    def _topk_from_sorted(self, eps_sorted, perm, lw, hw, limit, descending,
                          margin_of_ids):
        n = eps_sorted.shape[0]
        limit = max(1, min(int(limit), n))
        slack = max(0.0, float(hw) - float(lw))
        if descending:
            c = eps_sorted[n - limit]
            lo = int(np.searchsorted(eps_sorted, c - slack, side="left"))
            cand = np.arange(lo, n)
        else:
            c = eps_sorted[limit - 1]
            hi = int(np.searchsorted(eps_sorted, c + slack, side="right"))
            cand = np.arange(0, hi)
        ids = np.asarray(perm)[cand]
        z = margin_of_ids(ids)
        order = np.argsort(-z if descending else z, kind="stable")[:limit]
        return ids[order], z[order], int(cand.size)


class SingleViewFacade(EngineFacade):
    """k = 1: `ClassificationView` over `HazyEngine`."""

    num_views = 1
    supports_delete = True

    def __init__(self, view: ClassificationView):
        super().__init__()
        self.view = view
        self.n, self.d = view.F.shape
        self.policy = view.engine.policy

    @property
    def engine(self):
        return self.view.engine

    def insert_examples(self, ids, labels):
        self.example_log.extend(
            (int(i), float(y)) for i, y in zip(ids, labels))
        self.view.insert_examples(list(ids), list(labels), batched=True)

    def force_round(self):
        self.view.engine.apply_model(self.view.model)

    def delete_examples(self, entity_id: int) -> int:
        """Footnote 2: drop every example of this entity and retrain
        non-incrementally (zero model -> replay the surviving stream)."""
        keep = [(i, y) for i, y in self.example_log if i != int(entity_id)]
        dropped = len(self.example_log) - len(keep)
        self.example_log = keep
        self.view.examples = [(self.view.F[i], y) for i, y in keep]
        self.view.retrain_from_scratch()
        return dropped

    def label(self, entity_id, view=0):
        return int(self.view.engine.label(int(entity_id)))

    def point_label(self, entity_id, view=0):
        eng = self.view.engine
        if self.policy == "hybrid":
            lab, how = eng.hybrid_label(int(entity_id))
        else:
            lab, how = eng.label(int(entity_id)), "map"
        self.tier_hits[how] += 1
        return int(lab), how

    def point_labels_of(self, entity_id):
        lab, how = self.point_label(entity_id)
        return np.array([lab], np.int8), [how]

    def labels_of(self, entity_id):
        return np.array([self.label(entity_id)], np.int8)

    def counts(self):
        return np.array([self.view.engine.all_members()], np.int64)

    def members(self, view=0, positive=True):
        eng = self.view.engine
        pos = eng.members()          # catches up under lazy/hybrid
        if positive:
            return pos
        return eng.perm[eng.labels_sorted == -1]

    def predict(self, entity_id):
        return self.point_label(entity_id)[0]

    def margin(self, entity_id, view=0):
        m = self.view.model
        return float(self.view.F[int(entity_id)] @ m.w - m.b)

    def margins_of(self, ids, rows=None, view=0):
        m = self.view.model
        if rows is None:
            X = self.view.F[np.asarray(ids, np.int64)]
        else:
            X = np.asarray(rows, np.float32)
        return (X @ m.w - m.b).astype(np.float32).reshape(len(X), 1)

    def waters(self):
        w = self.view.engine.waters
        return (np.array([w.lw], np.float64), np.array([w.hw], np.float64))

    def pending(self):
        return np.array([self.view.engine._pending is not None])

    def _prospective_waters(self):
        """Eq. 2 waters covering any PENDING model too — pure, not
        committed. Under lazy/hybrid a deferred model has not updated the
        engine's waters yet; every bound derived from stored eps (band
        width, top-k candidate slack) must use these, not the stale pair."""
        eng = self.view.engine
        lw, hw = eng.waters.lw, eng.waters.hw
        if eng._pending is not None:
            lw, hw = waters_update(lw, hw, eng.model.w, eng.model.b,
                                   eng.stored.w, eng.stored.b, eng.M,
                                   eng.waters.p)
        return float(lw), float(hw)

    def band_info(self, view=0):
        eng = self.view.engine
        lw, hw = self._prospective_waters()
        lo, hi = band_partition(eng.eps_sorted, lw, hw)
        return int(hi - lo), int(self.n - hi), self.n

    @property
    def disk_touches(self):
        return int(self.view.engine.disk_touches)

    def storage_stats(self):
        store = getattr(self.view.engine, "store", None)
        return store.stats() if store is not None else None

    def prefetch_band(self, view=0):
        eng = self.view.engine
        pre = getattr(getattr(eng, "store", None), "prefetcher", None)
        if pre is None:
            return 0
        lw, hw = self._prospective_waters()
        lo, hi = band_partition(eng.eps_sorted, lw, hw)
        if hi <= lo:
            return 0
        # boundary-outward: smallest |eps| first — the rows the scan's
        # per-entity probes will miss soonest
        band = np.arange(lo, hi)
        ids = eng.perm[band[np.argsort(np.abs(eng.eps_sorted[lo:hi]),
                                       kind="stable")]]
        pre.enqueue(ids, evict=True)
        return int(ids.size)

    def top_margins(self, view=0, limit=10, descending=True):
        eng = self.view.engine
        m = self.view.model
        lw, hw = self._prospective_waters()   # pending drift widens slack
        return self._topk_from_sorted(
            eng.eps_sorted, eng.perm, lw, hw, limit, descending,
            lambda ids: np.asarray(self.view.F[ids] @ m.w - m.b, np.float64))

    def cost_stats(self):
        eng = self.view.engine
        row = eng.cost.snapshot(0)
        row.update(view=0, policy=self.policy, cost_mode=eng.cost_mode,
                   S_model=float(eng.skiing.S), alpha=float(eng.skiing.alpha),
                   acc=float(eng.skiing.a),
                   reorgs_modeled=int(eng.skiing.reorgs))
        return [row]


class DerivedViewFacade(SingleViewFacade):
    """A classification view whose feature table is another view's margin
    column (views-over-views). The wrapped `ClassificationView` is an
    ordinary hazy k=1 view over an `(n, 1)` float32 matrix; this subclass
    adds the two hooks the freshness scheduler drives:

      * `insert_examples(..., features=)` trains on inputs PINNED at the
        parent's emission time, so the model trajectory is independent of
        when the refresh runs (it also skips the footnote-2 example log —
        DELETE cannot replay through a derived chain and is rejected
        upstream);
      * `refresh_features(F_new)` re-points the view at the parent's
        current margin column (a full pull — cheap at `(n, 1)`)."""

    supports_delete = False

    def __init__(self, view: ClassificationView, source: str):
        super().__init__(view)
        self.source = source               # the parent view's name

    def insert_examples(self, ids, labels, features=None):
        self.view.insert_examples(list(ids), list(labels), batched=True,
                                  features=features)

    def delete_examples(self, entity_id: int) -> int:
        raise NotImplementedError(
            "DELETE cannot replay through a derived view")

    def refresh_features(self, F_new: np.ndarray) -> None:
        self.view.refresh_features(np.asarray(F_new, np.float32))
        self.n, self.d = self.view.F.shape


class MultiViewFacade(EngineFacade):
    """k one-vs-all views: `MulticlassView` over `MultiViewEngine`."""

    def __init__(self, mc: MulticlassView):
        super().__init__()
        assert mc.vectorized, "MultiViewFacade requires the vectorized engine"
        self.mc = mc
        self.num_views = mc.k
        self.n, self.d = mc.F.shape
        self.policy = mc.engine.policy

    @property
    def engine(self):
        return self.mc.engine

    def insert_examples(self, ids, labels):
        # no example_log here: only the footnote-2 retrain (single-view
        # DELETE) consumes it, and k-view facades don't support that —
        # logging would just grow memory forever on a long insert stream
        self.mc.insert_examples([int(i) for i in ids],
                                [int(c) for c in labels])

    def force_round(self):
        self.mc.engine.apply_models(self.mc.W, self.mc.b)

    def label(self, entity_id, view=0):
        return int(self.mc.engine.label(int(view), int(entity_id)))

    def point_label(self, entity_id, view=0):
        eng = self.mc.engine
        if self.policy == "hybrid":
            lab, how = eng.hybrid_label(int(view), int(entity_id))
        else:
            lab, how = eng.label(int(view), int(entity_id)), "map"
        self.tier_hits[how] += 1
        return int(lab), how

    def point_labels_of(self, entity_id):
        eng = self.mc.engine
        if self.policy == "hybrid":
            labels, codes = eng.hybrid_labels_of(int(entity_id))
            hows = [PROBE_TIERS[c] for c in codes]
        else:
            labels = eng.labels_of(int(entity_id))
            hows = ["map"] * self.num_views
        for h in hows:
            self.tier_hits[h] += 1
        return labels, hows

    def labels_of(self, entity_id):
        return self.mc.engine.labels_of(int(entity_id))

    def counts(self):
        return self.mc.engine.all_members().astype(np.int64)

    def members(self, view=0, positive=True):
        eng = self.mc.engine
        pos = eng.members(int(view))     # per-view lazy catch-up
        if positive:
            return pos
        return eng.perm[view, eng.labels_sorted[view] == -1]

    def predict(self, entity_id):
        if self.policy == "hybrid":
            return int(self.mc.predict_via_views(int(entity_id)))
        return int(self.mc.predict(int(entity_id)))

    def margin(self, entity_id, view=0):
        return float(self.mc.F[int(entity_id)] @ self.mc.W[view]
                     - self.mc.b[view])

    def waters(self):
        eng = self.mc.engine
        return eng.lw.copy(), eng.hw.copy()

    def pending(self):
        return self.mc.engine.pending.copy()

    def _prospective_waters(self, v: int):
        """Per-view Eq. 2 waters covering any pending model — pure (see
        `SingleViewFacade._prospective_waters`)."""
        eng = self.mc.engine
        lw, hw = float(eng.lw[v]), float(eng.hw[v])
        if eng._waters_stale[v]:
            lw, hw = waters_update(lw, hw, eng.W[v], eng.b[v],
                                   eng.W_stored[v], eng.b_stored[v],
                                   eng.M, eng.p)
        return float(lw), float(hw)

    def band_info(self, view=0):
        eng = self.mc.engine
        v = int(view)
        lw, hw = self._prospective_waters(v)
        lo, hi = band_partition(eng.eps_sorted[v], lw, hw)
        return int(hi - lo), int(self.n - hi), self.n

    @property
    def disk_touches(self):
        return int(self.mc.engine.disk_touches)

    def storage_stats(self):
        store = getattr(self.mc.engine, "store", None)
        return store.stats() if store is not None else None

    def prefetch_band(self, view=0):
        eng = self.mc.engine
        pre = getattr(getattr(eng, "store", None), "prefetcher", None)
        if pre is None:
            return 0
        v = int(view)
        lw, hw = self._prospective_waters(v)
        lo, hi = band_partition(eng.eps_sorted[v], lw, hw)
        if hi <= lo:
            return 0
        band = np.arange(lo, hi)
        ids = eng.perm[v, band[np.argsort(np.abs(eng.eps_sorted[v, lo:hi]),
                                          kind="stable")]]
        pre.enqueue(ids, evict=True)
        return int(ids.size)

    def top_margins(self, view=0, limit=10, descending=True):
        eng = self.mc.engine
        v = int(view)
        lw, hw = self._prospective_waters(v)  # pending drift widens slack
        return self._topk_from_sorted(
            eng.eps_sorted[v], eng.perm[v], lw, hw, limit, descending,
            lambda ids: np.asarray(
                self.mc.F[ids] @ eng.W[v] - eng.b[v], np.float64))

    def cost_stats(self):
        eng = self.mc.engine
        out = []
        for v in range(self.num_views):
            row = eng.cost.snapshot(v)
            row.update(view=v, policy=self.policy, cost_mode=eng.cost_mode,
                       S_model=float(eng.S[v]), alpha=float(eng.alpha),
                       acc=float(eng.acc[v]),
                       reorgs_modeled=int(eng.reorg_counts[v]),
                       lazy_waste=float(eng.lazy_waste[v]))
            out.append(row)
        return out


class ShardedFacade(EngineFacade):
    """`ShardedMultiViewHazy`: device-resident shared clustering order,
    union-band relabels through the Pallas kernel, host-side stacked SGD
    (the same math as `MulticlassView._sgd_all_views`)."""

    policy = "eager"

    def __init__(self, driver, features: np.ndarray, *, lr: float = 0.1,
                 l2: float = 1e-4):
        super().__init__()
        self.driver = driver
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.num_views = driver.k
        self.lr, self.l2 = lr, l2
        self.W = np.zeros((driver.k, self.d), np.float32)
        self.b = np.zeros(driver.k, np.float64)
        self.state = driver.init_state(self.F)
        self._disk = 0

    def insert_examples(self, ids, labels):
        for i, c in zip(ids, labels):
            self.W, self.b = sgd_all_views(self.W, self.b, self.F[int(i)],
                                           int(c), lr=self.lr, l2=self.l2)
        self.state = self.driver.apply_models(self.state, self.W, self.b)

    def force_round(self):
        self.state = self.driver.apply_models(self.state, self.W, self.b)

    def point_labels_of(self, entity_id):
        labels, resolved = self.driver.hybrid_labels_of(
            self.state, self.W, self.b, int(entity_id))
        hows = ["water" if r else "disk" for r in resolved]
        if not bool(np.asarray(resolved).all()):
            self._disk += 1            # ONE shared feature-row gather
        for h in hows:
            self.tier_hits[h] += 1
        return labels, hows

    def point_label(self, entity_id, view=0):
        labels, hows = self.point_labels_of(entity_id)
        return int(labels[int(view)]), hows[int(view)]

    def labels_of(self, entity_id):
        gids = np.asarray(self.state.gids)
        pos = int(np.flatnonzero(gids == int(entity_id))[0])
        return np.asarray(self.state.labels)[:, pos].astype(np.int8)

    def label(self, entity_id, view=0):
        return int(self.labels_of(entity_id)[int(view)])

    def counts(self):
        return self.driver.all_members(self.state).astype(np.int64)

    def members(self, view=0, positive=True):
        gids = np.asarray(self.state.gids)
        lab = np.asarray(self.state.labels)[int(view)]
        want = 1 if positive else -1
        return np.sort(gids[lab == want])

    def predict(self, entity_id):
        labels, _ = self.point_labels_of(entity_id)
        pos = np.flatnonzero(labels == 1)
        if pos.size == 1:
            return int(pos[0])
        f = self.F[int(entity_id)]
        cand = pos if pos.size > 1 else np.arange(self.num_views)
        z = self.W[cand] @ f - self.b[cand].astype(np.float32)
        return int(cand[np.argmax(z)])

    def margin(self, entity_id, view=0):
        return float(self.F[int(entity_id)] @ self.W[view] - self.b[view])

    def waters(self):
        return self.driver.lw.copy(), self.driver.hw.copy()

    def pending(self):
        return np.zeros(self.num_views, bool)      # eager: nothing deferred

    def band_info(self, view=0):
        eps = np.asarray(self.state.eps)           # (k, n), SHARED order
        lw = self.driver.lw.astype(np.float32)
        hw = self.driver.hw.astype(np.float32)
        _, _, width = covering_windows(eps, lw, hw)
        v = int(view)
        # certainly-positive == probe tier +1 (THE Lemma 3.1 partition)
        certain_pos = int(np.count_nonzero(
            probe_partition(eps[v], lw[v], hw[v]) == 1))
        return int(width[v]), certain_pos, self.n

    @property
    def disk_touches(self):
        return self._disk

    def top_margins(self, view=0, limit=10, descending=True):
        v = int(view)
        eps = np.asarray(self.state.eps)[v]        # stored-model margins
        gids = np.asarray(self.state.gids)
        order = np.argsort(eps, kind="stable")
        return self._topk_from_sorted(
            eps[order], gids[order], self.driver.lw[v], self.driver.hw[v],
            limit, descending,
            lambda ids: np.asarray(
                self.F[ids] @ self.W[v] - self.b[v], np.float64))


def make_sharded_facade(features: np.ndarray, k: int, *, p: float = 2.0,
                        q: float = 2.0, lr: float = 0.1, l2: float = 1e-4,
                        alpha: float = 1.0, cap_frac: float = 0.5,
                        mesh=None) -> ShardedFacade:
    """Build a `ShardedFacade` on `mesh` (default: single-host (1, 1))."""
    from repro.core.sharded import ShardedMultiViewHazy
    from repro.core.waters import holder_M
    if mesh is None:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((1, 1))
    F = np.ascontiguousarray(features, np.float32)
    driver = ShardedMultiViewHazy(
        mesh=mesh, n=F.shape[0], d=F.shape[1], k=int(k),
        M=holder_M(F, q), p=p, alpha=alpha, cap_frac=cap_frac)
    return ShardedFacade(driver, F, lr=lr, l2=l2)
