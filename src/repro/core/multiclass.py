"""Multiclass classification via one-versus-all binary views (paper App.
B.5.4 / C.3).

Two execution paths share one API:

  * vectorized (default) — a single `MultiViewEngine` holds all k views
    over ONE shared feature table with a stacked (k, d) model matrix; an
    insert updates every model with one rank-1 update and one maintenance
    round reclassifies the union eps band with one matmul.
  * legacy (`vectorized=False`) — the seed's literal reproduction: k
    independent `HazyEngine`s looped over in Python, each with its own
    copy of the feature table. Kept as the baseline the benchmarks and
    equivalence tests compare against.

`insert_examples` is the batched fast path: SGD runs example-by-example
(same model trajectory as k calls to `insert_example`) but view maintenance
is amortized to ONE round per batch — the views are exact w.r.t. the
batch-final model, which is all any read after the batch can observe.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.linear_model import LinearModel, sgd_step, zero_model
from repro.core.multiview import MultiViewEngine


class MulticlassView:
    def __init__(self, features: np.ndarray, num_classes: int, *,
                 engine: str = "hazy", policy: str = "eager", lr: float = 0.1,
                 l2: float = 1e-4, alpha: float = 1.0,
                 p: float = float("inf"), q: float = 1.0,
                 cost_mode: str = "measured", touch_ns: float = 0.0,
                 vectorized: bool = True):
        self.F = np.asarray(features, np.float32)
        self.k = num_classes
        self.lr, self.l2 = lr, l2
        self.vectorized = bool(vectorized) and engine == "hazy"
        if self.vectorized:
            self.W = np.zeros((num_classes, self.F.shape[1]), np.float32)
            self.b = np.zeros(num_classes, np.float64)
            self.engine = MultiViewEngine(self.F, num_classes, p=p, q=q,
                                          alpha=alpha, policy=policy,
                                          cost_mode=cost_mode,
                                          touch_ns=touch_ns)
            self.engines = None
        else:
            self._models = [zero_model(self.F.shape[1])
                            for _ in range(num_classes)]
            if engine == "hazy":
                self.engines = [HazyEngine(self.F, p=p, q=q, alpha=alpha,
                                           policy=policy, cost_mode=cost_mode,
                                           touch_ns=touch_ns)
                                for _ in range(num_classes)]
            else:
                self.engines = [NaiveEngine(self.F, policy=policy,
                                            touch_ns=touch_ns)
                                for _ in range(num_classes)]
            self.engine = None

    # ------------------------------------------------------------------
    # Model state
    # ------------------------------------------------------------------

    @property
    def models(self) -> List[LinearModel]:
        if self.vectorized:
            return [LinearModel(self.W[c].copy(), float(self.b[c]))
                    for c in range(self.k)]
        return self._models

    def _sgd_all_views(self, f: np.ndarray, cls: int):
        """One training example against all k one-vs-all models at once —
        the stacked twin of k sequential `sgd_step` calls (bit-for-bit:
        same f32 accumulation order per view, bias kept in f64)."""
        y = np.where(np.arange(self.k) == cls, 1.0, -1.0)
        z = self.W @ f - self.b.astype(np.float32)       # (k,) f32 margins
        g = np.where(y * z.astype(np.float64) < 1.0, -y, 0.0)
        self.W = self.W * (1.0 - self.lr * self.l2)
        self.W -= (self.lr * g).astype(np.float32)[:, None] * f[None, :]
        self.b = self.b - self.lr * (-g)

    # ------------------------------------------------------------------
    # Updates
    # ------------------------------------------------------------------

    def insert_example(self, entity_id: int, cls: int):
        f = self.F[entity_id]
        if self.vectorized:
            self._sgd_all_views(f, cls)
            self.engine.apply_models(self.W, self.b)
            return
        for c in range(self.k):
            y = 1.0 if c == cls else -1.0
            self._models[c] = sgd_step(self._models[c], f, y, lr=self.lr,
                                       l2=self.l2, method="svm")
            self.engines[c].apply_model(self._models[c])

    def insert_examples(self, entity_ids: Sequence[int], classes: Sequence[int]):
        """Batched fast path: per-example SGD (identical model trajectory),
        ONE maintenance round for the whole batch."""
        if not self.vectorized:
            for i, c in zip(entity_ids, classes):
                self.insert_example(int(i), int(c))
            return
        for i, c in zip(entity_ids, classes):
            self._sgd_all_views(self.F[int(i)], int(c))
        self.engine.apply_models(self.W, self.b)

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def predict(self, entity_id: int) -> int:
        """argmax over per-class margins (ties to one-vs-all labels)."""
        f = self.F[entity_id]
        if self.vectorized:
            return int(np.argmax(self.W @ f - self.b.astype(np.float32)))
        scores = [f @ m.w - m.b for m in self._models]
        return int(np.argmax(scores))

    def predict_batch(self, entity_ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(entity_ids, np.int64)
        if self.vectorized:
            scores = self.F[ids] @ self.W.T - self.b.astype(np.float32)
        else:
            W = np.stack([m.w for m in self._models])
            b = np.array([m.b for m in self._models], np.float32)
            scores = self.F[ids] @ W.T - b
        return np.argmax(scores, axis=1)

    def class_counts(self) -> List[int]:
        if self.vectorized:
            return [int(c) for c in self.engine.all_members()]
        return [e.all_members() for e in self.engines]

    def view_labels(self, entity_id: int) -> np.ndarray:
        """±1 membership of one entity in each of the k views."""
        if self.vectorized:
            return self.engine.labels_of(entity_id)
        return np.array([e.label(entity_id) for e in self.engines], np.int8)

    def check_consistent(self) -> bool:
        if self.vectorized:
            return self.engine.check_consistent()
        for e in self.engines:
            if isinstance(e, HazyEngine):
                if not e.check_consistent():
                    return False
            else:
                e.all_members()   # lazy naive: force the on-read relabel
                truth = np.where(e.F @ e.model.w - e.model.b >= 0,
                                 1, -1).astype(np.int8)
                if not np.array_equal(truth, e.labels):
                    return False
        return True
