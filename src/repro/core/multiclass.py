"""Multiclass classification via one-versus-all binary views (paper App.
B.5.4 / C.3).

Two execution paths share one API:

  * vectorized (default) — a single `MultiViewEngine` holds all k views
    over ONE shared feature table with a stacked (k, d) model matrix; an
    insert updates every model with one rank-1 update and one maintenance
    round reclassifies the union eps band with one matmul.
  * legacy (`vectorized=False`) — the seed's literal reproduction: k
    independent `HazyEngine`s looped over in Python, each with its own
    copy of the feature table. Kept as the baseline the benchmarks and
    equivalence tests compare against.

`insert_examples` is the batched fast path: SGD runs example-by-example
(same model trajectory as k calls to `insert_example`) but view maintenance
is amortized to ONE round per batch — the views are exact w.r.t. the
batch-final model, which is all any read after the batch can observe.

`policy="hybrid"` (paper §3.5.2) defers maintenance like lazy and serves
single-entity reads through the per-view eps-map/waters/hot-buffer tier;
`predict_via_views` turns those per-view hybrid reads into a multiclass
argmax without a full-table scan — in the common one-positive-view case
without touching the feature table at all.

Architecture (PR 3): this view is a thin training + read shell; the
engines it drives (`MultiViewEngine`, the legacy `HazyEngine` loop, and
`ShardedMultiViewHazy` on device) are themselves stateful shells over the
single functional core in `core/engine.py`, so all execution paths share
one implementation of the maintenance rules.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.linear_model import LinearModel, sgd_step, zero_model
from repro.core.multiview import MultiViewEngine


def sgd_all_views(W: np.ndarray, b: np.ndarray, f: np.ndarray, cls: int, *,
                  lr: float, l2: float):
    """One training example against all k one-vs-all hinge models at once —
    the stacked twin of k sequential `sgd_step` calls (bit-for-bit: same
    f32 accumulation order per view, bias kept in f64). THE one
    implementation: `MulticlassView` and `ShardedFacade` both train
    through it, so their model trajectories can never drift apart."""
    k = W.shape[0]
    y = np.where(np.arange(k) == cls, 1.0, -1.0)
    z = W @ f - b.astype(np.float32)          # (k,) f32 margins
    g = np.where(y * z.astype(np.float64) < 1.0, -y, 0.0)
    W = W * (1.0 - lr * l2)
    W -= (lr * g).astype(np.float32)[:, None] * f[None, :]
    return W, b - lr * (-g)


class MulticlassView:
    def __init__(self, features: np.ndarray, num_classes: int, *,
                 engine: str = "hazy", policy: str = "eager", lr: float = 0.1,
                 l2: float = 1e-4, alpha: float = 1.0,
                 p: float = float("inf"), q: float = 1.0,
                 cost_mode: str = "measured", touch_ns: float = 0.0,
                 buffer_frac: float = 0.0, vectorized: bool = True,
                 store=None):
        self.F = np.asarray(features, np.float32)
        self.k = num_classes
        self.lr, self.l2 = lr, l2
        if policy == "hybrid" and not buffer_frac:
            buffer_frac = 0.01            # paper §4.2 default: 1% in memory
        self.vectorized = bool(vectorized) and engine == "hazy"
        if store is not None and not self.vectorized:
            raise ValueError("the storage tier (store=) requires the "
                             "vectorized MultiViewEngine")
        if self.vectorized:
            self.W = np.zeros((num_classes, self.F.shape[1]), np.float32)
            self.b = np.zeros(num_classes, np.float64)
            self.engine = MultiViewEngine(self.F, num_classes, p=p, q=q,
                                          alpha=alpha, policy=policy,
                                          cost_mode=cost_mode,
                                          touch_ns=touch_ns,
                                          buffer_frac=buffer_frac,
                                          store=store)
            self.engines = None
        else:
            self._models = [zero_model(self.F.shape[1])
                            for _ in range(num_classes)]
            if engine == "hazy":
                self.engines = [HazyEngine(self.F, p=p, q=q, alpha=alpha,
                                           policy=policy, cost_mode=cost_mode,
                                           touch_ns=touch_ns,
                                           buffer_frac=buffer_frac)
                                for _ in range(num_classes)]
            else:
                # NaiveEngine has no hybrid tier; lazy is the closest policy
                # (it too classifies on read against the current model).
                self.engines = [NaiveEngine(
                    self.F, policy="lazy" if policy == "hybrid" else policy,
                    touch_ns=touch_ns) for _ in range(num_classes)]
            self.engine = None

    # ------------------------------------------------------------------
    # Model state
    # ------------------------------------------------------------------

    @property
    def models(self) -> List[LinearModel]:
        if self.vectorized:
            return [LinearModel(self.W[c].copy(), float(self.b[c]))
                    for c in range(self.k)]
        return self._models

    def _sgd_all_views(self, f: np.ndarray, cls: int):
        self.W, self.b = sgd_all_views(self.W, self.b, f, cls,
                                       lr=self.lr, l2=self.l2)

    # ------------------------------------------------------------------
    # Updates
    # ------------------------------------------------------------------

    def insert_example(self, entity_id: int, cls: int):
        f = self.F[entity_id]
        if self.vectorized:
            self._sgd_all_views(f, cls)
            self.engine.apply_models(self.W, self.b)
            return
        for c in range(self.k):
            y = 1.0 if c == cls else -1.0
            self._models[c] = sgd_step(self._models[c], f, y, lr=self.lr,
                                       l2=self.l2, method="svm")
            self.engines[c].apply_model(self._models[c])

    def insert_examples(self, entity_ids: Sequence[int], classes: Sequence[int]):
        """Batched fast path: per-example SGD (identical model trajectory),
        ONE maintenance round for the whole batch."""
        if not self.vectorized:
            for i, c in zip(entity_ids, classes):
                self.insert_example(int(i), int(c))
            return
        for i, c in zip(entity_ids, classes):
            self._sgd_all_views(self.F[int(i)], int(c))
        self.engine.apply_models(self.W, self.b)

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def predict(self, entity_id: int) -> int:
        """argmax over per-class margins (ties to one-vs-all labels)."""
        f = self.F[entity_id]
        if self.vectorized:
            return int(np.argmax(self.W @ f - self.b.astype(np.float32)))
        scores = [f @ m.w - m.b for m in self._models]
        return int(np.argmax(scores))

    def predict_batch(self, entity_ids: Sequence[int]) -> np.ndarray:
        ids = np.asarray(entity_ids, np.int64)
        if self.vectorized:
            scores = self.F[ids] @ self.W.T - self.b.astype(np.float32)
        else:
            W = np.stack([m.w for m in self._models])
            b = np.array([m.b for m in self._models], np.float32)
            scores = self.F[ids] @ W.T - b
        return np.argmax(scores, axis=1)

    def class_counts(self) -> List[int]:
        if self.vectorized:
            return [int(c) for c in self.engine.all_members()]
        return [e.all_members() for e in self.engines]

    def view_labels(self, entity_id: int) -> np.ndarray:
        """±1 membership of one entity in each of the k views."""
        if self.vectorized:
            return self.engine.labels_of(entity_id)
        return np.array([e.label(entity_id) for e in self.engines], np.int8)

    def hybrid_view_labels(self, entity_id: int) -> np.ndarray:
        """±1 membership per view via the §3.5.2 hybrid read tier (exact
        under every policy; no catch-up, at most one feature-table touch)."""
        if self.vectorized:
            return self.engine.hybrid_labels_of(entity_id)[0]
        return np.array([e.hybrid_label(entity_id)[0]
                         if isinstance(e, HazyEngine) else e.label(entity_id)
                         for e in self.engines], np.int8)

    def predict_via_views(self, entity_id: int) -> int:
        """Multiclass argmax resolved from the per-view hybrid reads, never
        a full-table scan. Exactly one positive one-vs-all view — the common
        case on a trained model — decides the class with NO feature read
        (its margin is the only non-negative one, hence the argmax); ties
        (>1) rank only the positive views' margins, and the no-positive case
        falls back to all k margins from one feature row. Agrees with
        `predict` on every input."""
        labels = self.hybrid_view_labels(entity_id)
        pos = np.flatnonzero(labels == 1)
        if pos.size == 1:
            return int(pos[0])
        f = self.F[entity_id]
        if self.vectorized:
            W, b = self.W, self.b
        else:
            W = np.stack([m.w for m in self._models])
            b = np.array([m.b for m in self._models], np.float64)
        cand = pos if pos.size > 1 else np.arange(self.k)
        scores = W[cand] @ f - b[cand].astype(np.float32)
        return int(cand[np.argmax(scores)])

    def check_consistent(self) -> bool:
        if self.vectorized:
            return self.engine.check_consistent()
        for e in self.engines:
            if isinstance(e, HazyEngine):
                if not e.check_consistent():
                    return False
            else:
                e.all_members()   # lazy naive: force the on-read relabel
                truth = np.where(e.F @ e.model.w - e.model.b >= 0,
                                 1, -1).astype(np.int8)
                if not np.array_equal(truth, e.labels):
                    return False
        return True
