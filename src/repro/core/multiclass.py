"""Multiclass classification via sequential one-versus-all binary views
(paper App. B.5.4 / C.3). Each class keeps its own HAZY-maintained view;
an update touches only the views whose model changed."""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.hazy import HazyEngine, NaiveEngine
from repro.core.linear_model import LinearModel, sgd_step, zero_model


class MulticlassView:
    def __init__(self, features: np.ndarray, num_classes: int, *,
                 engine: str = "hazy", policy: str = "eager", lr: float = 0.1,
                 l2: float = 1e-4, alpha: float = 1.0,
                 p: float = float("inf"), q: float = 1.0,
                 cost_mode: str = "measured"):
        self.F = np.asarray(features, np.float32)
        self.k = num_classes
        self.lr, self.l2 = lr, l2
        self.models = [zero_model(self.F.shape[1]) for _ in range(num_classes)]
        if engine == "hazy":
            self.engines = [HazyEngine(self.F, p=p, q=q, alpha=alpha,
                                       policy=policy, cost_mode=cost_mode)
                            for _ in range(num_classes)]
        else:
            self.engines = [NaiveEngine(self.F, policy=policy)
                            for _ in range(num_classes)]

    def insert_example(self, entity_id: int, cls: int):
        f = self.F[entity_id]
        for c in range(self.k):
            y = 1.0 if c == cls else -1.0
            self.models[c] = sgd_step(self.models[c], f, y, lr=self.lr,
                                      l2=self.l2, method="svm")
            self.engines[c].apply_model(self.models[c])

    def predict(self, entity_id: int) -> int:
        """argmax over per-class margins (ties to one-vs-all labels)."""
        f = self.F[entity_id]
        scores = [f @ m.w - m.b for m in self.models]
        return int(np.argmax(scores))

    def class_counts(self) -> List[int]:
        return [e.all_members() for e in self.engines]
