"""Single-source functional core of the HAZY maintenance algorithm (§3.2–3.5).

The paper's incremental-maintenance algorithm used to be implemented three
separate times — host `HazyEngine` (core/hazy.py), vectorized
`MultiViewEngine` (core/multiview.py) and the jax `ShardedMultiViewHazy`
(core/sharded.py) — and the copies drifted apart in exactly the Lemma 3.1
partition they must agree on. Following Bismarck's unified-architecture
argument (one shared aggregation core under many statistical views) and
F-IVM's shared-state view maintenance, every algorithm *rule* now lives here
exactly once, backend-parameterized by `xp` (numpy on the host, jax.numpy
under jit/shard_map):

Layer 1 — primitives (imported by hazy.py / multiview.py / sharded.py /
waters.py / skiing.py; no other module may reimplement them):

  * `band_partition` / `band_windows` / `band_mask` / `probe_partition` —
    THE Lemma 3.1 partition: eps ≥ hw certainly positive (equality included,
    z ≥ 0 labels +1), eps < lw certainly negative, band [lw, hw) must be
    reclassified. Sorted-row (searchsorted), elementwise-mask and
    point-probe forms of the same inequalities.
  * `waters_bounds` / `waters_update` — Hölder waters, Eq. 2 (running
    min/max of ±M·‖ΔW‖_p + Δb), vectorized over stacked (k, d) models and
    valid for a single (d,) model.
  * `skiing_charge` / `skiing_due` — the SKIING strategy (§3.2.1, Fig. 7):
    accumulate incremental cost, reorganize when it reaches α·S.
  * `classify` — sign labels (z ≥ 0 → +1), `row_norms` — the one p-norm,
    `hot_buffer_window` — the §3.5.2 hot-buffer window around the zero
    boundary, `covering_windows` — per-view covering windows of the band in
    a SHARED clustering order (the device-side form the Pallas
    `multiview_band_reclassify` kernel consumes).

Layer 2 — `EngineState` pytree + pure steps (`apply_model`, `reorganize`,
`catch_up`, `hybrid_probe`): the executable specification of one
maintenance round over k views sharing ONE feature table, identical under
numpy and jax.numpy (jit-able: static shapes, full-mask band merges,
modeled costs). The stateful shells (`HazyEngine` as the k = 1
specialization with a materialized `F_sorted`, `MultiViewEngine` with exact
dynamic band slices and measured wall-time costs) keep their storage
layouts and cost accounting but route every decision through Layer 1; the
property tests drive the same insert stream through a shell and the jitted
Layer 2 steps and assert identical labels, counts, waters and reorg
schedules.

Modeled costs here are dimensionless (width/n, band fraction, lazy waste):
every modeled charge in the shells is S_v · (dimensionless quantity) and
the SKIING threshold is α · S_v, so S_v cancels and the reorg schedule is
invariant to it — Layer 2 therefore charges the dimensionless quantity
against the threshold α directly.
"""
from __future__ import annotations

import math

from typing import NamedTuple, Tuple

import numpy as np

# hybrid tier codes returned by the §3.5.2 probes (index into HYBRID_TIERS).
# The pure algorithm knows three tiers: waters short-circuit, hot buffer,
# and "the feature row was touched" (disk). When a shell backs that touch
# with a real `repro.storage.BufferPool`, the touch subdivides physically
# into a pool hit (page resident in the budgeted pool) vs a cold disk
# read — code TIER_POOL, name PROBE_TIERS[3]. The functional core never
# models storage, so HYBRID_TIERS stays 3-long.
HYBRID_TIERS = ("water", "buffer", "disk")
TIER_WATER, TIER_BUFFER, TIER_DISK = 0, 1, 2
TIER_POOL = 3
PROBE_TIERS = HYBRID_TIERS + ("pool",)


# ---------------------------------------------------------------------------
# Layer 1 — primitives (the single source of every algorithm rule)
# ---------------------------------------------------------------------------

def row_norms(X, p: float, xp=np):
    """p-norm over the LAST axis: (..., d) -> (...,). The one norm behind the
    Hölder waters (Eq. 2) on every backend; dtype-preserving."""
    if X.shape[-1] == 0:
        return xp.zeros(X.shape[:-1], X.dtype)
    A = xp.abs(X)
    if math.isinf(p):           # p is a Python scalar: stdlib, not host numpy
        return xp.max(A, axis=-1)
    if p == 1.0:
        return xp.sum(A, axis=-1)
    return xp.sum(A ** p, axis=-1) ** (1.0 / p)


def classify(z, xp=np):
    """Sign labels: z ≥ 0 → +1 else −1, int8 (z == 0 labels +1 everywhere —
    the convention every band search and probe below shares)."""
    return xp.where(z >= 0, 1, -1).astype(xp.int8)


def band_partition(eps_sorted, lw, hw, xp=np) -> Tuple:
    """THE Lemma 3.1 partition on one eps-sorted row: returns [lo, hi) such
    that positions ≥ hi are certainly positive (eps ≥ hw, equality
    included), positions < lo certainly negative (eps < lw), and [lo, hi)
    is the band reclassification must touch. `probe_partition` is the same
    partition for a point probe — they must never disagree (PR 2's
    exact-water-mark bug)."""
    lo = xp.searchsorted(eps_sorted, lw, side="left")
    hi = xp.searchsorted(eps_sorted, hw, side="left")
    return lo, hi


def band_windows(eps_sorted, lw, hw, xp=np) -> Tuple:
    """`band_partition` per view: (k, n) sorted rows + (k,) waters ->
    (k,) lo, (k,) hi. k is static, so the loop unrolls under jit."""
    pairs = [band_partition(eps_sorted[v], lw[v], hw[v], xp=xp)
             for v in range(eps_sorted.shape[0])]
    lo = xp.stack([xp.asarray(a) for a, _ in pairs])
    hi = xp.stack([xp.asarray(b) for _, b in pairs])
    return lo, hi


def band_mask(eps, lw, hw):
    """Elementwise Lemma 3.1 band membership: True iff eps ∈ [lw, hw) (the
    rows that must be reclassified), for eps rows in ANY order — the form
    the sharded shared-order steps use."""
    return (eps >= lw) & (eps < hw)


def probe_partition(eps, lw, hw, xp=np):
    """Point-probe form of the partition: +1 (eps ≥ hw), −1 (eps < lw),
    0 (in the band — the caller must classify against the current model)."""
    return xp.where(eps >= hw, 1, xp.where(eps < lw, -1, 0)).astype(xp.int8)


def waters_bounds(W, b, W_stored, b_stored, M: float, p: float, xp=np):
    """One round of Lemma 3.1 bounds: (−M‖ΔW‖_p + Δb, M‖ΔW‖_p + Δb).
    W may be a single (d,) model or stacked (k, d) models."""
    dw = row_norms(W - W_stored, p, xp=xp)
    db = b - b_stored
    return -M * dw + db, M * dw + db


def waters_update(lw, hw, W, b, W_stored, b_stored, M: float, p: float,
                  xp=np):
    """Eq. 2 running waters: lw never rises, hw never falls between
    reorganizations (monotone, idempotent). THE waters update."""
    lo, hi = waters_bounds(W, b, W_stored, b_stored, M, p, xp=xp)
    return xp.minimum(lw, lo), xp.maximum(hw, hi)


def skiing_charge(acc, cost):
    """THE SKIING charge rule: accumulate one incremental-step cost."""
    return acc + cost


def skiing_due(acc, alpha, S):
    """SKIING trigger (Fig. 7): reorganize when accumulated incremental
    cost has reached α·S. Scalar or per-view arrays."""
    return acc >= alpha * S


def hot_buffer_window(eps_sorted, cap: int, xp=np) -> Tuple:
    """[lo, hi) positions of the §3.5.2 hot buffer: `cap` eps-sorted slots
    centered on the zero boundary (the tuples most likely to flip). Shared
    by the single-view engine, the per-view windows of `MultiViewEngine`
    and the Layer 2 pure state."""
    n = eps_sorted.shape[0]
    cap = max(1, min(int(cap), n))
    boundary = xp.searchsorted(eps_sorted, 0.0, side="left")
    lo = xp.maximum(0, boundary - cap // 2)
    hi = xp.minimum(n, lo + cap)
    return lo, hi


def covering_windows(eps, lw, hw, xp=np) -> Tuple:
    """Per-view covering windows of the Lemma 3.1 band in a SHARED row
    order.

    eps: (k, n) per-view stored-model margins of the rows of ONE shared
    scratch table (each row of eps follows the table's shared clustering
    order, NOT sorted per view). Returns ((k,) start, (k,) end, (k,) true
    band width) where [start_v, end_v) is the tightest contiguous window
    containing every row of view v's band — relabeling a covering superset
    is exact because relabeling recomputes sign(w_v·f − b_v). This is the
    window form `multiview_band_reclassify` (Pallas) consumes; a view with
    an empty band gets the empty window [0, 0)."""
    k, n = eps.shape
    mask = band_mask(eps, lw[:, None], hw[:, None])
    width = xp.sum(mask, axis=1).astype(xp.int32)
    first = xp.argmax(mask, axis=1).astype(xp.int32)
    last = (n - 1 - xp.argmax(mask[:, ::-1], axis=1)).astype(xp.int32)
    has = width > 0
    start = xp.where(has, first, 0).astype(xp.int32)
    end = xp.where(has, last + 1, 0).astype(xp.int32)
    return start, end, width


def argsort_stable(x, xp=np, axis=-1):
    """Stable argsort on both backends (ties keep row order, so identical
    eps give identical clustering permutations everywhere)."""
    if xp is np:
        return np.argsort(x, axis=axis, kind="stable")
    return xp.argsort(x, axis=axis)        # jnp argsort is stable by default


# ---------------------------------------------------------------------------
# Layer 2 — EngineState pytree + pure steps (the executable specification)
# ---------------------------------------------------------------------------

class EngineParams(NamedTuple):
    """Static hyper-parameters of the maintenance algorithm (close over
    them with functools.partial before jit)."""
    M: float                 # Hölder constant max_t ‖f(t)‖_q
    p: float                 # waters norm (1/p + 1/q = 1)
    alpha: float             # SKIING threshold multiplier
    buffer_cap: int = 0      # §3.5.2 hot-buffer rows per view (0 = off)


class EngineState(NamedTuple):
    """k one-vs-all views over ONE shared feature table, as a pytree.

    F stays in fixed entity order for the lifetime of the state (the
    multi-view shared-table layout: reorganization re-sorts the per-view
    scratch rows, never the table). All per-view state is rows of stacked
    arrays — no Python objects, so the whole state jits and shards."""
    F: np.ndarray            # (n, d) f32 — shared table, fixed entity order
    W: np.ndarray            # (k, d) f32 current models
    b: np.ndarray            # (k,) current biases
    W_stored: np.ndarray     # (k, d) f32 models the clustering was built on
    b_stored: np.ndarray     # (k,)
    lw: np.ndarray           # (k,) low waters
    hw: np.ndarray           # (k,) high waters
    eps_sorted: np.ndarray   # (k, n) f32 stored-model eps, sorted per view
    perm: np.ndarray         # (k, n) position -> entity id
    inv_perm: np.ndarray     # (k, n) entity id -> position (the eps-map)
    labels: np.ndarray       # (k, n) int8, aligned to eps_sorted
    pos_count: np.ndarray    # (k,) number of +1 labels per view
    pending: np.ndarray      # (k,) bool — view defers maintenance
    acc: np.ndarray          # (k,) SKIING accumulators (dimensionless)
    buffer_lo: np.ndarray    # (k,) hot-buffer window start positions
    buffer_hi: np.ndarray    # (k,) hot-buffer window end positions


def make_params(F, *, p: float = 2.0, q: float = 2.0, alpha: float = 1.0,
                buffer_frac: float = 0.0) -> EngineParams:
    F = np.asarray(F, np.float32)
    cap = max(1, int(buffer_frac * F.shape[0])) if buffer_frac else 0
    return EngineParams(M=float(np.max(row_norms(F, q))), p=p, alpha=alpha,
                        buffer_cap=cap)


def init_state(F, k: int, params: EngineParams) -> EngineState:
    """Fresh state under the zero model, all k views clustered (built on the
    host with numpy; jax users tree-map `jnp.asarray` over the result)."""
    F = np.ascontiguousarray(F, np.float32)
    n, d = F.shape
    zk = np.zeros(k, np.float64)
    state = EngineState(
        F=F, W=np.zeros((k, d), np.float32), b=zk.copy(),
        W_stored=np.zeros((k, d), np.float32), b_stored=zk.copy(),
        lw=zk.copy(), hw=zk.copy(),
        eps_sorted=np.zeros((k, n), np.float32),
        perm=np.zeros((k, n), np.int64), inv_perm=np.zeros((k, n), np.int64),
        labels=np.zeros((k, n), np.int8), pos_count=np.zeros(k, np.int64),
        pending=np.zeros(k, bool), acc=zk.copy(),
        buffer_lo=np.zeros(k, np.int64), buffer_hi=np.zeros(k, np.int64),
    )
    return reorganize(state, np.ones(k, bool), params, xp=np)


def reorganize(state: EngineState, due, params: EngineParams,
               xp=np) -> EngineState:
    """Re-sort the scratch rows of every view in `due` from one shared
    `F @ W.T` product; reset their stored models, waters, SKIING
    accumulators and pending flags. F itself never moves."""
    k, n = state.eps_sorted.shape
    b32 = state.b.astype(xp.float32)
    Z = (state.F @ state.W.T - b32).T                    # (k, n) fresh eps
    order = argsort_stable(Z, xp=xp, axis=1)
    eps_new = xp.take_along_axis(Z, order, axis=1)
    inv_new = argsort_stable(order, xp=xp, axis=1)       # inverse permutation
    labels_new = classify(eps_new, xp=xp)
    pos_new = xp.sum(labels_new == 1, axis=1)
    due = xp.asarray(due)
    dr = due[:, None]
    out = state._replace(
        eps_sorted=xp.where(dr, eps_new, state.eps_sorted),
        perm=xp.where(dr, order, state.perm),
        inv_perm=xp.where(dr, inv_new, state.inv_perm),
        labels=xp.where(dr, labels_new, state.labels),
        pos_count=xp.where(due, pos_new, state.pos_count),
        W_stored=xp.where(dr, state.W, state.W_stored),
        b_stored=xp.where(due, state.b, state.b_stored),
        lw=xp.where(due, 0.0, state.lw), hw=xp.where(due, 0.0, state.hw),
        pending=state.pending & ~due,
        acc=xp.where(due, 0.0, state.acc),
    )
    if params.buffer_cap:
        wins = [hot_buffer_window(eps_new[v], params.buffer_cap, xp=xp)
                for v in range(k)]
        blo = xp.stack([xp.asarray(a) for a, _ in wins])
        bhi = xp.stack([xp.asarray(b) for _, b in wins])
        out = out._replace(buffer_lo=xp.where(due, blo, state.buffer_lo),
                           buffer_hi=xp.where(due, bhi, state.buffer_hi))
    return out


def _relabel(state: EngineState, sel, params: EngineParams, xp=np):
    """Waters update + banded reclassify of the views in `sel` (the shared
    incremental step). Returns (state', lo, widths)."""
    k, n = state.eps_sorted.shape
    lw, hw = waters_update(state.lw, state.hw, state.W, state.b,
                           state.W_stored, state.b_stored,
                           params.M, params.p, xp=xp)
    lw = xp.where(sel, lw, state.lw)
    hw = xp.where(sel, hw, state.hw)
    lo, hi = band_windows(state.eps_sorted, lw, hw, xp=xp)
    pos = xp.arange(n)[None, :]
    in_band = (pos >= lo[:, None]) & (pos < hi[:, None]) & sel[:, None]
    b32 = state.b.astype(xp.float32)
    Z = (state.F @ state.W.T - b32).T                    # (k, n) entity order
    Zs = xp.take_along_axis(Z, state.perm, axis=1)       # per-view eps order
    labels = xp.where(in_band, classify(Zs, xp=xp), state.labels)
    pos_count = xp.sum(labels == 1, axis=1)
    widths = xp.where(sel, hi - lo, 0)
    return (state._replace(lw=lw, hw=hw, labels=labels, pos_count=pos_count),
            lo, widths)


def apply_model(state: EngineState, W, b, params: EngineParams,
                policy: str = "eager", xp=np):
    """One maintenance round: the k views must reflect (W, b). Eager pays
    the banded reclassify now (SKIING check-first, Fig. 7); lazy defers
    everything to `catch_up`; hybrid defers the relabel but keeps the
    eps-map tight (SKIING charged with the expected probe miss rate).
    Returns (state', info) with info = {reorged (k,) bool, widths (k,)}."""
    k, n = state.eps_sorted.shape
    state = state._replace(W=xp.asarray(W, xp.float32), b=xp.asarray(b))
    zeros = xp.zeros(k, bool)
    if policy == "eager":
        due = skiing_due(state.acc, params.alpha, 1.0)
        state = reorganize(state, due, params, xp=xp)
        state, _, widths = _relabel(state, ~due, params, xp=xp)
        state = state._replace(acc=skiing_charge(state.acc, widths / n))
        return state, {"reorged": due, "widths": widths}
    state = state._replace(pending=xp.ones(k, bool))
    if policy == "hybrid":
        lw, hw = waters_update(state.lw, state.hw, state.W, state.b,
                               state.W_stored, state.b_stored,
                               params.M, params.p, xp=xp)
        state = state._replace(lw=lw, hw=hw)
        lo, hi = band_windows(state.eps_sorted, lw, hw, xp=xp)
        state = state._replace(
            acc=skiing_charge(state.acc, (hi - lo) / n))
        due = skiing_due(state.acc, params.alpha, 1.0)
        state = reorganize(state, due, params, xp=xp)
        return state, {"reorged": due, "widths": hi - lo}
    return state, {"reorged": zeros, "widths": xp.zeros(k, xp.int32)}


def catch_up(state: EngineState, touch, params: EngineParams, xp=np):
    """Catch up the pending subset of the touched views (per-view laziness:
    untouched views keep deferring). Charges the §3.4 lazy waste
    (N_R − N_+)/N_R per caught-up view and reorganizes the ones SKIING says
    are due. Returns (state', info)."""
    k, n = state.eps_sorted.shape
    todo = state.pending & xp.asarray(touch)
    state, lo, widths = _relabel(state, todo, params, xp=xp)
    n_read = xp.maximum(1, n - lo)
    waste = xp.where(todo,
                     xp.maximum(0.0, (n_read - state.pos_count) / n_read),
                     0.0)
    acc = skiing_charge(state.acc, waste)
    due = skiing_due(acc, params.alpha, 1.0) & todo
    state = reorganize(state._replace(pending=state.pending & ~todo, acc=acc),
                       due, params, xp=xp)
    return state, {"reorged": due, "caught_up": todo, "waste": waste,
                   "widths": widths}


def hybrid_probe(state: EngineState, entity_id, params: EngineParams, xp=np):
    """§3.5.2/Fig. 8 single-entity read across all k views: eps-map lookup →
    waters short-circuit (`probe_partition`) → hot buffer → one shared
    F-row touch for every view the waters cannot resolve. Exact under every
    policy: a pending model only needs the monotone waters update, never a
    catch-up relabel. Returns (state', (k,) int8 labels, (k,) int8 tiers)."""
    lw, hw = waters_update(state.lw, state.hw, state.W, state.b,
                           state.W_stored, state.b_stored,
                           params.M, params.p, xp=xp)
    state = state._replace(lw=lw, hw=hw)
    posn = state.inv_perm[:, entity_id]
    e = xp.take_along_axis(state.eps_sorted, posn[:, None], axis=1)[:, 0]
    t = probe_partition(e, lw, hw, xp=xp)
    z = state.W @ state.F[entity_id] - state.b.astype(xp.float32)
    lab = xp.where(t != 0, t, classify(z, xp=xp)).astype(xp.int8)
    if params.buffer_cap:
        in_buf = (state.buffer_lo <= posn) & (posn < state.buffer_hi)
    else:
        in_buf = xp.zeros(t.shape, bool)
    tier = xp.where(t != 0, TIER_WATER,
                    xp.where(in_buf, TIER_BUFFER, TIER_DISK)).astype(xp.int8)
    return state, lab, tier
