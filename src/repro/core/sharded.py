"""Pod-scale sharded HAZY view maintenance (jit/shard_map twin of hazy.py).

Layout (DESIGN.md §2): entity rows sharded over ("pod","data"), feature dim
over ("model",). All three maintenance steps are expressible with *zero
cross-shard data movement* except a psum of per-shard eps partials over the
model axis and scalar metric reductions:

  * naive_update_step  — full eps recompute + relabel (the paper's naive
                         eager baseline; memory-bound roofline anchor)
  * hazy_update_step   — banded reclassify with a static capacity window
                         (the paper's incremental step; bytes ∝ band)
  * reorganize_step    — per-shard argsort + row gather (paper's re-sort;
                         embarrassingly parallel — see DESIGN.md on why
                         shard-local clustering preserves correctness)

Static band capacity: jit needs static shapes, so the band is processed
through a `cap`-row window per shard (cap = n_shard * cap_frac). The host
wrapper checks the true width and triggers reorganization if the window
overflows — SKIING would usually have reorganized long before that.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ShardedHazyState(NamedTuple):
    F: jax.Array            # (n, d) bf16 — rows in shard-local eps-sorted order
    eps: jax.Array          # (n,) f32  — stored-model eps (the eps-map)
    labels: jax.Array       # (n,) int8
    perm: jax.Array         # (n,) int32 — shard-local positions -> entity ids
    w_stored: jax.Array     # (d,) f32
    b_stored: jax.Array     # () f32
    lw: jax.Array           # () f32
    hw: jax.Array           # () f32


def state_specs(n: int, d: int, mesh: Mesh, dtype=jnp.bfloat16):
    """Abstract ShardedHazyState with shardings (dry-run inputs)."""
    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rows = P(row_axes)
    rows_feat = P(row_axes, "model" if "model" in mesh.axis_names else None)
    feat = P("model" if "model" in mesh.axis_names else None)

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, spec))

    return ShardedHazyState(
        F=sds((n, d), dtype, rows_feat),
        eps=sds((n,), jnp.float32, rows),
        labels=sds((n,), jnp.int8, rows),
        perm=sds((n,), jnp.int32, rows),
        w_stored=sds((d,), jnp.float32, feat),
        b_stored=sds((), jnp.float32, P()),
        lw=sds((), jnp.float32, P()),
        hw=sds((), jnp.float32, P()),
    )


def _row_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _specs(mesh: Mesh):
    rows = _row_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return P(rows, model), P(rows), P(model)


# ---------------------------------------------------------------------------
# Steps (built per mesh; call under `with mesh:` or pass to jit/lower)
# ---------------------------------------------------------------------------

def make_naive_update_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        labels = jnp.where(z >= 0, 1, -1).astype(jnp.int8)
        return labels

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=pr)

    def step(state: ShardedHazyState, w, b):
        labels = fn(*state, w, b)
        return state._replace(labels=labels)

    return step


def make_hazy_update_step(mesh: Mesh, n: int, cap_frac: float = 1 / 64):
    """Banded incremental step. Returns (state', width_total)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    rows = _row_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in rows])) if rows else 1
    n_local = n // n_shards
    cap = max(64, int(n_local * cap_frac))

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        # Hölder waters were updated on the host (scalars); locate the band.
        lo = jnp.searchsorted(eps, lw, side="left").astype(jnp.int32)
        hi = jnp.searchsorted(eps, hw, side="right").astype(jnp.int32)
        width = hi - lo
        start = jnp.clip(lo, 0, jnp.maximum(0, eps.shape[0] - cap))
        Fb = jax.lax.dynamic_slice(F, (start, 0), (cap, F.shape[1]))
        z = jnp.einsum("nd,d->n", Fb.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        new = jnp.where(z >= 0, 1, -1).astype(jnp.int8)
        old = jax.lax.dynamic_slice(labels, (start,), (cap,))
        idx = jnp.arange(cap) + start
        in_band = (idx >= lo) & (idx < hi)
        merged = jnp.where(in_band, new, old)
        labels = jax.lax.dynamic_update_slice(labels, merged, (start,))
        wsum, wmax = width, width
        for ax in rows:
            wsum = jax.lax.psum(wsum, ax)
            wmax = jax.lax.pmax(wmax, ax)
        return labels, wsum, wmax

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pr, P(), P()))

    def step(state: ShardedHazyState, w, b):
        labels, wsum, wmax = fn(*state, w, b)
        return state._replace(labels=labels), wsum, wmax

    return step, cap


def make_reorganize_step(mesh: Mesh):
    """Per-shard sort by fresh eps + row gather; resets the stored model.

    No collectives beyond the model-axis psum of eps partials: the
    clustering is shard-local by design (DESIGN.md §2)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        order = jnp.argsort(z)
        eps_new = z[order]
        F_new = jnp.take(F, order, axis=0)
        perm_new = jnp.take(perm, order)
        labels_new = jnp.where(eps_new >= 0, 1, -1).astype(jnp.int8)
        return F_new, eps_new, labels_new, perm_new

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pf, pr, pr, pr))

    def step(state: ShardedHazyState, w, b):
        F, eps, labels, perm = fn(*state, w, b)
        return ShardedHazyState(F, eps, labels, perm, w, b,
                                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    return step


def make_all_members_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    rows = _row_axes(mesh)

    def local(labels):
        c = jnp.sum((labels == 1).astype(jnp.int32))
        for ax in rows:
            c = jax.lax.psum(c, ax)
        return c

    fn = jax.shard_map(local, mesh=mesh, in_specs=(pr,), out_specs=P())
    return lambda state: fn(state.labels)


# ---------------------------------------------------------------------------
# Host-side driver (real runs; the Waters/Skiing control loop stays host-side
# exactly as the paper's strategy is driven outside the storage engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedHazy:
    mesh: Mesh
    n: int
    d: int
    M: float
    p: float = 2.0
    alpha: float = 1.0
    cap_frac: float = 1 / 64

    def __post_init__(self):
        self._naive = jax.jit(make_naive_update_step(self.mesh))
        hz, self.cap = make_hazy_update_step(self.mesh, self.n, self.cap_frac)
        self._hazy = jax.jit(hz)
        self._reorg = jax.jit(make_reorganize_step(self.mesh))
        self._count = jax.jit(make_all_members_step(self.mesh))
        from repro.core.skiing import Skiing
        self.skiing = Skiing(S=1.0, alpha=self.alpha)
        self.lw = 0.0
        self.hw = 0.0

    def init_state(self, F: np.ndarray) -> ShardedHazyState:
        specs = state_specs(self.n, self.d, self.mesh, dtype=jnp.bfloat16)
        put = lambda x, s: jax.device_put(x, s.sharding)
        state = ShardedHazyState(
            F=put(F.astype(np.float32), specs.F),
            eps=put(np.zeros(self.n, np.float32), specs.eps),
            labels=put(np.ones(self.n, np.int8), specs.labels),
            perm=put(np.arange(self.n, dtype=np.int32), specs.perm),
            w_stored=put(np.zeros(self.d, np.float32), specs.w_stored),
            b_stored=put(np.zeros((), np.float32), specs.b_stored),
            lw=put(np.zeros((), np.float32), specs.lw),
            hw=put(np.zeros((), np.float32), specs.hw),
        )
        return self._reorg(state, jnp.zeros(self.d, jnp.float32), jnp.zeros((), jnp.float32))

    def apply_model(self, state: ShardedHazyState, w, b) -> ShardedHazyState:
        """One eager round under SKIING (modeled costs: bytes ∝ rows touched)."""
        from repro.core.waters import vector_norm
        if self.skiing.should_reorganize():
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        dw = vector_norm(np.asarray(w) - np.asarray(state.w_stored), self.p)
        db = float(b) - float(state.b_stored)
        self.lw = min(self.lw, -self.M * dw + db)
        self.hw = max(self.hw, self.M * dw + db)
        state, wsum, wmax = self._hazy(
            state._replace(lw=jnp.float32(self.lw), hw=jnp.float32(self.hw)), w, b)
        if int(wmax) > self.cap:
            # capacity window overflowed on some shard: fall back to reorg
            # (correctness preserved; SKIING would reorganize soon anyway)
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        self.skiing.record_incremental(int(wsum) / self.n)  # modeled cost
        return state

    def all_members(self, state) -> int:
        return int(self._count(state))
