"""Pod-scale sharded HAZY view maintenance (jit/shard_map twin of hazy.py).

Layout (DESIGN.md §2): entity rows sharded over ("pod","data"), feature dim
over ("model",). All three maintenance steps are expressible with *zero
cross-shard data movement* except a psum of per-shard eps partials over the
model axis and scalar metric reductions:

  * naive_update_step  — full eps recompute + relabel (the paper's naive
                         eager baseline; memory-bound roofline anchor)
  * hazy_update_step   — banded reclassify with a static capacity window
                         (the paper's incremental step; bytes ∝ band)
  * reorganize_step    — per-shard argsort + row gather (paper's re-sort;
                         embarrassingly parallel — see DESIGN.md on why
                         shard-local clustering preserves correctness)

The multi-view twin additionally exposes the §3.5.2 hybrid read pair:
`make_multiview_hybrid_probe_step` (eps-map lookup + waters short-circuit —
a pure (k,) compare, zero feature bytes) and
`make_multiview_entity_margin_step` (ONE shared feature-row gather that
classifies every view the waters cannot resolve).

Static band capacity: jit needs static shapes, so the band is processed
through a `cap`-row window per shard (cap = n_shard * cap_frac). The host
wrapper checks the true width and triggers reorganization if the window
overflows — SKIING would usually have reorganized long before that.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                   # jax >= 0.6 exports it at top level
    shard_map = jax.shard_map
except AttributeError:                 # pinned 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map


class ShardedHazyState(NamedTuple):
    F: jax.Array            # (n, d) bf16 — rows in shard-local eps-sorted order
    eps: jax.Array          # (n,) f32  — stored-model eps (the eps-map)
    labels: jax.Array       # (n,) int8
    perm: jax.Array         # (n,) int32 — shard-local positions -> entity ids
    w_stored: jax.Array     # (d,) f32
    b_stored: jax.Array     # () f32
    lw: jax.Array           # () f32
    hw: jax.Array           # () f32


def state_specs(n: int, d: int, mesh: Mesh, dtype=jnp.bfloat16):
    """Abstract ShardedHazyState with shardings (dry-run inputs)."""
    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rows = P(row_axes)
    rows_feat = P(row_axes, "model" if "model" in mesh.axis_names else None)
    feat = P("model" if "model" in mesh.axis_names else None)

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, spec))

    return ShardedHazyState(
        F=sds((n, d), dtype, rows_feat),
        eps=sds((n,), jnp.float32, rows),
        labels=sds((n,), jnp.int8, rows),
        perm=sds((n,), jnp.int32, rows),
        w_stored=sds((d,), jnp.float32, feat),
        b_stored=sds((), jnp.float32, P()),
        lw=sds((), jnp.float32, P()),
        hw=sds((), jnp.float32, P()),
    )


def _row_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _specs(mesh: Mesh):
    rows = _row_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return P(rows, model), P(rows), P(model)


# ---------------------------------------------------------------------------
# Steps (built per mesh; call under `with mesh:` or pass to jit/lower)
# ---------------------------------------------------------------------------

def make_naive_update_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        labels = jnp.where(z >= 0, 1, -1).astype(jnp.int8)
        return labels

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=pr)

    def step(state: ShardedHazyState, w, b):
        labels = fn(*state, w, b)
        return state._replace(labels=labels)

    return step


def make_hazy_update_step(mesh: Mesh, n: int, cap_frac: float = 1 / 64):
    """Banded incremental step. Returns (state', width_total)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    rows = _row_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in rows])) if rows else 1
    n_local = n // n_shards
    cap = max(64, int(n_local * cap_frac))

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        # Hölder waters were updated on the host (scalars); locate the band
        # [lw, hw) — the same Lemma 3.1 partition the hybrid probe uses
        # (eps ≥ hw certainly positive incl. equality, eps < lw negative).
        lo = jnp.searchsorted(eps, lw, side="left").astype(jnp.int32)
        hi = jnp.searchsorted(eps, hw, side="left").astype(jnp.int32)
        width = hi - lo
        start = jnp.clip(lo, 0, jnp.maximum(0, eps.shape[0] - cap))
        Fb = jax.lax.dynamic_slice(F, (start, 0), (cap, F.shape[1]))
        z = jnp.einsum("nd,d->n", Fb.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        new = jnp.where(z >= 0, 1, -1).astype(jnp.int8)
        old = jax.lax.dynamic_slice(labels, (start,), (cap,))
        idx = jnp.arange(cap) + start
        in_band = (idx >= lo) & (idx < hi)
        merged = jnp.where(in_band, new, old)
        labels = jax.lax.dynamic_update_slice(labels, merged, (start,))
        wsum, wmax = width, width
        for ax in rows:
            wsum = jax.lax.psum(wsum, ax)
            wmax = jax.lax.pmax(wmax, ax)
        return labels, wsum, wmax

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pr, P(), P()))

    def step(state: ShardedHazyState, w, b):
        labels, wsum, wmax = fn(*state, w, b)
        return state._replace(labels=labels), wsum, wmax

    return step, cap


def make_reorganize_step(mesh: Mesh):
    """Per-shard sort by fresh eps + row gather; resets the stored model.

    No collectives beyond the model-axis psum of eps partials: the
    clustering is shard-local by design (DESIGN.md §2)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        order = jnp.argsort(z)
        eps_new = z[order]
        F_new = jnp.take(F, order, axis=0)
        perm_new = jnp.take(perm, order)
        labels_new = jnp.where(eps_new >= 0, 1, -1).astype(jnp.int8)
        return F_new, eps_new, labels_new, perm_new

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pf, pr, pr, pr))

    def step(state: ShardedHazyState, w, b):
        F, eps, labels, perm = fn(*state, w, b)
        return ShardedHazyState(F, eps, labels, perm, w, b,
                                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    return step


def make_all_members_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    rows = _row_axes(mesh)

    def local(labels):
        c = jnp.sum((labels == 1).astype(jnp.int32))
        for ax in rows:
            c = jax.lax.psum(c, ax)
        return c

    fn = shard_map(local, mesh=mesh, in_specs=(pr,), out_specs=P())
    return lambda state: fn(state.labels)


# ---------------------------------------------------------------------------
# Host-side driver (real runs; the Waters/Skiing control loop stays host-side
# exactly as the paper's strategy is driven outside the storage engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedHazy:
    mesh: Mesh
    n: int
    d: int
    M: float
    p: float = 2.0
    alpha: float = 1.0
    cap_frac: float = 1 / 64

    def __post_init__(self):
        self._naive = jax.jit(make_naive_update_step(self.mesh))
        hz, self.cap = make_hazy_update_step(self.mesh, self.n, self.cap_frac)
        self._hazy = jax.jit(hz)
        self._reorg = jax.jit(make_reorganize_step(self.mesh))
        self._count = jax.jit(make_all_members_step(self.mesh))
        from repro.core.skiing import Skiing
        self.skiing = Skiing(S=1.0, alpha=self.alpha)
        self.lw = 0.0
        self.hw = 0.0

    def init_state(self, F: np.ndarray) -> ShardedHazyState:
        specs = state_specs(self.n, self.d, self.mesh, dtype=jnp.bfloat16)
        put = lambda x, s: jax.device_put(x, s.sharding)
        state = ShardedHazyState(
            F=put(F.astype(np.float32), specs.F),
            eps=put(np.zeros(self.n, np.float32), specs.eps),
            labels=put(np.ones(self.n, np.int8), specs.labels),
            perm=put(np.arange(self.n, dtype=np.int32), specs.perm),
            w_stored=put(np.zeros(self.d, np.float32), specs.w_stored),
            b_stored=put(np.zeros((), np.float32), specs.b_stored),
            lw=put(np.zeros((), np.float32), specs.lw),
            hw=put(np.zeros((), np.float32), specs.hw),
        )
        return self._reorg(state, jnp.zeros(self.d, jnp.float32), jnp.zeros((), jnp.float32))

    def apply_model(self, state: ShardedHazyState, w, b) -> ShardedHazyState:
        """One eager round under SKIING (modeled costs: bytes ∝ rows touched)."""
        from repro.core.waters import vector_norm
        if self.skiing.should_reorganize():
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        dw = vector_norm(np.asarray(w) - np.asarray(state.w_stored), self.p)
        db = float(b) - float(state.b_stored)
        self.lw = min(self.lw, -self.M * dw + db)
        self.hw = max(self.hw, self.M * dw + db)
        state, wsum, wmax = self._hazy(
            state._replace(lw=jnp.float32(self.lw), hw=jnp.float32(self.hw)), w, b)
        if int(wmax) > self.cap:
            # capacity window overflowed on some shard: fall back to reorg
            # (correctness preserved; SKIING would reorganize soon anyway)
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        self.skiing.record_incremental(int(wsum) / self.n)  # modeled cost
        return state

    def all_members(self, state) -> int:
        return int(self._count(state))


# ---------------------------------------------------------------------------
# Multi-view twin: k one-vs-all views over ONE shared, never-gathered table.
# The view index is a vmapped axis — one program maintains all k views.
# ---------------------------------------------------------------------------

class ShardedMultiViewState(NamedTuple):
    """k views sharing one feature table.

    F stays in FIXED entity order for the lifetime of the state (it is the
    single shared copy — reorganization re-sorts the per-view scratch
    arrays, never the table). Per-view state carries a leading k axis and
    is replicated over the model axis, sharded over rows."""
    F: jax.Array            # (n, d) — fixed entity order, shared by all views
    ids: jax.Array          # (n,) i32 global entity id per row
    eps: jax.Array          # (k, n) f32 — per-view eps, shard-locally sorted
    labels: jax.Array       # (k, n) int8 aligned to eps order
    perm: jax.Array         # (k, n) i32 shard-LOCAL row index per position
    gids: jax.Array         # (k, n) i32 global entity id per position
    W_stored: jax.Array     # (k, d) f32
    b_stored: jax.Array     # (k,) f32
    lw: jax.Array           # (k,) f32
    hw: jax.Array           # (k,) f32


def multiview_state_specs(n: int, d: int, k: int, mesh: Mesh,
                          dtype=jnp.bfloat16):
    row_axes = _row_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    rows = P(row_axes)
    krows = P(None, row_axes)

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, spec))

    return ShardedMultiViewState(
        F=sds((n, d), dtype, P(row_axes, model)),
        ids=sds((n,), jnp.int32, rows),
        eps=sds((k, n), jnp.float32, krows),
        labels=sds((k, n), jnp.int8, krows),
        perm=sds((k, n), jnp.int32, krows),
        gids=sds((k, n), jnp.int32, krows),
        W_stored=sds((k, d), jnp.float32, P(None, model)),
        b_stored=sds((k,), jnp.float32, P()),
        lw=sds((k,), jnp.float32, P()),
        hw=sds((k,), jnp.float32, P()),
    )


def _mv_specs(mesh: Mesh):
    rows = _row_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return (P(rows, model), P(rows), P(None, rows), P(None, model))


def make_multiview_hazy_update_step(mesh: Mesh, n: int, k: int,
                                    cap_frac: float = 1 / 64):
    """Banded incremental step for all k views in one launch; the view axis
    is vmapped so XLA fuses the k band matmuls over the shared table.
    Returns (state', widths_sum (k,), widths_max (k,))."""
    pf, pr, pkr, pkw = _mv_specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    rows = _row_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in rows])) if rows else 1
    n_local = n // n_shards
    cap = max(64, int(n_local * cap_frac))

    def local(F, ids, eps, labels, perm, gids, W_s, b_s, lw, hw, W, b):
        Ff = F.astype(jnp.float32)

        def one_view(eps_v, labels_v, perm_v, lw_v, hw_v, w_v, b_v):
            lo = jnp.searchsorted(eps_v, lw_v, side="left").astype(jnp.int32)
            hi = jnp.searchsorted(eps_v, hw_v, side="left").astype(jnp.int32)
            width = hi - lo
            start = jnp.clip(lo, 0, jnp.maximum(0, eps_v.shape[0] - cap))
            idx = jax.lax.dynamic_slice(perm_v, (start,), (cap,))
            Fb = jnp.take(Ff, idx, axis=0)     # gather cap rows of the ONE table
            z = jnp.einsum("nd,d->n", Fb, w_v)
            if model_ax:
                z = jax.lax.psum(z, model_ax)
            z = z - b_v
            new = jnp.where(z >= 0, 1, -1).astype(jnp.int8)
            old = jax.lax.dynamic_slice(labels_v, (start,), (cap,))
            pos = jnp.arange(cap) + start
            in_band = (pos >= lo) & (pos < hi)
            merged = jnp.where(in_band, new, old)
            return jax.lax.dynamic_update_slice(labels_v, merged, (start,)), width

        labels, widths = jax.vmap(one_view)(eps, labels, perm, lw, hw, W, b)
        wsum, wmax = widths, widths
        for ax in rows:
            wsum = jax.lax.psum(wsum, ax)
            wmax = jax.lax.pmax(wmax, ax)
        return labels, wsum, wmax

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, pkr, pkr, pkw, P(), P(), P(), pkw, P()),
        out_specs=(pkr, P(), P()))

    def step(state: ShardedMultiViewState, W, b):
        labels, wsum, wmax = fn(*state, W, b)
        return state._replace(labels=labels), wsum, wmax

    return step, cap


def make_multiview_reorganize_step(mesh: Mesh):
    """Re-sort every view's scratch arrays from ONE `F @ W.T` product.

    Because the table itself is never permuted, reorganization does NOT
    gather F rows at all — it is strictly cheaper than the single-view
    reorganize (whose dominant cost is the row gather), and still needs no
    collectives beyond the model-axis eps psum."""
    pf, pr, pkr, pkw = _mv_specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, ids, eps, labels, perm, gids, W_s, b_s, lw, hw, W, b):
        Z = jnp.einsum("nd,kd->kn", F.astype(jnp.float32), W)
        if model_ax:
            Z = jax.lax.psum(Z, model_ax)
        Z = Z - b[:, None]
        order = jnp.argsort(Z, axis=1).astype(jnp.int32)
        eps_new = jnp.take_along_axis(Z, order, axis=1)
        gids_new = jax.vmap(lambda o: jnp.take(ids, o))(order)
        labels_new = jnp.where(eps_new >= 0, 1, -1).astype(jnp.int8)
        return eps_new, labels_new, order, gids_new

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, pkr, pkr, pkw, P(), P(), P(), pkw, P()),
        out_specs=(pkr, pkr, pkr, pkr))

    def step(state: ShardedMultiViewState, W, b):
        eps, labels, perm, gids = fn(*state, W, b)
        k = b.shape[0]
        zeros = jnp.zeros((k,), jnp.float32)
        return ShardedMultiViewState(state.F, state.ids, eps, labels, perm,
                                     gids, W, b, zeros, zeros)

    return step


def make_multiview_hybrid_probe_step(mesh: Mesh):
    """§3.5.2 waters short-circuit for ONE entity across all k views with
    ZERO feature-table bytes: the entity's stored eps per view comes from
    the eps-map (masked row-shard sum over `gids`, psum'd), and the waters
    test itself is a pure (k,) compare vmapped over views. Returns
    (labels (k,) int8 with 0 = unresolved, resolved (k,) bool, eps_e (k,))."""
    pf, pr, pkr, pkw = _mv_specs(mesh)
    rows = _row_axes(mesh)

    def local(F, ids, eps, labels, perm, gids, W_s, b_s, lw, hw, eid):
        def one_view(eps_v, gids_v):
            hit = gids_v == eid                  # entity appears once globally
            return jnp.sum(jnp.where(hit, eps_v, 0.0))

        e = jax.vmap(one_view)(eps, gids)        # (k,) shard-local partial
        for ax in rows:
            e = jax.lax.psum(e, ax)
        # the waters test: a pure (k,) compare, no feature bytes touched
        lab = jnp.where(e >= hw, 1, jnp.where(e < lw, -1, 0)).astype(jnp.int8)
        return lab, lab != 0, e

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, pkr, pkr, pkw, P(), P(), P(), P()),
        out_specs=(P(), P(), P()))

    def step(state: ShardedMultiViewState, entity_id):
        return fn(*state, entity_id)

    return step


def make_multiview_entity_margin_step(mesh: Mesh):
    """The "disk" fallback for views the waters cannot short-circuit: ONE
    gather of the entity's feature row (masked row-shard sum), then every
    view's margin from the stacked models — one shared F touch for all k
    views that miss. Returns z (k,) f32 (margins, bias already subtracted)."""
    pf, pr, pkr, pkw = _mv_specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    rows = _row_axes(mesh)

    def local(F, ids, eps, labels, perm, gids, W_s, b_s, lw, hw, W, b, eid):
        hit = (ids == eid).astype(jnp.float32)            # (n_local,)
        f = jnp.einsum("n,nd->d", hit, F.astype(jnp.float32))
        z = jnp.einsum("kd,d->k", W, f)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        for ax in rows:            # other row shards contribute exact zeros
            z = jax.lax.psum(z, ax)
        return z - b

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, pkr, pkr, pkw, P(), P(), P(), pkw, P(), P()),
        out_specs=P())

    def step(state: ShardedMultiViewState, W, b, entity_id):
        return fn(*state, W, b, entity_id)

    return step


def make_multiview_all_members_step(mesh: Mesh):
    _, _, pkr, _ = _mv_specs(mesh)
    rows = _row_axes(mesh)

    def local(labels):
        c = jnp.sum((labels == 1).astype(jnp.int32), axis=1)
        for ax in rows:
            c = jax.lax.psum(c, ax)
        return c

    fn = shard_map(local, mesh=mesh, in_specs=(pkr,), out_specs=P())
    return lambda state: fn(state.labels)


@dataclasses.dataclass
class ShardedMultiViewHazy:
    """Host driver for k views: pooled SKIING (a reorganization re-sorts all
    views from one fused matmul, so the strategy treats it as one global
    op), per-view Hölder waters kept host-side as arrays."""
    mesh: Mesh
    n: int
    d: int
    k: int
    M: float
    p: float = 2.0
    alpha: float = 1.0
    cap_frac: float = 1 / 64

    def __post_init__(self):
        hz, self.cap = make_multiview_hazy_update_step(
            self.mesh, self.n, self.k, self.cap_frac)
        self._hazy = jax.jit(hz)
        self._reorg = jax.jit(make_multiview_reorganize_step(self.mesh))
        self._count = jax.jit(make_multiview_all_members_step(self.mesh))
        self._probe = jax.jit(make_multiview_hybrid_probe_step(self.mesh))
        self._margin = jax.jit(make_multiview_entity_margin_step(self.mesh))
        from repro.core.skiing import Skiing
        self.skiing = Skiing(S=1.0, alpha=self.alpha)
        self.lw = np.zeros(self.k, np.float64)
        self.hw = np.zeros(self.k, np.float64)

    def init_state(self, F: np.ndarray) -> ShardedMultiViewState:
        specs = multiview_state_specs(self.n, self.d, self.k, self.mesh,
                                      dtype=jnp.bfloat16)
        put = lambda x, s: jax.device_put(x, s.sharding)
        k, n = self.k, self.n
        state = ShardedMultiViewState(
            F=put(F.astype(np.float32), specs.F),
            ids=put(np.arange(n, dtype=np.int32), specs.ids),
            eps=put(np.zeros((k, n), np.float32), specs.eps),
            labels=put(np.ones((k, n), np.int8), specs.labels),
            perm=put(np.tile(np.arange(n, dtype=np.int32), (k, 1)), specs.perm),
            gids=put(np.tile(np.arange(n, dtype=np.int32), (k, 1)), specs.gids),
            W_stored=put(np.zeros((k, self.d), np.float32), specs.W_stored),
            b_stored=put(np.zeros(k, np.float32), specs.b_stored),
            lw=put(np.zeros(k, np.float32), specs.lw),
            hw=put(np.zeros(k, np.float32), specs.hw),
        )
        return self._reorg(state, jnp.zeros((k, self.d), jnp.float32),
                           jnp.zeros(k, jnp.float32))

    def _do_reorg(self, state, W, b):
        state = self._reorg(state, W, b)
        self.skiing.record_reorg()
        self.lw[:] = 0.0
        self.hw[:] = 0.0
        return state

    def apply_models(self, state: ShardedMultiViewState, W, b):
        """One eager round for all k views (modeled costs ∝ rows touched)."""
        from repro.core.multiview import row_norms
        if self.skiing.should_reorganize():
            return self._do_reorg(state, W, b)
        dw = row_norms(np.asarray(W) - np.asarray(state.W_stored), self.p)
        db = np.asarray(b, np.float64) - np.asarray(state.b_stored, np.float64)
        self.lw = np.minimum(self.lw, -self.M * dw + db)
        self.hw = np.maximum(self.hw, self.M * dw + db)
        state, wsum, wmax = self._hazy(
            state._replace(lw=jnp.asarray(self.lw, jnp.float32),
                           hw=jnp.asarray(self.hw, jnp.float32)), W, b)
        if int(np.max(np.asarray(wmax))) > self.cap:
            # some view's capacity window overflowed on some shard
            return self._do_reorg(state, W, b)
        self.skiing.record_incremental(
            float(np.sum(np.asarray(wsum))) / (self.n * self.k))
        return state

    def all_members(self, state) -> np.ndarray:
        return np.asarray(self._count(state))

    def hybrid_labels_of(self, state: ShardedMultiViewState, W, b,
                         entity_id: int):
        """§3.5.2 batched single-entity read: the device-side waters probe
        resolves what it can with zero feature bytes; the views that miss
        share ONE feature-row gather (the margin step). Returns
        ((k,) int8 labels, (k,) bool resolved-by-water mask)."""
        st = state._replace(lw=jnp.asarray(self.lw, jnp.float32),
                            hw=jnp.asarray(self.hw, jnp.float32))
        lab, resolved, _ = self._probe(st, jnp.int32(entity_id))
        lab = np.asarray(lab).copy()
        resolved = np.asarray(resolved)
        if not resolved.all():
            z = np.asarray(self._margin(st, W, jnp.asarray(b, jnp.float32),
                                        jnp.int32(entity_id)))
            lab = np.where(resolved, lab, np.where(z >= 0, 1, -1)).astype(np.int8)
        return lab, resolved
