"""Pod-scale sharded HAZY view maintenance (jit/shard_map twin of hazy.py).

Stateful-shell #3 over the functional core in `core/engine.py`: every
algorithm rule — the Lemma 3.1 partition (`band_partition` inside the
single-view step, `covering_windows`/`band_mask` inside the multi-view
step, `probe_partition` inside the hybrid probe), the Eq. 2 waters update
(host-side in the drivers, via `waters_update`) and the SKIING charge rule
(via `Skiing`) — is imported from engine.py; this module owns sharding
layout, shard_map plumbing and the kernel launch.

Single-view layout (DESIGN.md §2): entity rows sharded over ("pod","data"),
feature dim over ("model",). All three maintenance steps need *zero
cross-shard data movement* except a psum of per-shard eps partials over the
model axis and scalar metric reductions:

  * naive_update_step  — full eps recompute + relabel (the paper's naive
                         eager baseline; memory-bound roofline anchor)
  * hazy_update_step   — banded reclassify with a static capacity window
                         (the paper's incremental step; bytes ∝ band)
  * reorganize_step    — per-shard argsort + row gather (paper's re-sort;
                         embarrassingly parallel — see DESIGN.md on why
                         shard-local clustering preserves correctness)

Multi-view layout: k one-vs-all views share ONE scratch table whose rows
are kept in a shard-local SHARED clustering order (sorted by
min_v |eps_v|, the distance to the nearest view's decision boundary, so
every view's band is clustered near the front of the shard). The order is
maintained entirely device-side: the reorganize step re-sorts it, the
update step computes per-view covering windows of the Lemma 3.1 band in
that order (`engine.covering_windows`) and relabels the union of the k
windows with ONE `multiview_band_reclassify` Pallas launch — no vmapped
per-view dynamic slices. The kernel computes sign(w_v·f − b_v) from whole
feature rows, so the scratch table is row-sharded and model-REPLICATED
(the (k, d) models are tiny; the big model-sharded training jobs live in
models/steps.py). The §3.5.2 hybrid read pair rides the same state:
`make_multiview_hybrid_probe_step` (eps-map lookup + waters short-circuit,
zero feature bytes) and `make_multiview_entity_margin_step` (ONE shared
feature-row gather for the views the waters cannot resolve).

Static band capacity: jit needs static shapes, so bands are processed
through a `cap`-row window per shard (cap ≈ n_shard * cap_frac, tile
aligned). The kernel reports per-view window overflow
(`with_overflow=True`) and the host driver triggers reorganization instead
of shipping the stale labels a truncated window would leave behind —
SKIING would usually have reorganized long before that.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (band_partition, classify, covering_windows,
                               probe_partition, waters_update)
from repro.kernels.band_reclassify.ops import multiview_band_reclassify

try:                                   # jax >= 0.6 exports it at top level
    shard_map = jax.shard_map
except AttributeError:                 # pinned 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map


class ShardedHazyState(NamedTuple):
    F: jax.Array            # (n, d) bf16 — rows in shard-local eps-sorted order
    eps: jax.Array          # (n,) f32  — stored-model eps (the eps-map)
    labels: jax.Array       # (n,) int8
    perm: jax.Array         # (n,) int32 — shard-local positions -> entity ids
    w_stored: jax.Array     # (d,) f32
    b_stored: jax.Array     # () f32
    lw: jax.Array           # () f32
    hw: jax.Array           # () f32


def state_specs(n: int, d: int, mesh: Mesh, dtype=jnp.bfloat16):
    """Abstract ShardedHazyState with shardings (dry-run inputs)."""
    row_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rows = P(row_axes)
    rows_feat = P(row_axes, "model" if "model" in mesh.axis_names else None)
    feat = P("model" if "model" in mesh.axis_names else None)

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, spec))

    return ShardedHazyState(
        F=sds((n, d), dtype, rows_feat),
        eps=sds((n,), jnp.float32, rows),
        labels=sds((n,), jnp.int8, rows),
        perm=sds((n,), jnp.int32, rows),
        w_stored=sds((d,), jnp.float32, feat),
        b_stored=sds((), jnp.float32, P()),
        lw=sds((), jnp.float32, P()),
        hw=sds((), jnp.float32, P()),
    )


def _row_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _specs(mesh: Mesh):
    rows = _row_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    return P(rows, model), P(rows), P(model)


# ---------------------------------------------------------------------------
# Steps (built per mesh; call under `with mesh:` or pass to jit/lower)
# ---------------------------------------------------------------------------

def make_naive_update_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        return classify(z, xp=jnp)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=pr)

    def step(state: ShardedHazyState, w, b):
        labels = fn(*state, w, b)
        return state._replace(labels=labels)

    return step


def make_hazy_update_step(mesh: Mesh, n: int, cap_frac: float = 1 / 64):
    """Banded incremental step. Returns (state', width_total)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    rows = _row_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in rows])) if rows else 1
    n_local = n // n_shards
    cap = max(64, int(n_local * cap_frac))

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        # Hölder waters were updated on the host (scalars); locate the band
        # [lw, hw) via THE shared Lemma 3.1 partition (engine.band_partition
        # — the same helper the host engines and the hybrid probe use).
        lo, hi = band_partition(eps, lw, hw, xp=jnp)
        lo, hi = lo.astype(jnp.int32), hi.astype(jnp.int32)
        width = hi - lo
        start = jnp.clip(lo, 0, jnp.maximum(0, eps.shape[0] - cap))
        Fb = jax.lax.dynamic_slice(F, (start, 0), (cap, F.shape[1]))
        z = jnp.einsum("nd,d->n", Fb.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        new = classify(z, xp=jnp)
        old = jax.lax.dynamic_slice(labels, (start,), (cap,))
        idx = jnp.arange(cap) + start
        in_band = (idx >= lo) & (idx < hi)
        merged = jnp.where(in_band, new, old)
        labels = jax.lax.dynamic_update_slice(labels, merged, (start,))
        wsum, wmax = width, width
        for ax in rows:
            wsum = jax.lax.psum(wsum, ax)
            wmax = jax.lax.pmax(wmax, ax)
        return labels, wsum, wmax

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pr, P(), P()))

    def step(state: ShardedHazyState, w, b):
        labels, wsum, wmax = fn(*state, w, b)
        return state._replace(labels=labels), wsum, wmax

    return step, cap


def make_reorganize_step(mesh: Mesh):
    """Per-shard sort by fresh eps + row gather; resets the stored model.

    No collectives beyond the model-axis psum of eps partials: the
    clustering is shard-local by design (DESIGN.md §2)."""
    pf, pr, pw = _specs(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None

    def local(F, eps, labels, perm, w_s, b_s, lw, hw, w, b):
        z = jnp.einsum("nd,d->n", F.astype(jnp.float32), w)
        if model_ax:
            z = jax.lax.psum(z, model_ax)
        z = z - b
        order = jnp.argsort(z)
        eps_new = z[order]
        F_new = jnp.take(F, order, axis=0)
        perm_new = jnp.take(perm, order)
        labels_new = classify(eps_new, xp=jnp)
        return F_new, eps_new, labels_new, perm_new

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pr, pr, pw, P(), P(), P(), pw, P()),
        out_specs=(pf, pr, pr, pr))

    def step(state: ShardedHazyState, w, b):
        F, eps, labels, perm = fn(*state, w, b)
        return ShardedHazyState(F, eps, labels, perm, w, b,
                                jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))

    return step


def make_all_members_step(mesh: Mesh):
    pf, pr, pw = _specs(mesh)
    rows = _row_axes(mesh)

    def local(labels):
        c = jnp.sum((labels == 1).astype(jnp.int32))
        for ax in rows:
            c = jax.lax.psum(c, ax)
        return c

    fn = shard_map(local, mesh=mesh, in_specs=(pr,), out_specs=P())
    return lambda state: fn(state.labels)


# ---------------------------------------------------------------------------
# Host-side driver (real runs; the Waters/Skiing control loop stays host-side
# exactly as the paper's strategy is driven outside the storage engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ShardedHazy:
    mesh: Mesh
    n: int
    d: int
    M: float
    p: float = 2.0
    alpha: float = 1.0
    cap_frac: float = 1 / 64

    def __post_init__(self):
        self._naive = jax.jit(make_naive_update_step(self.mesh))
        hz, self.cap = make_hazy_update_step(self.mesh, self.n, self.cap_frac)
        self._hazy = jax.jit(hz)
        self._reorg = jax.jit(make_reorganize_step(self.mesh))
        self._count = jax.jit(make_all_members_step(self.mesh))
        from repro.core.skiing import Skiing
        self.skiing = Skiing(S=1.0, alpha=self.alpha)
        self.lw = 0.0
        self.hw = 0.0

    def init_state(self, F: np.ndarray) -> ShardedHazyState:
        specs = state_specs(self.n, self.d, self.mesh, dtype=jnp.bfloat16)
        put = lambda x, s: jax.device_put(x, s.sharding)
        state = ShardedHazyState(
            F=put(F.astype(np.float32), specs.F),
            eps=put(np.zeros(self.n, np.float32), specs.eps),
            labels=put(np.ones(self.n, np.int8), specs.labels),
            perm=put(np.arange(self.n, dtype=np.int32), specs.perm),
            w_stored=put(np.zeros(self.d, np.float32), specs.w_stored),
            b_stored=put(np.zeros((), np.float32), specs.b_stored),
            lw=put(np.zeros((), np.float32), specs.lw),
            hw=put(np.zeros((), np.float32), specs.hw),
        )
        return self._reorg(state, jnp.zeros(self.d, jnp.float32), jnp.zeros((), jnp.float32))

    def apply_model(self, state: ShardedHazyState, w, b) -> ShardedHazyState:
        """One eager round under SKIING (modeled costs: bytes ∝ rows touched)."""
        if self.skiing.should_reorganize():
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        lw, hw = waters_update(self.lw, self.hw, np.asarray(w), float(b),
                               np.asarray(state.w_stored),
                               float(state.b_stored), self.M, self.p)
        self.lw, self.hw = float(lw), float(hw)
        state, wsum, wmax = self._hazy(
            state._replace(lw=jnp.float32(self.lw), hw=jnp.float32(self.hw)), w, b)
        if int(wmax) > self.cap:
            # capacity window overflowed on some shard: fall back to reorg
            # (correctness preserved; SKIING would reorganize soon anyway)
            state = self._reorg(state, w, b)
            self.skiing.record_reorg()
            self.lw = self.hw = 0.0
            return state
        self.skiing.record_incremental(int(wsum) / self.n)  # modeled cost
        return state

    def all_members(self, state) -> int:
        return int(self._count(state))


# ---------------------------------------------------------------------------
# Multi-view twin: k one-vs-all views over ONE shared scratch table kept in
# a device-resident SHARED clustering order (sorted by min_v |eps_v|). The
# update step relabels the k covering windows with ONE Pallas kernel launch.
# ---------------------------------------------------------------------------

class ShardedMultiViewState(NamedTuple):
    """k views sharing one scratch table in a shared clustering order.

    The shared order is the device-resident form of the engine's clustering
    permutation: each shard keeps its local rows sorted by min_v |eps_v|
    (distance to the nearest view's decision boundary), so every view's
    Lemma 3.1 band is a small covering window near the front of the shard —
    the exact window form `multiview_band_reclassify` consumes. `gids` IS
    the perm (position -> global entity id); reorganization re-sorts rows,
    eps and labels together, entirely on device. F rows are kept whole
    (row-sharded, model-replicated) because the band kernel computes
    sign(w_v·f − b_v) per row."""
    F: jax.Array            # (n, d) f32 — scratch rows, shared order
    gids: jax.Array         # (n,) i32 global entity id per scratch row
    eps: jax.Array          # (k, n) f32 stored-model margins, shared order
    labels: jax.Array       # (k, n) int8 aligned to the shared order
    W_stored: jax.Array     # (k, d) f32 (replicated)
    b_stored: jax.Array     # (k,) f32
    lw: jax.Array           # (k,) f32
    hw: jax.Array           # (k,) f32


def multiview_state_specs(n: int, d: int, k: int, mesh: Mesh,
                          dtype=jnp.float32):
    row_axes = _row_axes(mesh)
    rows = P(row_axes)
    krows = P(None, row_axes)

    def sds(shape, dt, spec):
        return jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, spec))

    return ShardedMultiViewState(
        F=sds((n, d), dtype, P(row_axes, None)),   # model-replicated rows
        gids=sds((n,), jnp.int32, rows),
        eps=sds((k, n), jnp.float32, krows),
        labels=sds((k, n), jnp.int8, krows),
        W_stored=sds((k, d), jnp.float32, P()),
        b_stored=sds((k,), jnp.float32, P()),
        lw=sds((k,), jnp.float32, P()),
        hw=sds((k,), jnp.float32, P()),
    )


def _mv_specs(mesh: Mesh):
    rows = _row_axes(mesh)
    return (P(rows, None), P(rows), P(None, rows))


def _mv_tiles(mesh: Mesh, n: int, cap_frac: float):
    """Per-shard (n_local, block_n, cap) for the band kernel: block_n must
    divide n_local, cap is tile-aligned in [block_n, n_local]."""
    rows = _row_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in rows])) if rows else 1
    n_local = n // n_shards
    block_n = 512
    while block_n > 8 and n_local % block_n:
        block_n //= 2
    if n_local % block_n:
        block_n = n_local
    cap = -(-max(block_n, int(n_local * cap_frac)) // block_n) * block_n
    return n_local, block_n, min(cap, n_local)


def make_multiview_update_step(mesh: Mesh, n: int, k: int,
                               cap_frac: float = 1 / 64,
                               interpret: Optional[bool] = None):
    """Banded incremental step for all k views in ONE Pallas launch.

    Per shard: `engine.covering_windows` locates each view's covering
    window of the Lemma 3.1 band in the shared order (pure device compute,
    no per-view dynamic slices), then `multiview_band_reclassify` streams
    only the union of the k windows HBM->VMEM and relabels them under the
    stacked models. Returns (state', true band widths (k,), overflow flag
    () i32 — nonzero when some view's window exceeded the kernel capacity
    on some shard, i.e. rows past the capacity kept stale labels and the
    driver must reorganize)."""
    pf, pr, pkr = _mv_specs(mesh)
    rows = _row_axes(mesh)
    n_local, block_n, cap = _mv_tiles(mesh, n, cap_frac)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def local(F, gids, eps, labels, W_s, b_s, lw, hw, W, b):
        start, end, width = covering_windows(eps, lw, hw, xp=jnp)
        labels, overflow = multiview_band_reclassify(
            F, labels, W, b, start, end, cap=cap, block_n=block_n,
            interpret=interpret, with_overflow=True)
        wsum = width
        ov = jnp.any(overflow).astype(jnp.int32)
        for ax in rows:
            wsum = jax.lax.psum(wsum, ax)
            ov = jax.lax.pmax(ov, ax)
        return labels, wsum, ov

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, P(), P(), P(), P(), P(), P()),
        out_specs=(pkr, P(), P()),
        check_rep=False)     # no replication rule for pallas_call (jax#21400)

    def step(state: ShardedMultiViewState, W, b):
        labels, wsum, ov = fn(*state, W, b)
        return state._replace(labels=labels), wsum, ov

    return step, cap


def make_multiview_reorganize_step(mesh: Mesh):
    """Re-sort the SHARED clustering order from one `F @ W.T` product: the
    new order sorts shard-local rows by min_v |eps_v| so that every view's
    band clusters near the front of the shard. Rows, gids, eps and labels
    move together; no collectives at all (shard-local clustering, and F
    rows are whole so there is no model-axis psum either)."""
    pf, pr, pkr = _mv_specs(mesh)

    def local(F, gids, eps, labels, W_s, b_s, lw, hw, W, b):
        Z = jnp.einsum("nd,kd->kn", F.astype(jnp.float32), W) - b[:, None]
        key = jnp.min(jnp.abs(Z), axis=0)          # nearest-boundary distance
        order = jnp.argsort(key).astype(jnp.int32)
        F_new = jnp.take(F, order, axis=0)
        gids_new = jnp.take(gids, order)
        eps_new = jnp.take(Z, order, axis=1)
        labels_new = classify(eps_new, xp=jnp)
        return F_new, gids_new, eps_new, labels_new

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, P(), P(), P(), P(), P(), P()),
        out_specs=(pf, pr, pkr, pkr))

    def step(state: ShardedMultiViewState, W, b):
        F, gids, eps, labels = fn(*state, W, b)
        zeros = jnp.zeros(b.shape, jnp.float32)
        return ShardedMultiViewState(F, gids, eps, labels, W, b, zeros, zeros)

    return step


def make_multiview_hybrid_probe_step(mesh: Mesh):
    """§3.5.2 waters short-circuit for ONE entity across all k views with
    ZERO feature-table bytes: the entity's stored eps per view comes from
    the eps-map (masked row-shard sum over the shared `gids`, psum'd), and
    the waters test is THE shared Lemma 3.1 point-probe
    (engine.probe_partition). Returns (labels (k,) int8 with 0 =
    unresolved, resolved (k,) bool, eps_e (k,))."""
    pf, pr, pkr = _mv_specs(mesh)
    rows = _row_axes(mesh)

    def local(F, gids, eps, labels, W_s, b_s, lw, hw, eid):
        hit = gids == eid                    # entity appears once globally
        e = jnp.sum(jnp.where(hit[None, :], eps, 0.0), axis=1)
        for ax in rows:
            e = jax.lax.psum(e, ax)
        lab = probe_partition(e, lw, hw, xp=jnp)
        return lab, lab != 0, e

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P()))

    def step(state: ShardedMultiViewState, entity_id):
        return fn(*state, entity_id)

    return step


def make_multiview_entity_margin_step(mesh: Mesh):
    """The "disk" fallback for views the waters cannot short-circuit: ONE
    gather of the entity's feature row (masked row-shard sum), then every
    view's margin from the stacked models — one shared F touch for all k
    views that miss. Returns z (k,) f32 (margins, bias subtracted)."""
    pf, pr, pkr = _mv_specs(mesh)
    rows = _row_axes(mesh)

    def local(F, gids, eps, labels, W_s, b_s, lw, hw, W, b, eid):
        hit = (gids == eid).astype(jnp.float32)           # (n_local,)
        f = jnp.einsum("n,nd->d", hit, F.astype(jnp.float32))
        z = jnp.einsum("kd,d->k", W, f)
        for ax in rows:            # other row shards contribute exact zeros
            z = jax.lax.psum(z, ax)
        return z - b

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(pf, pr, pkr, pkr, P(), P(), P(), P(), P(), P(), P()),
        out_specs=P())

    def step(state: ShardedMultiViewState, W, b, entity_id):
        return fn(*state, W, b, entity_id)

    return step


def make_multiview_all_members_step(mesh: Mesh):
    _, _, pkr = _mv_specs(mesh)
    rows = _row_axes(mesh)

    def local(labels):
        c = jnp.sum((labels == 1).astype(jnp.int32), axis=1)
        for ax in rows:
            c = jax.lax.psum(c, ax)
        return c

    fn = shard_map(local, mesh=mesh, in_specs=(pkr,), out_specs=P())
    return lambda state: fn(state.labels)


@dataclasses.dataclass
class ShardedMultiViewHazy:
    """Host driver for k views: pooled SKIING (a reorganization re-sorts the
    one shared order for all views, so the strategy treats it as one global
    op), per-view Hölder waters kept host-side via `engine.waters_update`.
    `apply_models` reclassifies the union band through the
    `multiview_band_reclassify` kernel against the device-resident shared
    clustering order, and falls back to reorganization whenever the kernel
    reports a covering-window overflow (stale labels would ship otherwise)."""
    mesh: Mesh
    n: int
    d: int
    k: int
    M: float
    p: float = 2.0
    alpha: float = 1.0
    cap_frac: float = 1 / 64
    interpret: Optional[bool] = None

    def __post_init__(self):
        up, self.cap = make_multiview_update_step(
            self.mesh, self.n, self.k, self.cap_frac, interpret=self.interpret)
        self._update = jax.jit(up)
        self._reorg = jax.jit(make_multiview_reorganize_step(self.mesh))
        self._count = jax.jit(make_multiview_all_members_step(self.mesh))
        self._probe = jax.jit(make_multiview_hybrid_probe_step(self.mesh))
        self._margin = jax.jit(make_multiview_entity_margin_step(self.mesh))
        from repro.core.skiing import Skiing
        self.skiing = Skiing(S=1.0, alpha=self.alpha)
        self.lw = np.zeros(self.k, np.float64)
        self.hw = np.zeros(self.k, np.float64)
        self.overflows = 0        # kernel-capacity overflow -> forced reorg

    def init_state(self, F: np.ndarray) -> ShardedMultiViewState:
        specs = multiview_state_specs(self.n, self.d, self.k, self.mesh)
        put = lambda x, s: jax.device_put(x, s.sharding)
        k, n = self.k, self.n
        state = ShardedMultiViewState(
            F=put(F.astype(np.float32), specs.F),
            gids=put(np.arange(n, dtype=np.int32), specs.gids),
            eps=put(np.zeros((k, n), np.float32), specs.eps),
            labels=put(np.ones((k, n), np.int8), specs.labels),
            W_stored=put(np.zeros((k, self.d), np.float32), specs.W_stored),
            b_stored=put(np.zeros(k, np.float32), specs.b_stored),
            lw=put(np.zeros(k, np.float32), specs.lw),
            hw=put(np.zeros(k, np.float32), specs.hw),
        )
        return self._reorg(state, jnp.zeros((k, self.d), jnp.float32),
                           jnp.zeros(k, jnp.float32))

    def _do_reorg(self, state, W, b):
        state = self._reorg(state, W, b)
        self.skiing.record_reorg()
        self.lw[:] = 0.0
        self.hw[:] = 0.0
        return state

    def apply_models(self, state: ShardedMultiViewState, W, b):
        """One eager round for all k views (modeled costs ∝ rows touched)."""
        W = jnp.asarray(W, jnp.float32)
        b32 = jnp.asarray(b, jnp.float32)
        if self.skiing.should_reorganize():
            return self._do_reorg(state, W, b32)
        self.lw, self.hw = waters_update(
            self.lw, self.hw, np.asarray(W), np.asarray(b, np.float64),
            np.asarray(state.W_stored),
            np.asarray(state.b_stored, np.float64), self.M, self.p)
        state, wsum, overflow = self._update(
            state._replace(lw=jnp.asarray(self.lw, jnp.float32),
                           hw=jnp.asarray(self.hw, jnp.float32)), W, b32)
        if int(overflow):
            # some view's covering window outgrew the kernel capacity on
            # some shard: its labels past the capacity are stale — rebuild
            # the shared order instead of shipping them
            self.overflows += 1
            return self._do_reorg(state, W, b32)
        self.skiing.record_incremental(
            float(np.sum(np.asarray(wsum))) / (self.n * self.k))
        return state

    def all_members(self, state) -> np.ndarray:
        return np.asarray(self._count(state))

    def hybrid_labels_of(self, state: ShardedMultiViewState, W, b,
                         entity_id: int):
        """§3.5.2 batched single-entity read: the device-side waters probe
        resolves what it can with zero feature bytes; the views that miss
        share ONE feature-row gather (the margin step). Returns
        ((k,) int8 labels, (k,) bool resolved-by-water mask)."""
        st = state._replace(lw=jnp.asarray(self.lw, jnp.float32),
                            hw=jnp.asarray(self.hw, jnp.float32))
        lab, resolved, _ = self._probe(st, jnp.int32(entity_id))
        lab = np.asarray(lab).copy()
        resolved = np.asarray(resolved)
        if not resolved.all():
            z = np.asarray(self._margin(st, jnp.asarray(W, jnp.float32),
                                        jnp.asarray(b, jnp.float32),
                                        jnp.int32(entity_id)))
            lab = np.where(resolved, lab, classify(z)).astype(np.int8)
        return lab, resolved
