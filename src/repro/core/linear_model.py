"""Linear classification models + incremental (stochastic-gradient) training.

Paper §2.1/§3.1 and Appendix A.1/B.5.1: a model is (w, b); the view labels
an entity f as sign(w·f − b). Training is incremental SGD (Bottou-style) on
one of the convex losses in Fig. 9 — hinge (SVM), logistic, ridge — each a
few lines, matching the paper's observation that "a new linear model
requires tens of lines of code".

Both a NumPy path (host-driven engine, exact dynamic shapes — the paper's
single-node setting) and a jitted JAX path (TPU integration) are provided.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

try:  # the jax path is optional at import time for pure-numpy users
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None


@dataclasses.dataclass
class LinearModel:
    w: np.ndarray          # (d,)
    b: float

    def copy(self) -> "LinearModel":
        return LinearModel(self.w.copy(), float(self.b))

    def eps(self, F: np.ndarray) -> np.ndarray:
        return F @ self.w - self.b

    def predict(self, F: np.ndarray) -> np.ndarray:
        e = self.eps(F)
        return np.where(e >= 0, 1.0, -1.0)


def zero_model(d: int) -> LinearModel:
    return LinearModel(np.zeros(d, np.float32), 0.0)


# ---------------------------------------------------------------------------
# Loss gradients (subgradients). All take margin-era scalars, vectorized.
# ---------------------------------------------------------------------------

def _loss_grad(method: str, z: np.ndarray, y: np.ndarray) -> np.ndarray:
    """dL/dz for z = w·f − b, label y ∈ {−1, 1}."""
    if method == "svm":           # hinge: max(0, 1 − yz)
        return np.where(y * z < 1.0, -y, 0.0)
    if method == "logistic":      # log(1 + exp(−yz))
        return -y / (1.0 + np.exp(np.clip(y * z, -30, 30)))
    if method == "ridge":         # (z − y)^2
        return 2.0 * (z - y)
    raise ValueError(method)


def sgd_step(model: LinearModel, f: np.ndarray, y: float, *, lr: float,
             l2: float = 1e-4, method: str = "svm") -> LinearModel:
    """One incremental training example (paper: ~100µs/update regime)."""
    z = float(f @ model.w - model.b)
    g = float(_loss_grad(method, np.asarray(z), np.asarray(y)))
    w = model.w * (1.0 - lr * l2)
    if g != 0.0:
        w = w - lr * g * f
    b = model.b - lr * (-g)  # d z / d b = −1
    return LinearModel(w.astype(np.float32), float(b))


def train_batch(model: LinearModel, F: np.ndarray, Y: np.ndarray, *, lr: float,
                l2: float = 1e-4, method: str = "svm", epochs: int = 1,
                seed: int = 0) -> LinearModel:
    """Multi-epoch SGD over a labeled set (bulk-load / Fig. 10 baseline)."""
    r = np.random.default_rng(seed)
    w, b = model.w.copy(), model.b
    n = F.shape[0]
    for _ in range(epochs):
        order = r.permutation(n)
        for i in order:
            z = F[i] @ w - b
            g = float(_loss_grad(method, np.asarray(z), np.asarray(Y[i])))
            w *= (1.0 - lr * l2)
            if g != 0.0:
                w -= lr * g * F[i]
            b -= lr * (-g)
    return LinearModel(w.astype(np.float32), float(b))


def full_gradient_train(model: LinearModel, F: np.ndarray, Y: np.ndarray, *,
                        lr: float, l2: float = 1e-4, method: str = "svm",
                        iters: int = 200) -> LinearModel:
    """Full-batch (sub)gradient descent — the non-incremental baseline the
    paper compares against (SVMLight stand-in for Fig. 10 timing)."""
    w, b = model.w.copy(), model.b
    n = F.shape[0]
    for _ in range(iters):
        z = F @ w - b
        g = _loss_grad(method, z, Y)
        gw = F.T @ g / n + l2 * w
        gb = -np.mean(g)
        w -= lr * gw
        b -= lr * gb
    return LinearModel(w.astype(np.float32), float(b))


def precision_recall(model: LinearModel, F: np.ndarray, Y: np.ndarray) -> Tuple[float, float]:
    pred = model.predict(F)
    tp = float(np.sum((pred == 1) & (Y == 1)))
    fp = float(np.sum((pred == 1) & (Y == -1)))
    fn = float(np.sum((pred == -1) & (Y == 1)))
    prec = tp / max(1.0, tp + fp)
    rec = tp / max(1.0, tp + fn)
    return prec, rec


# ---------------------------------------------------------------------------
# JAX twin (used by the sharded engine and examples)
# ---------------------------------------------------------------------------

if jax is not None:

    def jax_sgd_step(w, b, f, y, lr, l2=1e-4, method: str = "svm"):
        z = jnp.dot(f, w) - b
        if method == "svm":
            g = jnp.where(y * z < 1.0, -y, 0.0)
        elif method == "logistic":
            g = -y / (1.0 + jnp.exp(jnp.clip(y * z, -30, 30)))
        else:
            g = 2.0 * (z - y)
        w = w * (1.0 - lr * l2) - lr * g * f
        b = b + lr * g  # dL/db = −g; descent: b − lr·(−g)
        return w, b
