"""HAZY incremental classification-view maintenance (paper §3.2–3.5).

Host-driven engine (NumPy): the k = 1 stateful shell over the functional
core in `core/engine.py` — exact dynamic band sizes, measured wall-time
costs, and a *materialized* clustered table `F_sorted` (the paper's
single-view storage layout: the clustering gather is the dominant
reorganization cost the benchmarks measure). Every algorithm rule — the
Lemma 3.1 partition (`band_partition` / `probe_partition`), the Eq. 2
waters update (via `Waters` → `engine.waters_update`), the SKIING charge
rule (via `Skiing` → `engine.skiing_charge`/`skiing_due`), sign labels
(`classify`) and the §3.5.2 hot-buffer window — is imported from
`core/engine.py`; this module owns only storage, timing and policy
sequencing. The vectorized k-view shell lives in `core/multiview.py`, the
TPU-sharded twin in `core/sharded.py` (static band capacities,
pjit/shard_map) — all three share the same engine core.

Engine state (mirrors §3.2.2):
  * F_sorted / eps_sorted / labels_sorted — the eps-clustered scratch table H
  * perm / inv_perm — clustering permutation (B+-tree analogue) and the
    hybrid eps-map (id → eps is `eps_sorted[inv_perm[id]]`, O(1))
  * stored vs current model, Waters (lw/hw), Skiing accumulator

Cost accounting: `cost_mode="measured"` uses wall time (paper's choice);
"modeled" uses S·(band/n) for deterministic tests. `touch_ns` adds a
per-tuple-touched penalty to emulate a slower storage tier (the paper's
on-disk architecture) — 0 for main-memory mode.

Policies: "eager" maintains on every model round, "lazy" defers to the next
read, "hybrid" (§3.5.2) defers like lazy but serves single-entity reads
through the eps-map/waters/hot-buffer tier (`hybrid_label`) without a full
catch-up — a pending model only needs a waters update (Eq. 2 is monotone)
for the short-circuit to stay exact. Boundary convention (Lemma 3.1):
eps ≥ hw is certainly positive, eps < lw certainly negative, and the band
[lw, hw) is what reclassification must touch — the probe and the band
search use the same partition because both call the same engine helper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

from repro.core.engine import (band_partition, classify, hot_buffer_window,
                               probe_partition)
from repro.core.linear_model import LinearModel, zero_model
from repro.core.skiing import Skiing, alpha_star
from repro.core.waters import Waters, holder_M
from repro.obs import clock
from repro.obs.cost import ViewCostRecorder


@dataclasses.dataclass
class Stats:
    rounds: int = 0
    reorgs: int = 0
    tuples_reclassified: int = 0
    tuples_total_possible: int = 0
    band_fraction_last: float = 0.0
    incremental_seconds: float = 0.0
    reorg_seconds: float = 0.0


class HazyEngine:
    """Eager/lazy/hybrid incremental maintenance of one binary view."""

    def __init__(self, features: np.ndarray, *, p: float = float("inf"),
                 q: float = 1.0, alpha: float = 1.0, policy: str = "eager",
                 cost_mode: str = "measured", touch_ns: float = 0.0,
                 buffer_frac: float = 0.0, store=None):
        assert policy in ("eager", "lazy", "hybrid")
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.policy = policy
        self._defers = policy in ("lazy", "hybrid")
        self.cost_mode = cost_mode
        self.touch_ns = touch_ns
        self.M = holder_M(self.F, q)
        self.waters = Waters(p=p, M=self.M)
        self.model = zero_model(self.d)
        self.stored = self.model.copy()
        self.stats = Stats()
        self.buffer_frac = buffer_frac
        self._buffer_lo = 0
        self._buffer_hi = 0
        # optional memory-budgeted storage tier (repro.storage.BufferPool):
        # when set, every probe the waters cannot resolve reads through the
        # pool ("pool" = page resident, "disk" = cold page read) and the
        # hot buffer is served from PINNED pool pages. Maintenance scans
        # (reorg/relabel) stream F directly — the budget governs the
        # §3.5.2 point-read path, exactly the paper's Fig. 8 economics.
        self.store = store
        self.disk_touches = 0      # probes that paid a COLD feature-row read
        self._eps_order = None     # boundary-outward eps order (readahead)
        self._eps_pos = None       # entity id -> position in _eps_order
        # measured-cost telemetry: wall-clock reorg/step timings recorded
        # ALONGSIDE the modeled charges, never fed back into them (the
        # modeled trajectory stays bitwise deterministic).
        self.cost = ViewCostRecorder(1)
        # initial organization (free S estimate)
        t0 = clock()
        self._do_reorganize()
        S0 = max(clock() - t0, 1e-9)
        # sigma = scan/S; estimate scan as a single pass over eps
        t0 = clock()
        float(np.sum(self.eps_sorted))
        scan = max(clock() - t0, 1e-12)
        self.sigma = min(1.0, scan / S0)
        # modeled mode is the deterministic test contract: charges are
        # S-invariant dimensionless fractions (S pinned to 1.0, exactly
        # like the Layer 2 pure steps), so two engines fed the same stream
        # have bitwise-identical SKIING trajectories regardless of wall
        # clock. Measured mode keeps the paper's wall-time S.
        S_init = 1.0 if cost_mode == "modeled" else S0
        self.skiing = Skiing(S=S_init,
                             alpha=(alpha if alpha else alpha_star(self.sigma)))
        self._pending: Optional[LinearModel] = None  # lazy: latest unapplied model

    # ------------------------------------------------------------------
    # Organization
    # ------------------------------------------------------------------

    def _do_reorganize(self):
        eps = self.F @ self.model.w - self.model.b
        self.perm = np.argsort(eps, kind="stable")
        self.inv_perm = np.empty_like(self.perm)
        self.inv_perm[self.perm] = np.arange(self.n)
        self.eps_sorted = eps[self.perm]
        self.F_sorted = self.F[self.perm]          # the clustering gather (dominant cost)
        self.labels_sorted = classify(self.eps_sorted)
        self.pos_count = int(np.count_nonzero(self.labels_sorted == 1))
        self.stored = self.model.copy()
        self.waters.reset()
        if self.buffer_frac:
            self._buffer_lo, self._buffer_hi = hot_buffer_window(
                self.eps_sorted, int(self.buffer_frac * self.n))
        if self.store is not None:
            self._rewarm_store()

    def _rewarm_store(self):
        """Re-warm the pool along the NEW clustering order (the paper's
        index idea: the eps order is the locality order). The hot-buffer
        window's pages are pinned; then pages are prefetched in
        boundary-outward eps order — the rows most likely to miss the
        waters short-circuit (the band) — until the budget is full.
        With a `Prefetcher` attached the schedule is handed to its
        background worker (serving overlaps the warm-up); without one it
        warms inline, synchronously, as before."""
        self.store.repin_rows(self.perm[self._buffer_lo:self._buffer_hi])
        order = self.perm[np.argsort(np.abs(self.eps_sorted), kind="stable")]
        # cache the boundary-outward order for per-miss readahead hints
        self._eps_order = order
        pos = np.empty(self.n, np.int64)
        pos[order] = np.arange(self.n)
        self._eps_pos = pos
        pre = getattr(self.store, "prefetcher", None)
        if pre is not None:
            pre.enqueue(order)
        else:
            self.store.warm(order)

    def _hint_readahead(self, entity_id: int, window: int = 64):
        """Band-probe miss at eps-position p: enqueue the next `window`
        entities boundary-outward (they are the next-most-likely misses,
        and on disk they are the NEXT pages — eps order is locality
        order). No-op without an attached prefetcher."""
        pre = getattr(self.store, "prefetcher", None)
        if pre is None or self._eps_order is None:
            return
        p = int(self._eps_pos[entity_id])
        nxt = self._eps_order[p + 1:p + 1 + window]
        if nxt.size:
            pre.enqueue(nxt, evict=True)

    def reorganize(self):
        t0 = clock()
        self._do_reorganize()
        S = clock() - t0 + self.touch_ns * 1e-9 * self.n
        # modeled mode keeps S pinned (dimensionless charges); measured
        # mode re-estimates the reorg cost from this wall time
        self.skiing.record_reorg(None if self.cost_mode == "modeled" else S)
        self.stats.reorgs += 1
        self.stats.reorg_seconds += S
        self.cost.record_reorg(0, S)

    # ------------------------------------------------------------------
    # Incremental step (paper Fig. 2): reclassify only the water band
    # ------------------------------------------------------------------

    def _band(self) -> Tuple[int, int]:
        # [lw, hw) via THE shared Lemma 3.1 partition — the same helper
        # `hybrid_label` short-circuits with (engine.probe_partition).
        lo, hi = band_partition(self.eps_sorted, self.waters.lw,
                                self.waters.hw)
        return int(lo), int(hi)

    def _incremental_step(self) -> float:
        """Reclassify the band under the *current* model. Returns cost."""
        t0 = clock()
        lo, hi = self._band()
        width = hi - lo
        if width > 0:
            z = self.F_sorted[lo:hi] @ self.model.w - self.model.b
            new_lab = classify(z)
            old = self.labels_sorted[lo:hi]
            self.pos_count += int(np.count_nonzero(new_lab == 1)) - int(np.count_nonzero(old == 1))
            self.labels_sorted[lo:hi] = new_lab
        wall = clock() - t0 + self.touch_ns * 1e-9 * width
        self.stats.tuples_reclassified += width
        self.stats.tuples_total_possible += self.n
        self.stats.band_fraction_last = width / max(1, self.n)
        c = (self.skiing.S * (width / max(1, self.n))
             if self.cost_mode == "modeled" else wall)
        self.cost.record_step(0, wall, c)
        return c

    def apply_model(self, model: LinearModel):
        """One round: the view must reflect `model` (eager) or remember it
        (lazy). SKIING decides reorg-vs-incremental (Fig. 7: check first)."""
        self.model = model.copy()
        self.stats.rounds += 1
        if self._defers:
            self._pending = self.model
            if self.policy == "hybrid":
                # §3.5.2: the band relabel stays deferred (hybrid reads do
                # not need it), but the eps-map must stay tight or every
                # probe degrades to the disk tier — so SKIING still decides
                # reorgs on updates, charging the expected probe miss rate
                # (the band fraction) instead of relabel wall time.
                self.waters.update(self.model, self.stored)
                lo, hi = self._band()
                miss = self.skiing.S * ((hi - lo) / max(1, self.n))
                if self.skiing.record_incremental(miss):
                    self.reorganize()
                    self._pending = None
            return
        if self.skiing.should_reorganize():
            self.reorganize()
        else:
            self.waters.update(self.model, self.stored)
            c = self._incremental_step()
            self.skiing.record_incremental(c)
            self.stats.incremental_seconds += c

    def _lazy_catch_up(self):
        if self._pending is None:
            return
        self.waters.update(self.model, self.stored)
        lo, hi = self._band()
        width = hi - lo
        t0 = clock()
        if width:
            z = self.F_sorted[lo:hi] @ self.model.w - self.model.b
            new_lab = classify(z)
            old = self.labels_sorted[lo:hi]
            self.pos_count += int(np.count_nonzero(new_lab == 1)) - int(np.count_nonzero(old == 1))
            self.labels_sorted[lo:hi] = new_lab
        self._pending = None
        # lazy cost accounting (paper §3.4): waste = (N_R − N_+)/N_R · S
        n_read = self.n - lo
        waste = (n_read - self.pos_count) / max(1, n_read)
        wall = clock() - t0 + self.touch_ns * 1e-9 * width
        c = (wall if self.cost_mode == "measured"
             else self.skiing.S * max(0.0, waste))
        self.cost.record_step(0, wall, max(0.0, c))
        self.stats.tuples_reclassified += width
        self.stats.tuples_total_possible += self.n
        self.stats.incremental_seconds += max(0.0, c)
        if self.skiing.record_incremental(max(0.0, c)):
            self.reorganize()

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def all_members(self) -> int:
        """'How many entities with label 1?' (paper's All Members probe)."""
        if self._defers:
            self._lazy_catch_up()
        return self.pos_count

    def members(self) -> np.ndarray:
        if self._defers:
            self._lazy_catch_up()
        return self.perm[self.labels_sorted == 1]

    def label(self, entity_id: int) -> int:
        if self._defers:
            self._lazy_catch_up()
        return int(self.labels_sorted[self.inv_perm[entity_id]])

    # ------------------------------------------------------------------
    # Hybrid single-entity read (paper §3.5.2, Fig. 8)
    # ------------------------------------------------------------------

    def hybrid_label(self, entity_id: int) -> Tuple[int, str]:
        """eps-map + waters + buffer; returns (label, how) where how ∈
        {water, buffer, disk} for instrumentation.

        Exact under every policy: a pending (lazy/hybrid) model only needs
        the monotone waters update — no catch-up relabel — because the
        short-circuit tests the guarantee, not the materialized labels, and
        the buffer/disk tiers classify against the current model directly."""
        if self._pending is not None:
            self.waters.update(self.model, self.stored)
        pos = self.inv_perm[entity_id]
        e = self.eps_sorted[pos]
        # THE Lemma 3.1 partition, point-probe form — shared with _band()
        # so probe and band search can never disagree (PR 2's bug class).
        t = int(probe_partition(e, self.waters.lw, self.waters.hw))
        if t != 0:
            return t, "water"
        if self._buffer_lo <= pos < self._buffer_hi and (
                self.store is None or self.store.resident(entity_id)):
            # hot buffer: with a storage tier this is a PINNED pool page
            # (never a separately materialized copy). A window wider than
            # the budget leaves its tail unpinned — those rows are not "in
            # the buffer" and fall through to the pool/disk tiers below.
            f = (self.store.get_row(entity_id) if self.store is not None
                 else self.F_sorted[pos])
            z = f @ self.model.w - self.model.b
            return int(classify(z)), "buffer"
        if self.store is not None:            # "go to disk" via the pool
            f, how = self.store.touch(entity_id)
            if how == "disk":
                self.disk_touches += 1        # cold page reads only
                self._hint_readahead(entity_id)
            z = f @ self.model.w - self.model.b
            return int(classify(z)), how
        z = self.F[entity_id] @ self.model.w - self.model.b   # "go to disk"
        self.disk_touches += 1     # charged as disk_touches * touch_ns by
        return int(classify(z)), "disk"   # callers (sleep is too coarse)

    # ------------------------------------------------------------------

    def band_fraction(self) -> float:
        if self._defers:
            self._lazy_catch_up()
        lo, hi = self._band()
        return (hi - lo) / max(1, self.n)

    def check_consistent(self) -> bool:
        """Golden invariant: view == naive relabel under the current model
        (after lazy catch-up)."""
        if self._defers:
            self._lazy_catch_up()
        truth = classify(self.F_sorted @ self.model.w - self.model.b)
        return bool(np.array_equal(truth, self.labels_sorted))


class NaiveEngine:
    """Naïve eager/lazy baselines (paper §2.2)."""

    def __init__(self, features: np.ndarray, *, policy: str = "eager",
                 touch_ns: float = 0.0):
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.policy = policy
        self.touch_ns = touch_ns
        self.model = zero_model(self.d)
        self.labels = np.where(-self.model.b >= 0, 1, -1) * np.ones(self.n, np.int8)
        self._relabel()

    def _relabel(self):
        z = self.F @ self.model.w - self.model.b
        self.labels = classify(z)
        if self.touch_ns:
            time.sleep(self.touch_ns * 1e-9 * self.n)

    def apply_model(self, model: LinearModel):
        self.model = model.copy()
        if self.policy == "eager":
            self._relabel()  # full scan + rewrite every update

    def all_members(self) -> int:
        if self.policy == "lazy":
            self._relabel()  # scan and classify every tuple per read
        return int(np.count_nonzero(self.labels == 1))

    def label(self, entity_id: int) -> int:
        if self.policy == "lazy":
            z = self.F[entity_id] @ self.model.w - self.model.b
            return int(classify(z))
        return int(self.labels[entity_id])
