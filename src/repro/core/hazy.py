"""HAZY incremental classification-view maintenance (paper §3.2–3.5).

Host-driven engine (NumPy): exact dynamic band sizes, measured costs — the
faithful reproduction of the paper's single-node algorithm, used by the
benchmarks (Fig. 4/5/6/11/12/13). The TPU-sharded twin lives in
`core/sharded.py` (static band capacities, pjit/shard_map).

Engine state (mirrors §3.2.2):
  * F_sorted / eps_sorted / labels_sorted — the eps-clustered scratch table H
  * perm / inv_perm — clustering permutation (B+-tree analogue) and the
    hybrid eps-map (id → eps is `eps_sorted[inv_perm[id]]`, O(1))
  * stored vs current model, Waters (lw/hw), Skiing accumulator

Cost accounting: `cost_mode="measured"` uses wall time (paper's choice);
"modeled" uses S·(band/n) for deterministic tests. `touch_ns` adds a
per-tuple-touched penalty to emulate a slower storage tier (the paper's
on-disk architecture) — 0 for main-memory mode.

Policies: "eager" maintains on every model round, "lazy" defers to the next
read, "hybrid" (§3.5.2) defers like lazy but serves single-entity reads
through the eps-map/waters/hot-buffer tier (`hybrid_label`) without a full
catch-up — a pending model only needs a waters update (Eq. 2 is monotone)
for the short-circuit to stay exact. Boundary convention (Lemma 3.1):
eps ≥ hw is certainly positive, eps < lw certainly negative, and the band
[lw, hw) is what reclassification must touch — the probe and the band
search use the same partition.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.linear_model import LinearModel, zero_model
from repro.core.skiing import Skiing, alpha_star
from repro.core.waters import Waters, holder_M


def hot_buffer_window(eps_sorted: np.ndarray, cap: int) -> Tuple[int, int]:
    """[lo, hi) positions of the §3.5.2 hot buffer: `cap` eps-sorted slots
    centered on the zero boundary (the tuples most likely to flip). Shared
    by the single-view engine and the per-view windows of `MultiViewEngine`."""
    n = eps_sorted.shape[0]
    cap = max(1, min(int(cap), n))
    boundary = int(np.searchsorted(eps_sorted, 0.0))
    lo = max(0, boundary - cap // 2)
    hi = min(n, lo + cap)
    return lo, hi


@dataclasses.dataclass
class Stats:
    rounds: int = 0
    reorgs: int = 0
    tuples_reclassified: int = 0
    tuples_total_possible: int = 0
    band_fraction_last: float = 0.0
    incremental_seconds: float = 0.0
    reorg_seconds: float = 0.0


class HazyEngine:
    """Eager/lazy/hybrid incremental maintenance of one binary view."""

    def __init__(self, features: np.ndarray, *, p: float = float("inf"),
                 q: float = 1.0, alpha: float = 1.0, policy: str = "eager",
                 cost_mode: str = "measured", touch_ns: float = 0.0,
                 buffer_frac: float = 0.0):
        assert policy in ("eager", "lazy", "hybrid")
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.policy = policy
        self._defers = policy in ("lazy", "hybrid")
        self.cost_mode = cost_mode
        self.touch_ns = touch_ns
        self.M = holder_M(self.F, q)
        self.waters = Waters(p=p, M=self.M)
        self.model = zero_model(self.d)
        self.stored = self.model.copy()
        self.stats = Stats()
        self.buffer_frac = buffer_frac
        self._buffer_lo = 0
        self._buffer_hi = 0
        self.disk_touches = 0      # hybrid probes that read a feature row
        # initial organization (free S estimate)
        t0 = time.perf_counter()
        self._do_reorganize()
        S0 = max(time.perf_counter() - t0, 1e-9)
        # sigma = scan/S; estimate scan as a single pass over eps
        t0 = time.perf_counter()
        float(np.sum(self.eps_sorted))
        scan = max(time.perf_counter() - t0, 1e-12)
        self.sigma = min(1.0, scan / S0)
        self.skiing = Skiing(S=S0, alpha=(alpha if alpha else alpha_star(self.sigma)))
        self._pending: Optional[LinearModel] = None  # lazy: latest unapplied model

    # ------------------------------------------------------------------
    # Organization
    # ------------------------------------------------------------------

    def _do_reorganize(self):
        eps = self.F @ self.model.w - self.model.b
        self.perm = np.argsort(eps, kind="stable")
        self.inv_perm = np.empty_like(self.perm)
        self.inv_perm[self.perm] = np.arange(self.n)
        self.eps_sorted = eps[self.perm]
        self.F_sorted = self.F[self.perm]          # the clustering gather (dominant cost)
        self.labels_sorted = np.where(self.eps_sorted >= 0, 1, -1).astype(np.int8)
        self.pos_count = int(np.count_nonzero(self.labels_sorted == 1))
        self.stored = self.model.copy()
        self.waters.reset()
        if self.buffer_frac:
            self._buffer_lo, self._buffer_hi = hot_buffer_window(
                self.eps_sorted, int(self.buffer_frac * self.n))

    def reorganize(self):
        t0 = time.perf_counter()
        self._do_reorganize()
        S = time.perf_counter() - t0 + self.touch_ns * 1e-9 * self.n
        self.skiing.record_reorg(S)
        self.stats.reorgs += 1
        self.stats.reorg_seconds += S

    # ------------------------------------------------------------------
    # Incremental step (paper Fig. 2): reclassify only the water band
    # ------------------------------------------------------------------

    def _band(self) -> Tuple[int, int]:
        # [lw, hw): eps ≥ hw is certainly positive (equality included, since
        # z ≥ 0 labels +1), eps < lw certainly negative — the same partition
        # `hybrid_label` short-circuits on.
        lo = int(np.searchsorted(self.eps_sorted, self.waters.lw, side="left"))
        hi = int(np.searchsorted(self.eps_sorted, self.waters.hw, side="left"))
        return lo, hi

    def _incremental_step(self) -> float:
        """Reclassify the band under the *current* model. Returns cost."""
        t0 = time.perf_counter()
        lo, hi = self._band()
        width = hi - lo
        if width > 0:
            z = self.F_sorted[lo:hi] @ self.model.w - self.model.b
            new_lab = np.where(z >= 0, 1, -1).astype(np.int8)
            old = self.labels_sorted[lo:hi]
            self.pos_count += int(np.count_nonzero(new_lab == 1)) - int(np.count_nonzero(old == 1))
            self.labels_sorted[lo:hi] = new_lab
        wall = time.perf_counter() - t0 + self.touch_ns * 1e-9 * width
        self.stats.tuples_reclassified += width
        self.stats.tuples_total_possible += self.n
        self.stats.band_fraction_last = width / max(1, self.n)
        if self.cost_mode == "modeled":
            return self.skiing.S * (width / max(1, self.n))
        return wall

    def apply_model(self, model: LinearModel):
        """One round: the view must reflect `model` (eager) or remember it
        (lazy). SKIING decides reorg-vs-incremental (Fig. 7: check first)."""
        self.model = model.copy()
        self.stats.rounds += 1
        if self._defers:
            self._pending = self.model
            if self.policy == "hybrid":
                # §3.5.2: the band relabel stays deferred (hybrid reads do
                # not need it), but the eps-map must stay tight or every
                # probe degrades to the disk tier — so SKIING still decides
                # reorgs on updates, charging the expected probe miss rate
                # (the band fraction) instead of relabel wall time.
                self.waters.update(self.model, self.stored)
                lo, hi = self._band()
                miss = self.skiing.S * ((hi - lo) / max(1, self.n))
                if self.skiing.record_incremental(miss):
                    self.reorganize()
                    self._pending = None
            return
        if self.skiing.should_reorganize():
            self.reorganize()
        else:
            self.waters.update(self.model, self.stored)
            c = self._incremental_step()
            self.skiing.record_incremental(c)
            self.stats.incremental_seconds += c

    def _lazy_catch_up(self):
        if self._pending is None:
            return
        self.waters.update(self.model, self.stored)
        lo, hi = self._band()
        width = hi - lo
        t0 = time.perf_counter()
        if width:
            z = self.F_sorted[lo:hi] @ self.model.w - self.model.b
            new_lab = np.where(z >= 0, 1, -1).astype(np.int8)
            old = self.labels_sorted[lo:hi]
            self.pos_count += int(np.count_nonzero(new_lab == 1)) - int(np.count_nonzero(old == 1))
            self.labels_sorted[lo:hi] = new_lab
        self._pending = None
        # lazy cost accounting (paper §3.4): waste = (N_R − N_+)/N_R · S
        n_read = self.n - lo
        waste = (n_read - self.pos_count) / max(1, n_read)
        c = (time.perf_counter() - t0 + self.touch_ns * 1e-9 * width
             if self.cost_mode == "measured" else self.skiing.S * max(0.0, waste))
        self.stats.tuples_reclassified += width
        self.stats.tuples_total_possible += self.n
        self.stats.incremental_seconds += max(0.0, c)
        if self.skiing.record_incremental(max(0.0, c)):
            self.reorganize()

    # ------------------------------------------------------------------
    # Reads
    # ------------------------------------------------------------------

    def all_members(self) -> int:
        """'How many entities with label 1?' (paper's All Members probe)."""
        if self._defers:
            self._lazy_catch_up()
        return self.pos_count

    def members(self) -> np.ndarray:
        if self._defers:
            self._lazy_catch_up()
        return self.perm[self.labels_sorted == 1]

    def label(self, entity_id: int) -> int:
        if self._defers:
            self._lazy_catch_up()
        return int(self.labels_sorted[self.inv_perm[entity_id]])

    # ------------------------------------------------------------------
    # Hybrid single-entity read (paper §3.5.2, Fig. 8)
    # ------------------------------------------------------------------

    def hybrid_label(self, entity_id: int) -> Tuple[int, str]:
        """eps-map + waters + buffer; returns (label, how) where how ∈
        {water, buffer, disk} for instrumentation.

        Exact under every policy: a pending (lazy/hybrid) model only needs
        the monotone waters update — no catch-up relabel — because the
        short-circuit tests the guarantee, not the materialized labels, and
        the buffer/disk tiers classify against the current model directly."""
        if self._pending is not None:
            self.waters.update(self.model, self.stored)
        pos = self.inv_perm[entity_id]
        e = self.eps_sorted[pos]
        # Lemma 3.1 partition, aligned with _band(): eps ≥ hw certainly
        # positive (z == 0 labels +1, so equality short-circuits high);
        # eps < lw certainly negative — eps == lw may sit exactly on the
        # boundary (z == 0 ⇒ +1) and must be classified, not short-circuited.
        if e >= self.waters.hw:
            return 1, "water"
        if e < self.waters.lw:
            return -1, "water"
        if self._buffer_lo <= pos < self._buffer_hi:
            z = self.F_sorted[pos] @ self.model.w - self.model.b
            return (1 if z >= 0 else -1), "buffer"
        z = self.F[entity_id] @ self.model.w - self.model.b   # "go to disk"
        self.disk_touches += 1     # charged as disk_touches * touch_ns by
        return (1 if z >= 0 else -1), "disk"   # callers (sleep is too coarse)

    # ------------------------------------------------------------------

    def band_fraction(self) -> float:
        if self._defers:
            self._lazy_catch_up()
        lo, hi = self._band()
        return (hi - lo) / max(1, self.n)

    def check_consistent(self) -> bool:
        """Golden invariant: view == naive relabel under the current model
        (after lazy catch-up)."""
        if self._defers:
            self._lazy_catch_up()
        truth = np.where(self.F_sorted @ self.model.w - self.model.b >= 0, 1, -1)
        return bool(np.array_equal(truth.astype(np.int8), self.labels_sorted))


class NaiveEngine:
    """Naïve eager/lazy baselines (paper §2.2)."""

    def __init__(self, features: np.ndarray, *, policy: str = "eager",
                 touch_ns: float = 0.0):
        self.F = np.ascontiguousarray(features, np.float32)
        self.n, self.d = self.F.shape
        self.policy = policy
        self.touch_ns = touch_ns
        self.model = zero_model(self.d)
        self.labels = np.where(-self.model.b >= 0, 1, -1) * np.ones(self.n, np.int8)
        self._relabel()

    def _relabel(self):
        z = self.F @ self.model.w - self.model.b
        self.labels = np.where(z >= 0, 1, -1).astype(np.int8)
        if self.touch_ns:
            time.sleep(self.touch_ns * 1e-9 * self.n)

    def apply_model(self, model: LinearModel):
        self.model = model.copy()
        if self.policy == "eager":
            self._relabel()  # full scan + rewrite every update

    def all_members(self) -> int:
        if self.policy == "lazy":
            self._relabel()  # scan and classify every tuple per read
        return int(np.count_nonzero(self.labels == 1))

    def label(self, entity_id: int) -> int:
        if self.policy == "lazy":
            z = self.F[entity_id] @ self.model.w - self.model.b
            return 1 if z >= 0 else -1
        return int(self.labels[entity_id])
