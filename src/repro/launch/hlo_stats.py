"""Collective-traffic extraction from compiled HLO text.

`cost_analysis()` has no collective-bytes entry, so we parse the compiled
module: every `all-reduce` / `all-gather` / `reduce-scatter` / `all-to-all`
/ `collective-permute` op contributes its *output* operand bytes (a
reasonable per-device wire proxy: ring all-reduce moves ~2x, all-gather
ingests (k-1)/k of the output — we report raw output bytes and note the
convention in EXPERIMENTS.md). `-start`/`-done` pairs are counted once.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"                      # output shape (or tuple)
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind (output-operand convention)."""
    out: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, op, phase = m.groups()
        if phase == "-done":
            continue  # counted at -start
        out[op] += _shape_bytes(shape_txt)
        counts[op + "_count"] += 1
    out.update(counts)
    out["total"] = sum(v for k, v in out.items()
                       if not k.endswith("_count") and k != "total")
    return dict(out)
