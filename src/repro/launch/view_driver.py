"""Importable view-serving driver (the paper's workload, LM-encoded).

A classification view over a corpus of documents *encoded by an LM
backbone*, serving batched mixed read/update traffic — Single-Entity
reads, All-Members scans, and streaming training examples — with the HAZY
engine maintaining the view and SKIING deciding reorganizations.

This module is the single home of the driver: `examples/serve_view.py` is
a thin shim over it and `repro.launch.serve --mode view` imports it
directly (no `spec_from_file_location` path hacks). `--mode sql` serves
the same kind of workload through the relational front-end instead.

Run:  PYTHONPATH=src python -m repro.launch.view_driver [--requests 3000]
"""
from __future__ import annotations

import argparse
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import clock


def make_backbone_encoder(arch: str = "tinyllama-1.1b", batch: int = 32):
    """A reduced assigned-arch backbone as the HAZY feature function."""
    from repro.configs import smoke_config
    from repro.models import build
    from repro.models.steps import init_train_state
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    params = state["params"]

    @jax.jit
    def encode_batch(tokens):
        hidden, _ = mdl.forward(params, {"tokens": tokens}, return_hidden=True)
        emb = jnp.mean(jnp.take(params["tok"]["embedding"], tokens, axis=0), axis=1)
        # mean-pooled final hidden + mean-pooled token embeddings
        return jnp.concatenate([jnp.mean(hidden, axis=1), emb.astype(hidden.dtype)], -1)

    def encode(docs_tokens: np.ndarray) -> np.ndarray:
        out = []
        for i in range(0, docs_tokens.shape[0], batch):
            out.append(np.asarray(encode_batch(
                jnp.asarray(docs_tokens[i:i + batch])), np.float32))
        F = np.concatenate(out)
        return F / np.maximum(np.linalg.norm(F, axis=1, keepdims=True), 1e-9)

    return encode, cfg


def make_topic_docs(cfg, n_docs: int, doc_len: int, seed: int = 0):
    """Two 'topics': docs drawn from distinct topical vocabularies (with
    some shared common words mixed in). Returns (docs_tokens, topic mask)."""
    r = np.random.default_rng(seed)
    topic = r.random(n_docs) < 0.5
    v8 = cfg.vocab_size // 8
    topical = np.where(topic[:, None],
                       r.integers(0, v8, (n_docs, doc_len)),
                       r.integers(4 * v8, 5 * v8, (n_docs, doc_len)))
    common = r.integers(6 * v8, 8 * v8, (n_docs, doc_len))
    use_common = r.random((n_docs, doc_len)) < 0.3
    docs = np.where(use_common, common, topical).astype(np.int32)
    return docs, topic


def serve_view(requests: int = 3000, docs: int = 4000, doc_len: int = 32):
    """The classic driver: direct `ClassificationView` calls."""
    from repro.core import ClassificationView
    r = np.random.default_rng(0)
    encode, cfg = make_backbone_encoder()
    tokens, topic = make_topic_docs(cfg, docs, doc_len)
    t0 = clock()
    F = encode(tokens)
    print(f"encoded {docs} docs with {cfg.name} backbone "
          f"in {clock()-t0:.1f}s -> features {F.shape}")

    view = ClassificationView(F, method="svm", policy="hybrid",
                              norm=(2.0, 2.0), lr=0.1, buffer_frac=0.01)

    labels = np.where(topic, 1.0, -1.0)
    kinds = r.choice(["read", "members", "update"], size=requests,
                     p=[0.55, 0.05, 0.40])
    served = {"read": 0, "members": 0, "update": 0}
    t0 = clock()
    for kind in kinds:
        if kind == "read":
            view.label(int(r.integers(0, docs)))
        elif kind == "members":
            view.all_members()
        else:
            i = int(r.integers(0, docs))
            view.insert_example(i, float(labels[i]))
        served[kind] += 1
    dt = clock() - t0
    print(f"served {requests} requests in {dt:.2f}s "
          f"({requests/dt:.0f} req/s): {served}")
    eng = view.engine
    print(f"SKIING reorgs: {eng.skiing.reorgs}, "
          f"band now: {eng.band_fraction():.4f}")
    acc = np.mean([view.label(i) == labels[i] for i in range(0, docs, 7)])
    print(f"classification agreement with topic labels: {acc:.3f}")
    assert eng.check_consistent()
    print("view exact ✓")
    return view


def serve_sql(requests: int = 3000, docs: int = 4000, doc_len: int = 32,
              group_commit: int = 32):
    """The same workload through the relational front-end: the LM-encoded
    corpus becomes a base table, the view is created with SQL DDL, and the
    mixed traffic is a statement stream through the group-commit WAL."""
    from repro.rdbms import Catalog, Executor
    r = np.random.default_rng(0)
    encode, cfg = make_backbone_encoder()
    tokens, topic = make_topic_docs(cfg, docs, doc_len)
    t0 = clock()
    F = encode(tokens)
    print(f"encoded {docs} docs with {cfg.name} backbone "
          f"in {clock()-t0:.1f}s -> features {F.shape}")

    catalog = Catalog()
    catalog.register_table("docs", F, truth=np.where(topic, 1, -1))
    ex = Executor(catalog, group_commit=group_commit)
    ex.execute_one(
        "CREATE CLASSIFICATION VIEW topic ON docs USING MODEL svm "
        "WITH (policy = hybrid, buffer_frac = 0.01)")

    labels = np.where(topic, 1.0, -1.0)
    kinds = r.choice(["read", "members", "update"], size=requests,
                     p=[0.55, 0.05, 0.40])
    served = {"read": 0, "members": 0, "update": 0}
    t0 = clock()
    for kind in kinds:
        if kind == "read":
            i = int(r.integers(0, docs))
            ex.execute_one(f"SELECT label FROM topic WHERE id = {i}")
        elif kind == "members":
            ex.execute_one("SELECT count(*) FROM topic WHERE label = 1")
        else:
            i = int(r.integers(0, docs))
            ex.execute_one(f"INSERT INTO docs (id, label) VALUES "
                           f"({i}, {int(labels[i])})")
        served[kind] += 1
    dt = clock() - t0
    print(f"served {requests} SQL statements in {dt:.2f}s "
          f"({requests/dt:.0f} stmt/s): {served}")
    facade = catalog.view("topic").facade
    print(f"tier hits: {facade.tier_hits}, WAL commits: {ex.log.commits}")
    print(ex.execute_one(
        "EXPLAIN SELECT label FROM topic WHERE id = 0").pretty())
    assert facade.view.engine.check_consistent()
    print("view exact ✓")
    return ex


def main(argv: Optional[Sequence[str]] = None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3000)
    ap.add_argument("--docs", type=int, default=4000)
    ap.add_argument("--doc-len", type=int, default=32)
    ap.add_argument("--sql", action="store_true",
                    help="drive the workload through the SQL front-end")
    args = ap.parse_args(argv)
    if args.sql:
        serve_sql(args.requests, args.docs, args.doc_len)
    else:
        serve_view(args.requests, args.docs, args.doc_len)


if __name__ == "__main__":
    main()
