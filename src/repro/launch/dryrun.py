import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks the device count on init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k [--multipod] [--out results/dryrun]

Proves: the sharding config is coherent (GSPMD partitions every op), the
per-device memory fits, and yields cost_analysis + collective bytes for the
roofline (§Roofline reads the JSON this writes).

`--arch hazy-view` lowers the paper's three maintenance steps (naive /
banded incremental / reorganize) over a pod-scale entity table instead of
an LM step.
"""
import argparse
import json
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import clock


HAZY_SHAPES = {
    # (entities, feature_dim): a pod-scale corpus — 64Mi rows x 4096 dims
    # (bf16 features = 512 GiB, 2 GiB/chip on the single-pod mesh).
    "view_64m": (1 << 26, 4096),
    # smaller variant for quick iteration
    "view_8m": (1 << 23, 4096),
}


def _mesh(multi_pod: bool):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=multi_pod)


def lower_lm_cell(arch: str, shape_name: str, mesh, donate: bool = True):
    """Returns dict of step_name -> (lowered, seconds_to_lower)."""
    from repro.configs import SHAPES, get_config
    from repro.models import build
    from repro.models.steps import (batch_specs, decode_input_specs,
                                    make_decode_step, make_prefill_step,
                                    make_train_step, train_state_specs,
                                    abstract_tree)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mdl = build(cfg)
    out = {}
    with mesh:
        if shape.kind == "train":
            state = train_state_specs(mdl, mesh)
            batch = batch_specs(cfg, shape, mesh)
            fn = jax.jit(make_train_step(mdl),
                         donate_argnums=(0,) if donate else ())
            t0 = clock()
            out["train_step"] = (fn.lower(state, batch), clock() - t0)
        elif shape.kind == "prefill":
            params = abstract_tree(mdl.param_tree, mesh)
            batch = batch_specs(cfg, shape, mesh)
            fn = jax.jit(make_prefill_step(mdl))
            t0 = clock()
            out["prefill_step"] = (fn.lower(params, batch), clock() - t0)
        else:  # decode
            params = abstract_tree(mdl.param_tree, mesh)
            cache, token, index = decode_input_specs(mdl, shape, mesh)
            fn = jax.jit(make_decode_step(mdl),
                         donate_argnums=(1,) if donate else ())
            t0 = clock()
            out["decode_step"] = (fn.lower(params, cache, token, index),
                                  clock() - t0)
    return out, cfg, shape


def lower_hazy_cell(shape_name: str, mesh):
    from repro.core.sharded import (make_hazy_update_step, make_naive_update_step,
                                    make_reorganize_step, state_specs)
    from jax.sharding import NamedSharding, PartitionSpec as P
    n, d = HAZY_SHAPES[shape_name]
    out = {}
    with mesh:
        st = state_specs(n, d, mesh)
        w = jax.ShapeDtypeStruct((d,), jnp.float32,
                                 sharding=NamedSharding(mesh, P("model")))
        b = jax.ShapeDtypeStruct((), jnp.float32,
                                 sharding=NamedSharding(mesh, P()))
        naive = jax.jit(make_naive_update_step(mesh))
        t0 = clock()
        out["hazy_naive_step"] = (naive.lower(st, w, b), clock() - t0)
        banded, cap = make_hazy_update_step(mesh, n)
        t0 = clock()
        out["hazy_banded_step"] = (jax.jit(banded).lower(st, w, b), clock() - t0)
        reorg = jax.jit(make_reorganize_step(mesh))
        t0 = clock()
        out["hazy_reorg_step"] = (reorg.lower(st, w, b), clock() - t0)
    return out, n, d


def analyze(name: str, lowered, lower_s: float) -> Dict[str, Any]:
    from repro.launch.hlo_stats import collective_bytes
    t0 = clock()
    compiled = lowered.compile()
    compile_s = clock() - t0
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    rec = {
        "step": name,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        # cost_analysis is PER-DEVICE for SPMD modules (verified empirically)
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        },
        "hlo_chars": len(txt),
    }
    print(f"  {name}: compile {compile_s:.1f}s | "
          f"flops/dev {rec['flops_per_device']:.3e} | "
          f"bytes/dev {rec['bytes_per_device']:.3e} | "
          f"coll {coll.get('total', 0):.3e}B | "
          f"mem arg={rec['memory']['argument_bytes']/2**30:.2f}GiB "
          f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
    print(f"  memory_analysis: {ma}")
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             donate: bool = True, analysis: bool = None) -> Dict[str, Any]:
    mesh = _mesh(multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    print(f"[dryrun] {arch} × {shape_name} × {mesh_name}")
    t_start = clock()
    if analysis is None:
        analysis = not multi_pod  # roofline corrections: single-pod only
    cfg = None
    if arch == "hazy-view":
        lowered_map, n, d = lower_hazy_cell(shape_name, mesh)
        meta = {"entities": n, "feature_dim": d}
        analysis = False  # shard_map steps have no scans; raw numbers exact
    else:
        lowered_map, cfg, shape = lower_lm_cell(arch, shape_name, mesh, donate)
        meta = {"family": cfg.family, "seq_len": shape.seq_len,
                "global_batch": shape.global_batch, "kind": shape.kind}
    steps = [analyze(name, low, ts) for name, (low, ts) in lowered_map.items()]
    if analysis and cfg is not None:
        from repro.launch.analysis import corrected_cell_metrics
        from repro.models import build
        mdl = build(cfg)
        full = {"flops": steps[0]["flops_per_device"],
                "bytes": steps[0]["bytes_per_device"],
                "coll": steps[0]["collectives"].get("total", 0)}
        corr = corrected_cell_metrics(mdl, shape, mesh, full, shape.kind)
        steps[0]["loop_corrected"] = corr
        c = corr["corrected"]
        print(f"  loop-corrected: flops/dev {c['flops']:.3e} | "
              f"bytes/dev {c['bytes']:.3e} | coll {c['coll']:.3e}B")
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "num_devices": int(np.prod(mesh.devices.shape)),
        "meta": meta, "steps": steps,
        "total_s": round(clock() - t_start, 1),
        "ok": True,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-donate", action="store_true")
    args = ap.parse_args()
    rec = run_cell(args.arch, args.shape, args.multipod, args.out,
                   donate=not args.no_donate)
    print(json.dumps({k: v for k, v in rec.items() if k != "steps"}))


if __name__ == "__main__":
    main()
