"""§Roofline report generator: reads results/dryrun/*.json and emits the
three-term roofline table per (arch × shape) on the single-pod mesh.

  compute_s    = corrected_flops_per_device / PEAK_FLOPS
  memory_s     = corrected_bytes_per_device / HBM_BW
  collective_s = corrected_collective_bytes_per_device / ICI_BW

(cost_analysis is per-device for SPMD modules, so dividing by per-chip peak
is the spec's formula with both sides divided by the chip count.)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens per step
(train) / batch (decode). The MODEL/HLO ratio flags remat + dispatch waste.

  PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun_final] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (v5e-class target from the spec)
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link

# non-embedding parameter counts (computed analytically from the configs)
def param_counts():
    from repro.configs import ARCHS
    out = {}
    for name, cfg in ARCHS.items():
        d, ff, L = cfg.d_model, cfg.d_ff, cfg.num_layers
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        attn = d * nq * hd * 2 + d * nkv * hd * 2
        dense_mlp = 3 * d * ff
        if cfg.family == "ssm":
            H = cfg.rwkv_num_heads
            tm = 5 * d * d + d * 64 + 64 * d   # r/k/v/g/o + decay lora
            cm = d * ff + ff * d + d * d
            total = L * (tm + cm)
            active = total
        elif cfg.family == "hybrid":
            di = cfg.mamba_d_inner
            mamba_p = d * 2 * di + di * (cfg.dt_rank + 2 * cfg.mamba_d_state) \
                + cfg.dt_rank * di + di * d
            n_attn = L // cfg.attn_every
            n_mamba = L - n_attn
            n_moe = L // cfg.moe_every
            n_dense = L - n_moe
            total = n_attn * attn + n_mamba * mamba_p \
                + n_moe * cfg.num_experts * dense_mlp + n_dense * dense_mlp
            active = n_attn * attn + n_mamba * mamba_p \
                + n_moe * cfg.num_experts_per_tok * dense_mlp + n_dense * dense_mlp
        elif cfg.family == "moe":
            total = L * (attn + cfg.num_experts * dense_mlp
                         + cfg.num_shared_experts * dense_mlp)
            active = L * (attn + cfg.num_experts_per_tok * dense_mlp
                          + cfg.num_shared_experts * dense_mlp)
        elif cfg.family == "audio":
            enc = cfg.num_encoder_layers * (attn + 2 * d * ff)
            dec = L * (2 * attn + 2 * d * ff)
            total = active = enc + dec
        else:
            total = active = L * (attn + dense_mlp)
        out[name] = (total, active)
    return out


def model_flops(arch: str, meta: Dict, counts) -> Optional[float]:
    if arch not in counts:
        return None
    total, active = counts[arch]
    kind = meta.get("kind")
    if kind == "train":
        tokens = meta["seq_len"] * meta["global_batch"]
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = meta["seq_len"] * meta["global_batch"]
        return 2.0 * active * tokens
    if kind == "decode":
        return 2.0 * active * meta["global_batch"]
    return None


def load_cells(dir_: str):
    cells = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def hazy_rows(cell, num_chips=256, cap_frac=1 / 64):
    """hazy-view cells: one row per maintenance step.

    XLA's cost model charges `dynamic_slice` for its whole input, so the
    banded step's HLO bytes look like the naive step's. The per-row traffic
    the Pallas band_reclassify kernel actually commits to (BlockSpec tiles:
    2d feature bytes + 1 label byte per touched row, validated by the
    kernel test sweeps) is the honest number — reported as memory_s here,
    with the raw-HLO figure kept in memory_s_hlo."""
    n, d = cell["meta"]["entities"], cell["meta"]["feature_dim"]
    # rows shard over data (16); every model shard holds all its data-shard's
    # rows but only d/16 feature columns
    rows_per_device = n / 16
    row_bytes = 2 * d / 16 + 5  # bf16 feature slice + eps + label
    out = []
    for step in cell["steps"]:
        flops = step["flops_per_device"]
        bts = step["bytes_per_device"]
        coll = step["collectives"].get("total", 0)
        name = step["step"]
        if "banded" in name:
            analytic = min(bts, rows_per_device * cap_frac * row_bytes)
        elif "reorg" in name:
            analytic = rows_per_device * (2 * row_bytes + 8)  # read+write+keys
        else:  # naive: full scan
            analytic = rows_per_device * row_bytes
        compute_s = flops / PEAK_FLOPS
        memory_s = analytic / HBM_BW
        coll_s = coll / ICI_BW
        dominant = max(("compute", compute_s), ("memory", memory_s),
                       ("collective", coll_s), key=lambda kv: kv[1])[0]
        out.append({
            "arch": "hazy-view", "shape": cell["shape"], "step": name,
            "compute_s": compute_s, "memory_s": memory_s,
            "memory_s_hlo": bts / HBM_BW,
            "collective_s": coll_s, "dominant": dominant,
            "model_hlo_ratio": (2.0 * n * d / num_chips) / flops if flops else None,
            "roofline_frac": None,
            "temp_GiB": step["memory"]["temp_bytes"] / 2**30,
            "corrected": False,
        })
    return out


def roofline_row(cell, counts, num_chips=256):
    step = cell["steps"][0]
    corr = step.get("loop_corrected", {}).get("corrected")
    flops = corr["flops"] if corr else step["flops_per_device"]
    bts = corr["bytes"] if corr else step["bytes_per_device"]
    coll = corr["coll"] if corr else step["collectives"].get("total", 0)
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    coll_s = coll / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda kv: kv[1])[0]
    mf = model_flops(cell["arch"], cell.get("meta", {}), counts)
    ratio = (mf / num_chips) / flops if (mf and flops) else None
    # roofline fraction: useful model flops per second at the bottleneck,
    # relative to peak — i.e. (model_flops/chips / bottleneck_time) / peak
    bottleneck_s = max(compute_s, memory_s, coll_s)
    frac = ((mf / num_chips) / bottleneck_s / PEAK_FLOPS) if (mf and bottleneck_s) else None
    return {
        "arch": cell["arch"], "shape": cell["shape"], "step": step["step"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant, "model_hlo_ratio": ratio,
        "roofline_frac": frac,
        "temp_GiB": step["memory"]["temp_bytes"] / 2**30,
        "corrected": bool(corr),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun_final")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    counts = param_counts()
    rows = []
    for cell in load_cells(args.dir):
        if cell["mesh"] != "pod16x16":
            continue
        if cell["arch"] == "hazy-view":
            rows.extend(hazy_rows(cell))
        else:
            rows.append(roofline_row(cell, counts))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if args.md:
        print("| arch | shape | step | compute_s | memory_s | collective_s |"
              " dominant | MODEL/HLO | roofline | temp GiB |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            mh = f"{r['model_hlo_ratio']:.2f}" if r["model_hlo_ratio"] else "-"
            rf = f"{r['roofline_frac']*100:.1f}%" if r["roofline_frac"] else "-"
            print(f"| {r['arch']} | {r['shape']} | {r['step']} | "
                  f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
                  f"{r['collective_s']:.4f} | {r['dominant']} | {mh} | {rf} | "
                  f"{r['temp_GiB']:.1f} |")
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
