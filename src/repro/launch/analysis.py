"""Loop-corrected cost accounting for the dry-run roofline.

XLA's `compiled.cost_analysis()` counts a while-loop body exactly once,
regardless of trip count (verified empirically; see EXPERIMENTS.md
§Dry-run). Our models scan over layer blocks (and microbatches, and loss
chunks), so raw numbers undercount by ~the layer count. We therefore lower
each scan *block* as its own SPMD program on the same mesh — with inner
lax.scans unrolled (`cfg.unroll_inner_scans`) so ssm-chunk/loss-chunk loops
are fully counted — and correct:

    fixed     = full − Σ_u block_scan_u − loss_scan            (counted-once parts)
    corrected = fixed + mb × (Σ_u n_u · block_unroll_u + loss_unroll)

The (mb−1)·(adam+embed) error this folds into `fixed` is <0.3% (documented).
The same correction applies to FLOPs, bytes-accessed, and collective bytes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.launch.hlo_stats import collective_bytes


def _metrics(lowered) -> Dict[str, float]:
    comp = lowered.compile()
    ca = comp.cost_analysis() or {}
    txt = comp.as_text()
    coll = collective_bytes(txt)
    ma = comp.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total", 0)),
        "coll_by_op": {k: v for k, v in coll.items() if not k.endswith("_count")},
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
    }


def _sds_tree(spec_tree, mesh):
    from repro.models.steps import abstract_tree
    return abstract_tree(spec_tree, mesh)


def _act_sds(shape, mesh, axes=("batch", "seq_sp", None), dtype=jnp.bfloat16):
    from repro.models.params import resolve_axes, RULE_SETS
    spec = resolve_axes(tuple(axes), tuple(shape), mesh, RULE_SETS["tp"])
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def lower_block(mdl, unit, shape: ShapeConfig, mesh: Mesh, *, train: bool,
                unroll: bool, seq_override: Optional[int] = None):
    """Lower one scan block (fwd, or fwd+vjp for train) on the mesh."""
    from repro.models.transformer import _scan_unit_list, build
    cfg = dataclasses.replace(mdl.cfg, unroll_inner_scans=unroll)
    mdl_u = build(cfg)
    units = {u["name"]: u for u in _scan_unit_list(mdl_u)}
    u = units[unit["name"]]
    b = shape.global_batch
    s = seq_override or shape.seq_len
    if unit["name"] == "enc_blocks":
        s = cfg.encoder_seq_len
    x_sds = _act_sds((b, s, cfg.d_model), mesh)
    bp_sds = _sds_tree(u["params"], mesh)
    ctx_sds = {}
    if u["needs_enc"]:
        ctx_sds["enc"] = _act_sds((b, cfg.encoder_seq_len, cfg.d_model), mesh,
                                  axes=("batch", None, None))

    if train:
        def fn(bp, x, ctx):
            def f(bp_, x_):
                return jnp.sum(u["apply"](bp_, x_, ctx).astype(jnp.float32))
            val, grads = jax.value_and_grad(f, argnums=(0, 1))(bp, x)
            return val, grads
    else:
        def fn(bp, x, ctx):
            return u["apply"](bp, x, ctx)

    with mesh:
        return jax.jit(fn).lower(bp_sds, x_sds, ctx_sds)


def lower_loss(mdl, shape: ShapeConfig, mesh: Mesh, *, unroll: bool):
    """Lower the (hidden → CE loss) section with grad."""
    from repro.models.transformer import build
    cfg = dataclasses.replace(mdl.cfg, unroll_inner_scans=unroll)
    mdl_u = build(cfg)
    b = shape.global_batch
    s_text = shape.seq_len - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    hidden = _act_sds((b, s_text, cfg.d_model), mesh, axes=("batch", None, None))
    targets = _act_sds((b, s_text), mesh, axes=("batch", None), dtype=jnp.int32)
    tok = _sds_tree(mdl_u.param_tree["tok"], mesh)

    import jax.numpy as jnp_
    from repro.models import layers

    vp = cfg.padded_vocab()
    pad_mask_fn = lambda: (jnp_.arange(vp) < cfg.vocab_size)

    def loss_fn(tok_p, h, t):
        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def chunk_nll(h_c, t_c):
            logits = layers.unembed(tok_p, h_c).astype(jnp_.float32)
            logits = jnp_.where(pad_mask_fn()[None, None, :], logits, -1e30)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp_.take_along_axis(logp, t_c[..., None], axis=-1)[..., 0]

        from repro.models.steps import LOSS_CHUNK
        chunk = min(LOSS_CHUNK, h.shape[1])
        if h.shape[1] % chunk:
            chunk = h.shape[1]
        n_chunks = h.shape[1] // chunk
        if n_chunks > 1:
            h_c = h.reshape(h.shape[0], n_chunks, chunk, -1).swapaxes(0, 1)
            t_c = t.reshape(t.shape[0], n_chunks, chunk).swapaxes(0, 1)
            if unroll:
                nll = jnp_.stack([chunk_nll(h_c[i], t_c[i]) for i in range(n_chunks)])
            else:
                _, nll = jax.lax.scan(lambda c, ht: (c, chunk_nll(*ht)), 0, (h_c, t_c))
            return jnp_.mean(nll)
        return jnp_.mean(chunk_nll(h, t))

    def fn(tok_p, h, t):
        return jax.value_and_grad(loss_fn, argnums=(0, 1))(tok_p, h, t)

    with mesh:
        return jax.jit(fn).lower(tok, hidden, targets)


def lower_decode_block(mdl, shape: ShapeConfig, mesh: Mesh):
    """Lower one decode scan-block (no inner loops exist at s=1)."""
    from repro.models.params import ParamSpec, tree_map_specs
    from repro.models import transformer as tf
    from repro.models import layers
    cfg = mdl.cfg
    b, S = shape.global_batch, shape.seq_len
    long_ctx = S >= (1 << 18)
    stacked = mdl.cache_specs(b, S, long_ctx=long_ctx)
    key = "dec" if cfg.family == "audio" else "blocks"
    strip = lambda s: ParamSpec(s.shape[1:], s.dtype, s.axes[1:], s.init)
    block_cache = tree_map_specs(strip, stacked[key])

    if cfg.family == "audio":
        block_params = {
            "ln1": tf.norm_params(cfg), "ln_x": tf.norm_params(cfg),
            "ln2": tf.norm_params(cfg),
            "attn": layers.attention_params(cfg),
            "xattn": layers.attention_params(cfg, cross=True),
            "mlp": layers.mlp_params(cfg, gated=False),
        }

        def fn(bp, bc, x, idx):
            h = tf.apply_norm(cfg, bp["ln1"], x)
            y, ck, cv = layers.decode_attention(bp["attn"], cfg, h,
                                                bc["self"]["k"], bc["self"]["v"], idx)
            x = x + y
            h = tf.apply_norm(cfg, bp["ln_x"], x)
            x = x + layers.cross_attention(bp["xattn"], cfg, h,
                                           (bc["cross"]["k"], bc["cross"]["v"]))
            h = tf.apply_norm(cfg, bp["ln2"], x)
            x = x + layers.mlp(bp["mlp"], h, act=jax.nn.gelu)
            return x, (ck, cv)
        n_trips = cfg.num_layers
    else:
        plan, n_trips = tf._layer_plan(cfg)
        block_params = {f"pos{i}": tf._layer_params(cfg, kind, ffn)
                        for i, (kind, ffn) in enumerate(plan)}

        def fn(bp, bc, x, idx):
            return tf._decode_block_apply(cfg, plan, idx, x, bp, bc)

    bp_sds = _sds_tree(block_params, mesh)
    bc_sds = _sds_tree(block_cache, mesh)
    x_sds = _act_sds((b, 1, cfg.d_model), mesh, axes=("batch", None, None))
    idx_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
    with mesh:
        return jax.jit(fn).lower(bp_sds, bc_sds, x_sds, idx_sds), n_trips


def corrected_cell_metrics(mdl, shape: ShapeConfig, mesh: Mesh,
                           full_metrics: Dict[str, float],
                           kind: str) -> Dict[str, Any]:
    """Compute loop-corrected flops/bytes/collectives for one cell."""
    from repro.models.transformer import _scan_unit_list
    cfg = mdl.cfg
    train = kind == "train"
    mb = cfg.microbatches if train else 1

    detail = {}
    fixed = {k: full_metrics[k] for k in ("flops", "bytes", "coll")}
    core = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}

    if kind == "decode":
        lowered, n_trips = lower_decode_block(mdl, shape, mesh)
        m = _metrics(lowered)
        detail["decode_block"] = m
        for k in fixed:
            fixed[k] -= m[k]
            core[k] += n_trips * m[k]
    else:
        units = _scan_unit_list(mdl)
        has_inner = cfg.family in ("ssm", "hybrid")
        for u in units:
            m_scan = _metrics(lower_block(mdl, u, shape, mesh, train=train,
                                          unroll=False))
            if not has_inner:
                m_unroll = m_scan
            elif cfg.family == "ssm" and shape.seq_len > 8192:
                # rwkv block metrics are exactly linear in s at fixed wkv
                # chunk (attention-free): lower at 4096, scale.
                s_ana = 4096
                m_small = _metrics(lower_block(mdl, u, shape, mesh,
                                               train=train, unroll=True,
                                               seq_override=s_ana))
                scale = shape.seq_len / s_ana
                m_unroll = {k: (v * scale if isinstance(v, (int, float)) else v)
                            for k, v in m_small.items()}
            else:
                m_unroll = _metrics(lower_block(mdl, u, shape, mesh,
                                                train=train, unroll=True))
            detail[f"block_{u['name']}_scan"] = m_scan
            detail[f"block_{u['name']}_unroll"] = m_unroll
            for k in fixed:
                fixed[k] -= m_scan[k]
                core[k] += u["n"] * m_unroll[k]
        if train:
            l_scan = _metrics(lower_loss(mdl, shape, mesh, unroll=False))
            l_unroll = _metrics(lower_loss(mdl, shape, mesh, unroll=True))
            detail["loss_scan"] = l_scan
            detail["loss_unroll"] = l_unroll
            for k in fixed:
                fixed[k] -= l_scan[k]
                core[k] += l_unroll[k]

    corrected = {k: max(0.0, fixed[k]) + mb * core[k] for k in fixed}
    return {"corrected": corrected, "fixed": fixed, "core": core,
            "microbatches": mb, "detail": detail}
