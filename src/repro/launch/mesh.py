"""Production meshes. Import never touches jax device state — the mesh is
built inside the function, per the dry-run contract."""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """`jax.make_mesh` across JAX versions: explicit `axis_types` only
    exists from jax 0.5; on older pins every axis is Auto by default."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return jax.make_mesh(shape, axes, axis_types=(at.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_elastic_mesh(num_devices: int, *, model_parallel: int = 16):
    """Rebuild a mesh from the devices that survive a failure. Keeps the
    model axis (TP degree is a property of the checkpointed layout) and
    shrinks the data axis; restore_checkpoint reshards onto it."""
    devices = jax.devices()[:num_devices]
    assert num_devices % model_parallel == 0, (num_devices, model_parallel)
    data = num_devices // model_parallel
    import numpy as np
    arr = np.array(devices).reshape(data, model_parallel)
    from jax.sharding import Mesh
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return Mesh(arr, ("data", "model"), axis_types=(at.Auto,) * 2)
    return Mesh(arr, ("data", "model"))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (possibly fake) local devices exist —
    used by tests and CPU examples."""
    return make_mesh(shape, axes)
