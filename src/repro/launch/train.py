"""Production training launcher.

Wires together: config registry, production/elastic mesh, sharded train
state, deterministic sharded data, async checkpointing, straggler
detection, and signal-based preemption handling (SIGTERM → synchronous
checkpoint → clean exit → relaunch resumes).

On this CPU container you run reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 50 --ckpt-dir /tmp/ckpt
On a real pod, drop --smoke and point --mesh at the production topology.
"""
from __future__ import annotations

import argparse
import signal
import sys

import jax
import jax.numpy as jnp

from repro.obs import clock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", default="host", choices=["host", "pod", "multipod"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_config
    from repro.data import TokenStream
    from repro.models import build
    from repro.models.steps import init_train_state, make_train_step
    from repro.checkpoint import (AsyncCheckpointer, latest_step,
                                  restore_checkpoint, save_checkpoint)
    from repro.distributed import StragglerDetector
    from repro.launch.mesh import make_host_mesh, make_production_mesh

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mdl = build(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh((1, 1))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    ds = TokenStream(vocab_size=cfg.vocab_size, batch=args.batch,
                     seq_len=args.seq, seed=0,
                     shard=jax.process_index(), num_shards=jax.process_count())
    step_fn = jax.jit(make_train_step(mdl, lr=args.lr, warmup=20,
                                      total_steps=args.steps),
                      donate_argnums=(0,))

    state, start = init_train_state(mdl), 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
        if latest_step(args.ckpt_dir) is not None:
            abstract = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state, start = restore_checkpoint(args.ckpt_dir, abstract)
            print(f"[train] resumed at step {start}")

    # preemption: checkpoint synchronously and exit 0 so the scheduler
    # relaunches and the run resumes exactly
    stop = {"flag": False}

    def _handler(signum, frame):
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _handler)

    detector = StragglerDetector(n_workers=max(1, jax.process_count()))
    m = None
    with mesh:
        t_last = clock()
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
            state, m = step_fn(state, batch)
            if (i + 1) % args.log_every == 0:
                jax.block_until_ready(m["loss"])
                dt = clock() - t_last
                t_last = clock()
                tput = args.batch * args.seq * args.log_every / dt
                print(f"[train] step {i+1} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} {tput:.0f} tok/s")
                detector.observe({jax.process_index(): dt / args.log_every})
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(state, i + 1)
            if stop["flag"]:
                print("[train] preemption signal: checkpointing + exiting")
                if args.ckpt_dir:
                    if ckpt:
                        ckpt.wait()
                        ckpt.close()
                        ckpt = None
                    save_checkpoint(args.ckpt_dir, state, i + 1)
                sys.exit(0)
    if ckpt:
        ckpt.save(state, args.steps)
        ckpt.wait()
        ckpt.close()
    if m is not None:
        print(f"[train] done at step {args.steps}, "
              f"final loss {float(m['loss']):.4f}")
    else:
        print(f"[train] nothing to do (already at step {start})")


if __name__ == "__main__":
    main()
