"""Run the full dry-run sweep: every (arch × shape) × both meshes + the
hazy-view cells. One subprocess per cell (isolates jax state; a crash in
one cell doesn't kill the sweep). Resumable: cells with an existing JSON
are skipped.

  PYTHONPATH=src python -m repro.launch.sweep [--out results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from repro.obs import clock


def jobs():
    from repro.configs.registry import cells
    out = []
    # risky/expensive families first so failures surface early
    order = {"jamba-v0.1-52b": 0, "rwkv6-3b": 1, "whisper-tiny": 2,
             "dbrx-132b": 3, "pixtral-12b": 4}
    cs = sorted(cells(), key=lambda c: order.get(c[0], 9))
    for multipod in (False, True):
        for arch, shape in cs:
            out.append((arch, shape, multipod))
        out.append(("hazy-view", "view_64m", multipod))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    log_path = os.path.join(args.out, "sweep_log.jsonl")
    todo = jobs()
    t0 = clock()
    n_ok = n_fail = n_skip = 0
    for i, (arch, shape, multipod) in enumerate(todo):
        mesh = "pod2x16x16" if multipod else "pod16x16"
        out_json = os.path.join(args.out, f"{arch}__{shape}__{mesh}.json")
        if os.path.exists(out_json):
            n_skip += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", args.out]
        if multipod:
            cmd.append("--multipod")
        t1 = clock()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout)
            ok = proc.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            proc = None
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "ok": ok,
               "seconds": round(clock() - t1, 1)}
        if not ok:
            rec["tail"] = (proc.stderr[-2000:] if proc else "TIMEOUT")
        with open(log_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
        n_ok += ok
        n_fail += (not ok)
        print(f"[{i+1}/{len(todo)}] {arch} {shape} {mesh}: "
              f"{'ok' if ok else 'FAIL'} ({rec['seconds']}s)", flush=True)
    print(f"done: {n_ok} ok, {n_fail} fail, {n_skip} skipped, "
          f"{(clock()-t0)/60:.1f} min")


if __name__ == "__main__":
    main()
