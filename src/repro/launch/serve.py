"""Serving launcher: classification-view service over an LM-encoded corpus
(the paper's workload), a SQL front-end over the same engines, and a
pure-LM decode mode for the decode-shape configs.

  PYTHONPATH=src python -m repro.launch.serve --mode view --requests 2000
  PYTHONPATH=src python -m repro.launch.serve --mode sql            # REPL
  PYTHONPATH=src python -m repro.launch.serve --mode sql --script demo.sql
  PYTHONPATH=src python -m repro.launch.serve --mode sql \
      --execute "SHOW TABLES"
  PYTHONPATH=src python -m repro.launch.serve --mode sql \
      --serve 127.0.0.1:5433 --script schema.sql   # concurrent SQL server
  PYTHONPATH=src python -m repro.launch.serve --mode decode --arch tinyllama-1.1b

In server mode (`--serve HOST:PORT`), an optional --script/--execute runs
first against the shared executor (schema bootstrap), then the asyncio
server accepts N concurrent wire-protocol sessions (`repro.rdbms.client`
speaks it) until interrupted.

The view driver is an importable module (`repro.launch.view_driver`)
shared with `examples/serve_view.py` — no file-path loading hacks.
"""
from __future__ import annotations

import argparse

from repro.obs import clock


def serve_decode(arch: str, steps: int, batch: int, cache_len: int):
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models import build
    from repro.models.steps import init_cache, init_train_state, make_decode_step
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    cache = init_cache(mdl, batch, cache_len)
    dec = jax.jit(make_decode_step(mdl), donate_argnums=(1,))
    tok = jnp.zeros((batch, 1), jnp.int32)
    t0 = clock()
    for i in range(steps):
        tok, cache = dec(state["params"], cache, tok, jnp.asarray(i, jnp.int32))
    jax.block_until_ready(tok)
    dt = clock() - t0
    print(f"[serve] decode: {steps} steps x batch {batch} -> "
          f"{steps*batch/dt:.0f} tok/s ({dt/steps*1e3:.1f} ms/step)")


def serve_sql(script: str = None, execute: str = None, serve: str = None,
              slow_ms: float = None, log_statements: bool = False):
    from repro.rdbms.executor import Executor
    from repro.rdbms.repl import repl, run_script
    ex = Executor(slow_ms=slow_ms)
    if slow_ms is not None or log_statements:
        import logging
        logging.basicConfig(level=logging.INFO)  # slow/access logs visible
    if serve:
        import asyncio
        from repro.rdbms.server import SqlServer
        host, _, port = serve.rpartition(":")
        if not host or not port.isdigit():
            raise SystemExit(f"--serve wants HOST:PORT, got {serve!r}")
        # schema bootstrap runs before the first connection is accepted
        if script:
            with open(script) as fh:
                run_script(fh.read(), ex)
        elif execute:
            run_script(execute, ex)
        # the freshness scheduler runs for the server's whole lifetime:
        # views with a target_lag are refreshed in the background while
        # sessions are served (idle ticks are one catalog scan)
        from repro.scheduler import FreshnessScheduler
        refresher = FreshnessScheduler(ex).start()

        async def _serve():
            server = SqlServer(ex, host=host, port=int(port),
                               log_statements=log_statements)
            await server.start()
            print(f"[serve] sql server on {server.host}:{server.port} "
                  f"(length-prefixed JSON; freshness scheduler on; "
                  f"Ctrl-C to stop)")
            try:
                await server.serve_forever()
            finally:
                refresher.stop()

        try:
            asyncio.run(_serve())
        except KeyboardInterrupt:
            print("[serve] sql server stopped")
        return
    if script:
        with open(script) as fh:
            run_script(fh.read(), ex)
    elif execute:
        run_script(execute, ex)
    else:
        repl(ex)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="view", choices=["view", "sql", "decode"])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--script", default=None,
                    help="sql mode: run this .sql file instead of the REPL")
    ap.add_argument("--execute", default=None,
                    help="sql mode: run these ;-separated statements")
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="sql mode: run the concurrent wire-protocol "
                         "server instead of the REPL (--script/--execute "
                         "bootstrap the schema first)")
    ap.add_argument("--slow-ms", type=float, default=None,
                    help="sql mode: log the span tree of any statement "
                         "slower than this many milliseconds")
    ap.add_argument("--log-statements", action="store_true",
                    help="sql mode: access log — one structured line per "
                         "served statement")
    args = ap.parse_args()
    if args.mode == "decode":
        serve_decode(args.arch, args.steps, args.batch, args.cache_len)
    elif args.mode == "sql":
        serve_sql(args.script, args.execute, args.serve,
                  slow_ms=args.slow_ms, log_statements=args.log_statements)
    else:
        from repro.launch.view_driver import main as view_main
        view_main(["--requests", str(args.requests)])


if __name__ == "__main__":
    main()
