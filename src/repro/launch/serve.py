"""Serving launcher: classification-view service over an LM-encoded corpus
(the paper's workload) — thin CLI over examples/serve_view.py logic, plus a
pure-LM decode mode for the decode-shape configs.

  PYTHONPATH=src python -m repro.launch.serve --mode view --requests 2000
  PYTHONPATH=src python -m repro.launch.serve --mode decode --arch tinyllama-1.1b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_decode(arch: str, steps: int, batch: int, cache_len: int):
    from repro.configs import smoke_config
    from repro.models import build
    from repro.models.steps import init_cache, init_train_state, make_decode_step
    cfg = smoke_config(arch)
    mdl = build(cfg)
    state = init_train_state(mdl)
    cache = init_cache(mdl, batch, cache_len)
    dec = jax.jit(make_decode_step(mdl), donate_argnums=(1,))
    tok = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.perf_counter()
    for i in range(steps):
        tok, cache = dec(state["params"], cache, tok, jnp.asarray(i, jnp.int32))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"[serve] decode: {steps} steps x batch {batch} -> "
          f"{steps*batch/dt:.0f} tok/s ({dt/steps*1e3:.1f} ms/step)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="view", choices=["view", "decode"])
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()
    if args.mode == "decode":
        serve_decode(args.arch, args.steps, args.batch, args.cache_len)
    else:
        import sys
        sys.argv = ["serve_view", "--requests", str(args.requests)]
        import importlib.util, os
        path = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "examples", "serve_view.py")
        spec = importlib.util.spec_from_file_location("serve_view", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        mod.main()


if __name__ == "__main__":
    main()
