"""Optimizers (no optax dependency): AdamW + SGD, fp32 master moments.

Opt-state moments mirror the parameter pytree (and inherit the same
PartitionSpecs via `opt_specs`), so the optimizer is sharded exactly like
the model — ZeRO-style when params are FSDP-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, tree_map_specs


def opt_specs(param_tree):
    """ParamSpec tree for AdamW moments (fp32, same axes as params)."""
    def mom(s: ParamSpec):
        return ParamSpec(s.shape, "float32", s.axes, "zeros")
    return {
        "m": tree_map_specs(mom, param_tree),
        "v": tree_map_specs(mom, param_tree),
        "count": ParamSpec((), "int32", (), "zeros"),
    }


def adamw_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, opt_state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0):
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = jax.tree_util.tree_unflatten
    return unf(tdef, new_p), {"m": unf(tdef, new_m), "v": unf(tdef, new_v), "count": count}


def sgd_update(params, grads, lr, momentum_state=None, momentum: float = 0.0):
    if momentum and momentum_state is not None:
        momentum_state = jax.tree_util.tree_map(
            lambda s, g: momentum * s + g.astype(jnp.float32), momentum_state, grads)
        eff = momentum_state
    else:
        eff = grads
    params = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, eff)
    return params, momentum_state
