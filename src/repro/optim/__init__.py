from repro.optim.optimizers import (adamw_init, adamw_update, sgd_update,
                                    clip_by_global_norm, global_norm)
from repro.optim.schedules import warmup_cosine, constant
