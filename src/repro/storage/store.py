"""On-disk entity store: fixed-stride feature rows + a page directory.

One file holds the whole entity table as contiguous float32 rows (stride
= d * 4 bytes), memory-mapped read-only. Rows are grouped into pages of
`rows_per_page` consecutive entity ids; `read_page` materializes one page
into private memory and is the unit of "disk" I/O the `BufferPool`
budgets (and counts). The page directory maps entity id -> (page, slot)
explicitly, so the layout could become non-dense later without touching
the pool.

The store is deliberately read-only: the maintenance write path (labels,
eps, permutations) lives in the engines' scratch state, exactly as the
paper separates the clustered scratch table H from the entity relation.
"""
from __future__ import annotations

import os
import tempfile
from typing import List, Optional, Sequence

import numpy as np

from repro.analysis.witness import assert_unlocked

PAGE_BYTES = 8192          # default page size (rows are grouped to ~8 KiB)


class EntityStore:
    """Memory-mapped (n, d) float32 entity table, paged by entity id."""

    def __init__(self, path: str, n: int, d: int, rows_per_page: int, *,
                 owns_file: bool = False):
        self.path = path
        self.n, self.d = int(n), int(d)
        self.stride = self.d * 4                      # bytes per row
        self.rows_per_page = max(1, int(rows_per_page))
        self.page_bytes = self.rows_per_page * self.stride
        self.num_pages = -(-self.n // self.rows_per_page)
        self._owns = owns_file
        self._mmap: Optional[np.memmap] = np.memmap(
            path, dtype=np.float32, mode="r", shape=(self.n, self.d))
        # page directory keyed by entity id: id -> (page, slot)
        ids = np.arange(self.n, dtype=np.int64)
        self.dir_page = ids // self.rows_per_page
        self.dir_slot = (ids % self.rows_per_page).astype(np.int32)
        self.page_reads = 0                           # cold I/O counter

    @classmethod
    def from_array(cls, F: np.ndarray, path: Optional[str] = None,
                   page_bytes: int = PAGE_BYTES) -> "EntityStore":
        """Write `F` to `path` (a private temp file if None) and mmap it."""
        F = np.ascontiguousarray(F, np.float32)
        n, d = F.shape
        assert d >= 1, "entity rows must have at least one feature"
        rows_per_page = max(1, int(page_bytes) // (d * 4))
        owns = path is None
        if owns:
            fd, path = tempfile.mkstemp(prefix="hazy-entity-", suffix=".f32")
            os.close(fd)
        F.tofile(path)
        return cls(path, n, d, rows_per_page, owns_file=owns)

    # -- geometry ------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self.n * self.stride

    def page_of(self, entity_id: int) -> int:
        return int(self.dir_page[entity_id])

    def slot_of(self, entity_id: int) -> int:
        return int(self.dir_slot[entity_id])

    def page_nbytes(self, page_id: int) -> int:
        lo = page_id * self.rows_per_page
        return (min(self.n, lo + self.rows_per_page) - lo) * self.stride

    def page_row_ids(self, page_id: int) -> np.ndarray:
        lo = page_id * self.rows_per_page
        return np.arange(lo, min(self.n, lo + self.rows_per_page))

    # -- I/O -----------------------------------------------------------
    # Both readers assert (witness-armed only) that the caller does NOT
    # hold the pool lock: a disk read is the blocking operation the async
    # read path exists to keep off that lock (static twin: LCK004).

    def read_page(self, page_id: int) -> np.ndarray:
        """Materialize one page into private memory — the 'disk read'."""
        if self._mmap is None:
            raise ValueError("entity store is closed")
        assert_unlocked("pool", "EntityStore.read_page disk I/O")
        lo = page_id * self.rows_per_page
        hi = min(self.n, lo + self.rows_per_page)
        self.page_reads += 1
        return np.array(self._mmap[lo:hi])            # copy out of the mmap

    def read_pages(self, page_ids: Sequence[int]) -> List[np.ndarray]:
        """Batched `read_page`: one mmap slice copy per CONTIGUOUS RUN of
        page ids (prefetch schedules along the entity order collapse into
        a few big slabs; scattered eps-order schedules degrade to one copy
        per page). Counts `len(page_ids)` page reads — exactly what the
        equivalent `read_page` loop would — and returns per-page arrays
        aligned with the input order."""
        if self._mmap is None:
            raise ValueError("entity store is closed")
        assert_unlocked("pool", "EntityStore.read_pages disk I/O")
        pids = [int(p) for p in page_ids]
        self.page_reads += len(pids)
        out: List[np.ndarray] = []
        i = 0
        while i < len(pids):
            j = i                              # maximal run pids[i..j]
            while j + 1 < len(pids) and pids[j + 1] == pids[j] + 1:
                j += 1
            lo = pids[i] * self.rows_per_page
            hi = min(self.n, (pids[j] + 1) * self.rows_per_page)
            block = np.array(self._mmap[lo:hi])       # ONE copy per run
            for t in range(j - i + 1):                # per-page views of it
                a = t * self.rows_per_page
                b = min(a + self.rows_per_page, block.shape[0])
                out.append(block[a:b])
            i = j + 1
        return out

    def close(self):
        if self._mmap is not None:
            self._mmap = None
            if self._owns:
                try:
                    os.unlink(self.path)
                except OSError:
                    pass

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
