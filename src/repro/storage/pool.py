"""Buffer pool: a byte-denominated memory budget over `EntityStore` pages.

Semantics (the §3.5.2 storage economics, made physical):

  * `get_row(id)` / `touch(id)` — the probe read path. A resident page is
    a HIT ("pool" tier: answered from memory); a non-resident page is a
    MISS ("disk" tier: one `EntityStore.read_page` cold read, then the
    page is admitted and the budget enforced by eviction).
  * eviction — clock (second-chance): a sweep clears reference bits and
    evicts the first unreferenced, UNPINNED frame. Pinned frames are
    never evicted, whatever the budget says; if everything is pinned the
    pool overcommits rather than corrupting a pin.
  * pins — the §3.5.2 hot buffers are pinned pool pages. `repin_rows`
    pins the pages covering the new hot-buffer window (faulting them in
    as prefetches, not misses) before unpinning the old window, capped so
    pins alone never exceed the budget.
  * `warm(ids)` — prefetch pages of `ids` IN ORDER until the budget is
    full, never evicting. Reorganization calls this with the entities in
    boundary-outward eps order: the rows most likely to miss the waters
    (the band) are exactly the rows made resident — the paper's index
    idea, the eps order IS the locality order.

Counters reconcile by construction: hits + misses == probes (every
`get_row`/`touch` call is exactly one of the two); warming is counted
separately as `prefetches`.

Thread safety: the pool is shared by every concurrent session of the SQL
server, so ONE reentrant lock guards every compound invariant — the
(`frames`, `_clock`, `_hand`, `resident_bytes`) quartet mutated by
admission/eviction, the pin bookkeeping, and the counters. Without it two
concurrent `get_row` calls can both miss the same page (double-admitting
it and double-counting `resident_bytes`), and a clock sweep interleaved
with `pin_rows` can evict a page between its admission and its
`pin_count += 1` — exactly the races the regression test hammers. Reads
of a resident row copy the slot under the lock; the mmap `read_page` cold
read happens inside the lock too (correctness first — the async/prefetch
I/O path can move it out later by admitting a placeholder frame).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Tuple

import numpy as np

from repro.analysis.witness import wrap
from repro.storage.store import EntityStore


@dataclasses.dataclass
class Frame:
    data: np.ndarray           # (rows_in_page, d) float32, private copy
    pin_count: int = 0
    ref: bool = True           # clock reference bit


class BufferPool:
    def __init__(self, store: EntityStore, budget_bytes: int):
        self.store = store
        # the pool must be able to hold at least one page
        self.budget_bytes = max(int(budget_bytes), store.page_bytes)
        # reentrant: repin_rows -> pin_rows -> _admit all hold it
        self._lock = wrap(threading.RLock(), "pool")
        self.frames: Dict[int, Frame] = {}
        self._clock: List[int] = []                # page ids, clock order
        self._hand = 0
        self.resident_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.prefetches = 0
        self._hot_pins: List[int] = []             # pages pinned for hot buffers

    # -- read path -----------------------------------------------------
    @property
    def probes(self) -> int:
        return self.hits + self.misses

    def resident(self, entity_id: int) -> bool:
        with self._lock:
            return int(self.store.dir_page[entity_id]) in self.frames

    def touch(self, entity_id: int) -> Tuple[np.ndarray, str]:
        """Read one entity row; returns (row, "pool"|"disk")."""
        pid = int(self.store.dir_page[entity_id])
        slot = int(self.store.dir_slot[entity_id])
        with self._lock:
            fr = self.frames.get(pid)
            if fr is not None:
                fr.ref = True
                self.hits += 1
                return fr.data[slot], "pool"
            self.misses += 1
            fr = self._admit(pid)
            return fr.data[slot], "disk"

    def get_row(self, entity_id: int) -> np.ndarray:
        return self.touch(entity_id)[0]

    # -- admission / eviction ------------------------------------------
    def _admit(self, pid: int, *, prefetch: bool = False) -> Frame:
        fr = Frame(self.store.read_page(pid))
        self.frames[pid] = fr
        self._clock.append(pid)
        self.resident_bytes += fr.data.nbytes
        if prefetch:
            self.prefetches += 1
        else:
            self._evict_to_budget()
        return fr

    def _evict_to_budget(self):
        """Clock sweep until resident_bytes <= budget or nothing is
        evictable (all frames pinned -> overcommit rather than drop a pin)."""
        skipped = 0
        while self.resident_bytes > self.budget_bytes and self._clock:
            if skipped > 2 * len(self._clock):
                break                               # only pinned frames left
            if self._hand >= len(self._clock):
                self._hand = 0
            pid = self._clock[self._hand]
            fr = self.frames[pid]
            if fr.pin_count > 0:
                self._hand += 1
                skipped += 1
                continue
            if fr.ref:
                fr.ref = False                      # second chance
                self._hand += 1
                skipped += 1
                continue
            del self.frames[pid]
            self._clock.pop(self._hand)             # hand now at the next frame
            self.resident_bytes -= fr.data.nbytes
            self.evictions += 1
            skipped = 0

    # -- pins (hot buffers) --------------------------------------------
    def _ordered_pages(self, entity_ids: Iterable[int]) -> np.ndarray:
        """Unique pages of `entity_ids`, in first-appearance order. Fully
        vectorized: callers hand this the whole n-entity eps order on
        every reorganization, so any Python-loop dedup here would put an
        O(n) pass on the maintenance path. Consumers iterate the result
        lazily and break as soon as the budget is spent."""
        ids = np.asarray(entity_ids
                         if isinstance(entity_ids, np.ndarray)
                         else list(entity_ids), np.int64)
        if ids.size == 0:
            return ids
        pages = self.store.dir_page[ids]
        _, first = np.unique(pages, return_index=True)
        return pages[np.sort(first)]

    def pinned_bytes(self) -> int:
        with self._lock:
            return sum(fr.data.nbytes for fr in self.frames.values()
                       if fr.pin_count > 0)

    def pin_rows(self, entity_ids: Iterable[int]) -> List[int]:
        """Pin the pages covering `entity_ids` (in first-appearance order),
        faulting absent ones in as prefetches. Pins are capped so that the
        pinned set alone never exceeds the budget (at least one page is
        always pinned if any id was given). Returns the pinned page ids."""
        with self._lock:
            pinned: List[int] = []
            budget_left = self.budget_bytes - self.pinned_bytes()
            for pid in self._ordered_pages(entity_ids):
                pid = int(pid)
                size = self.store.page_nbytes(pid)
                if pinned and size > budget_left:
                    break
                fr = self.frames.get(pid)
                if fr is None:
                    fr = self._admit(pid, prefetch=True)
                fr.pin_count += 1
                fr.ref = True
                pinned.append(pid)
                budget_left -= size
            if pinned:
                self._evict_to_budget()
            return pinned

    def unpin(self, page_ids: Iterable[int]):
        with self._lock:
            for pid in page_ids:
                fr = self.frames.get(pid)
                if fr is not None and fr.pin_count > 0:
                    fr.pin_count -= 1

    def repin_rows(self, entity_ids: Iterable[int]):
        """Move the hot-buffer pin set to the pages of `entity_ids`. The
        OLD window is unpinned first so its pages release their budget
        claim before the new window's pin cap is computed — otherwise a
        full-budget window would cap its own replacement at ~one page.
        The whole move holds the pool lock, so no concurrent admission can
        sweep the briefly-unpinned overlap pages out from under the
        re-pin, and overlap pages are still resident when re-pinned."""
        with self._lock:
            self.unpin(self._hot_pins)
            self._hot_pins = self.pin_rows(entity_ids)
            self._evict_to_budget()

    # -- warming -------------------------------------------------------
    def warm(self, entity_ids: Iterable[int]):
        """Prefetch the pages of `entity_ids` IN ORDER until the budget is
        full; never evicts (already-resident pages just get a reference)."""
        with self._lock:
            for pid in self._ordered_pages(entity_ids):
                pid = int(pid)
                fr = self.frames.get(pid)
                if fr is not None:
                    fr.ref = True
                    continue
                if self.resident_bytes + self.store.page_nbytes(pid) \
                        > self.budget_bytes:
                    break
                self._admit(pid, prefetch=True)

    # -- introspection -------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return self._stats_locked()

    def _stats_locked(self) -> dict:
        probes = self.probes
        return {
            "budget_bytes": self.budget_bytes,
            "table_bytes": self.store.nbytes,
            "page_bytes": self.store.page_bytes,
            "pages_total": self.store.num_pages,
            "pages_resident": len(self.frames),
            "resident_bytes": self.resident_bytes,
            "pinned_pages": sum(1 for fr in self.frames.values()
                                if fr.pin_count > 0),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "prefetches": self.prefetches,
            "probes": probes,
            "hit_rate": self.hits / probes if probes else 1.0,
        }

    def close(self):
        """Drop every frame (the shared `EntityStore` is closed by its
        owner — several pools may share one store)."""
        with self._lock:
            self.frames.clear()
            self._clock.clear()
            self._hand = 0
            self.resident_bytes = 0
            self._hot_pins = []
