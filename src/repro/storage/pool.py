"""Buffer pool: a byte-denominated memory budget over `EntityStore` pages.

Semantics (the §3.5.2 storage economics, made physical):

  * `get_row(id)` / `touch(id)` — the probe read path. A resident page is
    a HIT ("pool" tier: answered from memory); a non-resident page is a
    MISS ("disk" tier: one `EntityStore.read_page` cold read, then the
    page is admitted and the budget enforced by eviction).
  * eviction — clock (second-chance): a sweep clears reference bits and
    evicts the first unreferenced, UNPINNED, settled frame. Pinned and
    in-flight frames are never evicted, whatever the budget says; if
    everything is pinned the pool overcommits rather than corrupting a pin.
  * pins — the §3.5.2 hot buffers are pinned pool pages. `repin_rows`
    pins the pages covering the new hot-buffer window (faulting them in
    as prefetches, not misses) before unpinning the old window, capped so
    pins alone never exceed the budget.
  * `warm(ids)` — prefetch pages of `ids` IN ORDER until the budget is
    full, never evicting. Reorganization calls this with the entities in
    boundary-outward eps order: the rows most likely to miss the waters
    (the band) are exactly the rows made resident — the paper's index
    idea, the eps order IS the locality order.

Counters reconcile by construction: hits + misses + coalesced == probes
(every `get_row`/`touch` call is exactly one of the three); warming is
counted separately as `prefetches`, background readahead as
`readahead_pages` (with `readahead_used` counting the first probe that
consumed each readahead page).

Thread safety + the ASYNC COLD-READ protocol: the pool is shared by every
concurrent session of the SQL server, so ONE reentrant lock guards every
compound invariant — the (`frames`, `_clock`, `_hand`, `resident_bytes`)
quartet mutated by admission/eviction, the pin bookkeeping, and the
counters. The mmap `read_page` copy, however, runs with NO lock held:

    miss ──▶ [lock] install placeholder Frame(data=None, latch) ──▶ [unlock]
              │                                                       │
              │  concurrent missers of the SAME page                  ▼
              └─▶ [lock] see data=None ─▶ [unlock] latch.wait()   read_page
                  (counted `coalesced`, NOT a second disk read)       │
                                                                      ▼
              [lock] publish data into the frame, evict to budget ──▶ latch.set()

A placeholder charges `resident_bytes` at install time (its size is known
from the page directory without reading anything), so budget accounting
never undercounts in-flight I/O; the clock sweep skips `data is None`
frames exactly like pinned ones. If the read fails, the placeholder is
removed, the error is stored on the frame, and every waiter re-raises it.
Waiters keep a reference to the frame OBJECT, so a page evicted between
publish and wake-up still hands them the (immutable, byte-exact) data.

`EntityStore.read_page`/`read_pages` assert — under `REPRO_LOCK_WITNESS=1`
— that the calling thread does NOT hold the pool lock, and the static
LCK004 rule (`repro.analysis.locks`) proves the same at rest: re-inlining
a disk read under the lock is a build error, not a perf regression.

Background readahead lives in `repro.storage.prefetch.Prefetcher`, which
feeds `_prefetch_pages` from its own thread; `pool.prefetcher` is the
attachment point the engines probe for.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.witness import wrap
from repro.obs.trace import span as _span
from repro.storage.store import EntityStore

#: placeholder frames installed per lock hold by the batched prefetch
#: path — bounds both lock hold time and the transient overshoot of the
#: evicting (streaming-readahead) mode to one batch of pages.
LOAD_BATCH_PAGES = 64


@dataclasses.dataclass
class Frame:
    data: Optional[np.ndarray]  # (rows_in_page, d) float32; None = IN FLIGHT
    nbytes: int                 # page size, charged to the budget at install
    pin_count: int = 0
    ref: bool = True            # clock reference bit
    latch: Optional[threading.Event] = None   # set when the load settles
    error: Optional[BaseException] = None     # loader failure, for waiters
    readahead: bool = False     # loaded by the Prefetcher, not yet consumed


class BufferPool:
    def __init__(self, store: EntityStore, budget_bytes: int, *, metrics=None):
        self.store = store
        # the pool must be able to hold at least one page
        self.budget_bytes = max(int(budget_bytes), store.page_bytes)
        # optional MetricsRegistry: cold-read spans record into
        # span.pool.read.seconds; counters stay local (see stats()).
        self._metrics = metrics
        # reentrant: repin_rows -> pin_rows -> install helpers all hold it
        self._lock = wrap(threading.RLock(), "pool")
        self.frames: Dict[int, Frame] = {}
        self._clock: List[int] = []                # page ids, clock order
        self._hand = 0
        self.resident_bytes = 0
        self.hits = 0
        self.misses = 0
        self.coalesced = 0          # probes that waited on another's read
        self.in_flight = 0          # gauge: placeholder frames outstanding
        self.evictions = 0
        self.prefetches = 0         # warm()/pin fault-ins
        self.readahead_pages = 0    # pages loaded by the Prefetcher
        self.readahead_used = 0     # readahead pages a probe then consumed
        self._hot_pins: List[int] = []             # pages pinned for hot buffers
        self.prefetcher = None      # Prefetcher attaches itself here

    # -- read path -----------------------------------------------------
    @property
    def probes(self) -> int:
        return self.hits + self.misses + self.coalesced

    def resident(self, entity_id: int) -> bool:
        with self._lock:
            return int(self.store.dir_page[entity_id]) in self.frames

    def touch(self, entity_id: int) -> Tuple[np.ndarray, str]:
        """Read one entity row; returns (row, "pool"|"disk")."""
        data, how = self._page(int(self.store.dir_page[entity_id]))
        return data[int(self.store.dir_slot[entity_id])], how

    def get_row(self, entity_id: int) -> np.ndarray:
        return self.touch(entity_id)[0]

    def _page(self, pid: int) -> Tuple[np.ndarray, str]:
        """Resolve one page: hit, coalesced wait, or loader miss. The cold
        `read_page` copy runs with NO lock held (see the module doc)."""
        while True:
            with self._lock:
                fr = self.frames.get(pid)
                if fr is None:
                    fr = self._install_placeholder(pid)
                    self.misses += 1
                    latch = fr.latch
                    break                          # -> loader path below
                fr.ref = True
                if fr.readahead:
                    fr.readahead = False
                    self.readahead_used += 1
                if fr.data is not None:
                    self.hits += 1
                    return fr.data, "pool"
                self.coalesced += 1                # someone else is reading
                latch = fr.latch
            latch.wait()                           # park OFF the lock
            if fr.error is not None:
                raise fr.error
            if fr.data is not None:                # frame object outlives
                return fr.data, "disk"             # any eviction race
            # loader dropped the frame without data or error: retry
        try:
            with _span("pool.read", metrics=self._metrics, pages=1):
                data = self.store.read_page(pid)   # THE cold read, unlocked
        except BaseException as e:
            with self._lock:
                fr.error = e
                self._drop_inflight(pid, fr)
            latch.set()
            raise
        with self._lock:
            self._publish(pid, fr, data)
            self._evict_to_budget()
        latch.set()
        return data, "disk"

    # -- admission / eviction (helpers suffixed-by-contract: callers hold
    # the pool lock; none of them block) -------------------------------
    def _install_placeholder(self, pid: int) -> Frame:
        fr = Frame(None, self.store.page_nbytes(pid),
                   latch=threading.Event())
        self.frames[pid] = fr
        self._clock.append(pid)
        self.resident_bytes += fr.nbytes           # charged while in flight
        self.in_flight += 1
        return fr

    def _publish(self, pid: int, fr: Frame, data: np.ndarray):
        fr.data = data
        fr.ref = True
        self.in_flight = max(0, self.in_flight - 1)

    def _drop_inflight(self, pid: int, fr: Frame):
        """Remove a placeholder whose read failed (waiters re-raise via
        `fr.error`; the frame object keeps carrying it after removal)."""
        if self.frames.get(pid) is fr:
            del self.frames[pid]
            self._clock.remove(pid)
            if self._hand >= len(self._clock):
                self._hand = 0
            self.resident_bytes -= fr.nbytes
        self.in_flight = max(0, self.in_flight - 1)

    def _evict_to_budget(self):
        """Clock sweep until resident_bytes <= budget or nothing is
        evictable (pinned/in-flight only -> overcommit rather than drop
        a pin or rip a page out from under its loader)."""
        skipped = 0
        while self.resident_bytes > self.budget_bytes and self._clock:
            if skipped > 2 * len(self._clock):
                break                       # only pinned/in-flight left
            if self._hand >= len(self._clock):
                self._hand = 0
            pid = self._clock[self._hand]
            fr = self.frames[pid]
            if fr.pin_count > 0 or fr.data is None:
                self._hand += 1
                skipped += 1
                continue
            if fr.ref:
                fr.ref = False                      # second chance
                self._hand += 1
                skipped += 1
                continue
            del self.frames[pid]
            self._clock.pop(self._hand)             # hand now at the next frame
            self.resident_bytes -= fr.nbytes
            self.evictions += 1
            skipped = 0

    def _load_frames(self, loads: Sequence[Tuple[int, Frame]]):
        """Read + publish placeholder frames installed by THIS caller.
        One batched `read_pages` (contiguous runs collapse to single mmap
        copies), NO lock held during the I/O."""
        latches = [fr.latch for _, fr in loads]
        try:
            with _span("pool.read", metrics=self._metrics, pages=len(loads)):
                datas = self.store.read_pages([pid for pid, _ in loads])
        except BaseException as e:
            with self._lock:
                for pid, fr in loads:
                    fr.error = e
                    self._drop_inflight(pid, fr)
            for latch in latches:
                latch.set()
            raise
        with self._lock:
            for (pid, fr), data in zip(loads, datas):
                self._publish(pid, fr, data)
        for latch in latches:
            latch.set()

    # -- pins (hot buffers) --------------------------------------------
    def _ordered_pages(self, entity_ids: Iterable[int]) -> np.ndarray:
        """Unique pages of `entity_ids`, in first-appearance order. Fully
        vectorized: callers hand this the whole n-entity eps order on
        every reorganization, so any Python-loop dedup here would put an
        O(n) pass on the maintenance path. Consumers iterate the result
        lazily and break as soon as the budget is spent."""
        ids = np.asarray(entity_ids
                         if isinstance(entity_ids, np.ndarray)
                         else list(entity_ids), np.int64)
        if ids.size == 0:
            return ids
        pages = self.store.dir_page[ids]
        _, first = np.unique(pages, return_index=True)
        return pages[np.sort(first)]

    def _pinned_bytes_locked(self, exclude: Iterable[int] = ()) -> int:
        ex = set(int(p) for p in exclude)
        return sum(fr.nbytes for pid, fr in self.frames.items()
                   if fr.pin_count > 0 and pid not in ex)

    def pinned_bytes(self) -> int:
        with self._lock:
            return self._pinned_bytes_locked()

    def pin_rows(self, entity_ids: Iterable[int]) -> List[int]:
        """Pin the pages covering `entity_ids` (in first-appearance order),
        faulting absent ones in as prefetches. Pins are capped so that the
        pinned set alone never exceeds the budget (at least one page is
        always pinned if any id was given). Returns the pinned page ids."""
        return self._pin_pages(self._ordered_pages(entity_ids), exclude=())

    def _pin_pages(self, pages: np.ndarray, *,
                   exclude: Iterable[int]) -> List[int]:
        """Pin `pages` up to the budget cap, with `exclude`'s pages not
        charged against the cap (repin: the old window releases its claim).
        Absent pages are installed as PINNED placeholders under the lock
        and their reads run after the lock is released — a concurrent
        sweep can never reclaim them mid-fault."""
        with self._lock:
            budget_left = self.budget_bytes - self._pinned_bytes_locked(
                exclude)
            targets: List[int] = []
            loads: List[Tuple[int, Frame]] = []
            for pid in pages:
                pid = int(pid)
                size = self.store.page_nbytes(pid)
                if targets and size > budget_left:
                    break
                fr = self.frames.get(pid)
                if fr is None:
                    fr = self._install_placeholder(pid)
                    self.prefetches += 1
                    loads.append((pid, fr))
                fr.pin_count += 1
                fr.ref = True
                targets.append(pid)
                budget_left -= size
        if loads:
            self._load_frames(loads)
        if targets:
            with self._lock:
                self._evict_to_budget()
        return targets

    def unpin(self, page_ids: Iterable[int]):
        with self._lock:
            for pid in page_ids:
                fr = self.frames.get(pid)
                if fr is not None and fr.pin_count > 0:
                    fr.pin_count -= 1

    def repin_rows(self, entity_ids: Iterable[int]):
        """Move the hot-buffer pin set to the pages of `entity_ids`. The
        NEW window is pinned first with the OLD window's pages excluded
        from the budget cap (they release their claim at the same move,
        so a full-budget window never caps its own replacement), then the
        old pins are dropped. Overlap pages are double-pinned for the
        duration — pin_count never dips to 0 — so no concurrent sweep can
        evict them mid-move, without holding the lock across the fault-in
        reads."""
        old = self._hot_pins
        self._hot_pins = self._pin_pages(self._ordered_pages(entity_ids),
                                         exclude=old)
        self.unpin(old)
        with self._lock:
            self._evict_to_budget()

    # -- warming / readahead -------------------------------------------
    def warm(self, entity_ids: Iterable[int]):
        """Prefetch the pages of `entity_ids` IN ORDER until the budget is
        full; never evicts (already-resident pages just get a reference).
        The reads run OFF the lock in placeholder batches."""
        self._prefetch_pages(self._ordered_pages(entity_ids), evict=False)

    def _prefetch_pages(self, pages, *, evict: bool = False,
                        readahead: bool = False,
                        batch: int = LOAD_BATCH_PAGES) -> int:
        """Load absent pages IN ORDER: `batch` placeholders installed per
        lock hold, then one batched read with no lock held. evict=False
        stops at the budget (warm semantics); evict=True keeps streaming
        and sweeps after each batch (scan readahead — transient overshoot
        bounded by one batch). Returns the number of pages loaded."""
        pages = [int(p) for p in np.asarray(pages).ravel()]
        batch = max(1, min(int(batch),
                           self.budget_bytes // self.store.page_bytes or 1))
        loaded, i, full = 0, 0, False
        while i < len(pages) and not full:
            loads: List[Tuple[int, Frame]] = []
            with self._lock:
                while i < len(pages) and len(loads) < batch:
                    pid = pages[i]
                    fr = self.frames.get(pid)
                    if fr is not None:
                        fr.ref = True
                        i += 1
                        continue
                    size = self.store.page_nbytes(pid)
                    if not evict and (self.resident_bytes + size
                                      > self.budget_bytes):
                        full = True                # budget full: stop, but
                        break                      # still load this batch
                    fr = self._install_placeholder(pid)
                    if readahead:
                        fr.readahead = True
                        self.readahead_pages += 1
                    else:
                        self.prefetches += 1
                    loads.append((pid, fr))
                    i += 1
            if loads:
                self._load_frames(loads)
                loaded += len(loads)
                if evict:
                    with self._lock:
                        self._evict_to_budget()
        return loaded

    # -- introspection -------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return self._stats_locked()

    def _stats_locked(self) -> dict:
        probes = self.probes
        return {
            "budget_bytes": self.budget_bytes,
            "table_bytes": self.store.nbytes,
            "page_bytes": self.store.page_bytes,
            "pages_total": self.store.num_pages,
            "pages_resident": len(self.frames),
            "resident_bytes": self.resident_bytes,
            "pinned_pages": sum(1 for fr in self.frames.values()
                                if fr.pin_count > 0),
            "hits": self.hits,
            "misses": self.misses,
            "coalesced": self.coalesced,
            "in_flight": self.in_flight,
            "evictions": self.evictions,
            "prefetches": self.prefetches,
            "readahead_pages": self.readahead_pages,
            "readahead_used": self.readahead_used,
            "readahead_hit_rate": (self.readahead_used / self.readahead_pages
                                   if self.readahead_pages else 1.0),
            "probes": probes,
            "hit_rate": self.hits / probes if probes else 1.0,
        }

    def close(self):
        """Drop every frame (the shared `EntityStore` is closed by its
        owner — several pools may share one store)."""
        with self._lock:
            self.frames.clear()
            self._clock.clear()
            self._hand = 0
            self.resident_bytes = 0
            self.in_flight = 0
            self._hot_pins = []
