"""Memory-budgeted storage tier behind the §3.5.2 hybrid probe.

The paper's third contribution (§3.5.2/Fig. 8) is an index structure that
lets the system keep only a *fraction* of the entities in memory; until
this package existed, our "disk" probe tier was backed by the fully
in-RAM feature table, so the hit-rate numbers measured probe routing but
not storage economics. This package supplies the missing physical layer:

  * `EntityStore` (store.py) — an on-disk entity table: fixed-stride
    float32 feature rows in one memory-mapped file, split into pages, with
    a page directory keyed by entity id. Reading a page is the unit of
    "disk" I/O.
  * `BufferPool` (pool.py) — a byte-denominated memory budget over those
    pages: clock (second-chance) eviction, pin counts (the §3.5.2 hot
    buffers are PINNED pool pages, never separately materialized copies),
    prefetch-warming along the eps clustering order (the paper's index
    idea: the eps order IS the locality order), and per-tier hit / miss /
    eviction counters that make `BENCH_storage.json` mean something
    physical. Cold reads run OFF the pool lock behind per-page latches
    (miss coalescing: concurrent missers of one page share one read).
  * `Prefetcher` (prefetch.py) — a background readahead worker fed by
    the engines: band-probe misses and reorganize schedules stream their
    eps-order page windows into the pool while serving continues.

The engine shells (`core/hazy.py`, `core/multiview.py`) take an optional
`store=BufferPool(...)`; when present, every probe that the waters cannot
resolve goes through `BufferPool.get_row(entity_id)` instead of an in-RAM
`F[id]` index, and the probe reports tier "pool" (page was resident) or
"disk" (cold page read). `CREATE CLASSIFICATION VIEW ... WITH
(memory_budget = ...)` and `SHOW STORAGE` expose residency through SQL.
"""
from repro.storage.pool import BufferPool
from repro.storage.prefetch import Prefetcher
from repro.storage.store import PAGE_BYTES, EntityStore

__all__ = ["BufferPool", "EntityStore", "PAGE_BYTES", "Prefetcher"]
