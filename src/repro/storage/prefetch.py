"""Background readahead along the eps order (`Prefetcher`).

The paper's §3.5.2 index argument — the eps clustering order IS the disk
locality order — means the storage layer can *predict* cold reads: a band
probe that misses at eps-position p will very likely be followed by
probes at p+1, p+2, ... boundary-outward. The `Prefetcher` turns that
prediction into overlapped I/O: engines enqueue entity-id schedules
(band windows on a miss, the whole eps order on reorganize) and a single
daemon worker streams the corresponding pages into the pool via
`BufferPool._prefetch_pages` — batched `read_pages` with no pool lock
held during the copies, placeholder frames keeping concurrent probes
coalesced rather than duplicated.

Contract:
  * bounded queue (`max_queue` schedules; newest-dropped when full —
    readahead is advisory, dropping it only costs a future miss);
  * budget-respecting: `evict=False` schedules stop at the pool budget
    (warm semantics), `evict=True` streams and sweeps (scan readahead);
    neither ever evicts a pinned or in-flight frame (pool invariant);
  * clean shutdown: `close()` drains the queue, joins the worker, and
    detaches from `pool.prefetcher`; idempotent; `drain()` lets tests
    and benchmarks wait for quiescence.

The worker never holds its own condition variable while calling into the
pool, so `prefetcher cv` sits entirely outside the `gate < wal_commit <
pool` order — no new lock-order edge for the witness to police.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Iterable, Tuple

import numpy as np


class Prefetcher:
    """One daemon thread feeding `pool._prefetch_pages` from a bounded
    queue of (pages, evict) schedules. Attaches itself as
    `pool.prefetcher`; engines discover it with `getattr`."""

    def __init__(self, pool, *, max_queue: int = 256, batch_pages: int = 32):
        self.pool = pool
        self.max_queue = int(max_queue)
        self.batch_pages = int(batch_pages)
        self._cv = threading.Condition()
        self._queue: deque = deque()        # of (np.ndarray pages, evict)
        self._idle = threading.Event()
        self._idle.set()
        self._closed = False
        self.enqueued = 0
        self.dropped = 0                    # schedules shed on overflow
        self.errors = 0
        self._thread = threading.Thread(target=self._run,
                                        name="repro-prefetcher", daemon=True)
        self._thread.start()
        pool.prefetcher = self

    # -- producers -----------------------------------------------------
    def enqueue(self, entity_ids: Iterable[int], *, evict: bool = False):
        """Schedule the pages of `entity_ids` (first-appearance order).
        evict=False warms until the budget is full; evict=True streams
        (scan readahead). Page mapping happens on the CALLER's thread —
        `_ordered_pages` is pure and lock-free — so the worker only does
        I/O."""
        pages = self.pool._ordered_pages(entity_ids)
        if pages.size:
            self.enqueue_pages(pages, evict=evict)

    def enqueue_pages(self, pages: np.ndarray, *, evict: bool = False):
        with self._cv:
            if self._closed:
                return
            if len(self._queue) >= self.max_queue:
                self.dropped += 1           # advisory: shed, don't block
                return
            self._queue.append((pages, bool(evict)))
            self.enqueued += 1
            self._idle.clear()
            self._cv.notify()

    # -- worker --------------------------------------------------------
    def _run(self):
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._idle.set()
                    self._cv.wait()
                if self._closed and not self._queue:
                    self._idle.set()
                    return
                pages, evict = self._queue.popleft()
            try:                            # cv released: I/O off ALL locks
                self.pool._prefetch_pages(pages, evict=evict,
                                          readahead=True,
                                          batch=self.batch_pages)
            except Exception:
                self.errors += 1            # advisory path: log-and-go

    # -- lifecycle -----------------------------------------------------
    def drain(self, timeout: float = 10.0) -> bool:
        """Block until the queue is empty and the worker is parked."""
        return self._idle.wait(timeout)

    def close(self, timeout: float = 5.0):
        """Stop the worker: shed queued schedules, join, detach."""
        with self._cv:
            self._closed = True
            self._queue.clear()
            self._cv.notify_all()
        self._thread.join(timeout)
        if getattr(self.pool, "prefetcher", None) is self:
            self.pool.prefetcher = None

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def stats(self) -> dict:
        with self._cv:
            return {
                "enqueued": self.enqueued,
                "dropped": self.dropped,
                "errors": self.errors,
                "queued": len(self._queue),
                "alive": self._thread.is_alive(),
            }
