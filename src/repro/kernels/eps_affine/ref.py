"""Pure-jnp oracle for eps_affine."""
import jax.numpy as jnp


def eps_affine_ref(F, w, b):
    eps = jnp.einsum("nd,d->n", F.astype(jnp.float32), w.astype(jnp.float32)) - b
    labels = jnp.where(eps >= 0, 1, -1).astype(jnp.int8)
    return eps, labels, jnp.sum((eps >= 0).astype(jnp.int32))
