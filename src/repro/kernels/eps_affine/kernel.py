"""eps_affine Pallas kernel: eps = F @ w − b, fused sign + positive count.

This is the paper's relabel-everything pass (naive eager update / the eps
recompute inside reorganization). It is purely memory-bound (2 flops per
feature byte), so the kernel's job is to stream F through VMEM in
MXU-aligned (block_n × d) tiles exactly once, producing all three outputs
in one pass: eps (fp32), labels (int8), per-tile positive counts (int32 —
reduced by the wrapper; keeping the reduction in-kernel avoids a second
pass over eps for the paper's All-Members counter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _eps_kernel(w_ref, b_ref, f_ref, eps_ref, lab_ref, cnt_ref):
    f = f_ref[...].astype(jnp.float32)          # (bn, d)
    w = w_ref[...].astype(jnp.float32)          # (1, d)
    eps = jnp.sum(f * w, axis=1, keepdims=True) - b_ref[0, 0]   # (bn, 1)
    eps_ref[...] = eps
    lab = jnp.where(eps >= 0, 1, -1).astype(jnp.int8)
    lab_ref[...] = lab
    cnt_ref[0, 0] = jnp.sum((eps >= 0).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def eps_affine(F, w, b, *, block_n: int = 512, interpret: bool = False):
    """F: (n, d) [n % block_n == 0, d % 128 == 0 for TPU]; w: (d,); b: ().

    Returns (eps (n,) f32, labels (n,) int8, pos_count () i32)."""
    n, d = F.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    eps, lab, cnt = pl.pallas_call(
        _eps_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),          # w broadcast
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # b broadcast
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),    # F tile
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int8),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(w[None, :], b.reshape(1, 1), F)
    return eps[:, 0], lab[:, 0], jnp.sum(cnt)
