"""Public wrapper for eps_affine: pads n to the tile size, d to lanes."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.eps_affine.kernel import eps_affine as _kernel


def eps_affine(F, w, b, *, block_n: int = 512, interpret: bool = False):
    n, d = F.shape
    dp = -(-d // 128) * 128
    npad = -(-n // block_n) * block_n
    if dp != d:
        F = jnp.pad(F, ((0, 0), (0, dp - d)))
        w = jnp.pad(w, (0, dp - d))
    if npad != n:
        F = jnp.pad(F, ((0, npad - n), (0, 0)))
    b = jnp.asarray(b, jnp.float32)
    eps, lab, cnt = _kernel(F, w, b, block_n=block_n, interpret=interpret)
    eps, lab = eps[:n], lab[:n]
    # padded rows contribute eps = −b; correct the fused count
    if npad != n:
        cnt = cnt - jnp.sum((jnp.zeros(npad - n) - b >= 0).astype(jnp.int32))
    return eps, lab, cnt
