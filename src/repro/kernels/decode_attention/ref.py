"""Pure-jnp oracle for decode attention."""
import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, cache_index):
    """q: (b, nkv, group, hd); k/v: (b, S, nkv, hd)."""
    b, nkv, group, hd = q.shape
    S = k.shape[1]
    logits = jnp.einsum("bngd,bsnd->bngs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    valid = (jnp.arange(S) <= cache_index)[None, None, None]
    logits = jnp.where(valid, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bngs,bsnd->bngd", probs, v.astype(jnp.float32)).astype(q.dtype)
