"""Public wrapper for decode attention: (b, 1, nq, hd) model layout in/out."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention as _kernel


def decode_attention(q, k_cache, v_cache, cache_index, *, block_s: int = 512,
                     interpret: bool = False):
    """q: (b, 1, nq, hd); caches: (b, S, nkv, hd). Returns (b, 1, nq, hd)."""
    b, one, nq, hd = q.shape
    nkv = k_cache.shape[2]
    group = nq // nkv
    S = k_cache.shape[1]
    bs = min(block_s, S)
    qg = q[:, 0].reshape(b, nkv, group, hd)
    out = _kernel(qg, k_cache, v_cache, jnp.asarray(cache_index, jnp.int32),
                  block_s=bs, interpret=interpret)
    return out.reshape(b, 1, nq, hd)
