"""Single-token GQA decode attention over a long KV cache (Pallas TPU).

Grid (b, nkv, s_blocks): each program block holds the q-head *group* for one
kv head (GQA handled by layout, zero KV duplication) and one KV-sequence
tile; the online-softmax state lives in VMEM scratch. The valid-length mask
comes from a scalar-prefetch cache index, so one compiled kernel serves
every decode position (flash-decoding on the sequence axis is the `model`-
mesh sharding of the caller — inside a shard this kernel streams its local
KV tile range).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_s: int):
    isb = pl.program_id(2)
    n_blocks = pl.num_programs(2)

    @pl.when(isb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (group, bs)
    pos = isb * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos <= idx_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new

    @pl.when(isb == n_blocks - 1)
    def _write():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k, v, cache_index, *, block_s: int = 512,
                     interpret: bool = False):
    """q: (b, nkv, group, hd); k/v: (b, S, nkv, hd); cache_index: () i32.

    Returns (b, nkv, group, hd)."""
    b, nkv, group, hd = q.shape
    S = k.shape[1]
    assert S % block_s == 0
    scale = hd ** -0.5
    grid = (b, nkv, S // block_s)
    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    idx = cache_index.reshape(1).astype(jnp.int32)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, hd), lambda ib, ik, isb, s_: (ib, ik, 0, 0)),
                pl.BlockSpec((1, block_s, 1, hd), lambda ib, ik, isb, s_: (ib, isb, ik, 0)),
                pl.BlockSpec((1, block_s, 1, hd), lambda ib, ik, isb, s_: (ib, isb, ik, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda ib, ik, isb, s_: (ib, ik, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, nkv, group, hd), q.dtype),
        interpret=interpret,
    )(idx, q, k, v)
