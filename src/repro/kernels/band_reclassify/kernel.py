"""band_reclassify Pallas kernel — the paper's incremental step as a kernel.

Only tiles overlapping the water band [start, start+width) are streamed
HBM→VMEM: the grid covers a fixed `cap`-row window and the scalar-prefetch
`start_block` shifts every tile's index map, so HBM traffic is ∝ band size,
not N (tile-granular version of "read only the B+-tree range"). Labels are
updated in place via input/output aliasing — out-of-band rows inside the
window are preserved with a predicated merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _band_kernel(scalars_ref,           # (2,) i32: [start_block, width]
                 w_ref, b_ref, f_ref, lab_in_ref, lab_out_ref):
    i = pl.program_id(0)
    width = scalars_ref[1]
    bn = f_ref.shape[0]
    f = f_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    eps = jnp.sum(f * w, axis=1, keepdims=True) - b_ref[0, 0]
    new = jnp.where(eps >= 0, 1, -1).astype(jnp.int8)
    offs = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
    in_band = offs < width
    lab_out_ref[...] = jnp.where(in_band, new, lab_in_ref[...])


def _mv_band_kernel(scalars_ref,        # (2, k) i32: [start_block_v; width_v]
                    w_ref, b_ref, f_ref, lab_in_ref, lab_out_ref):
    v = pl.program_id(0)
    i = pl.program_id(1)
    width = scalars_ref[1, v]
    bn = f_ref.shape[0]
    f = f_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    eps = jnp.sum(f * w, axis=1)[None, :] - b_ref[0, 0]
    new = jnp.where(eps >= 0, 1, -1).astype(jnp.int8)
    offs = i * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    in_band = offs < width
    lab_out_ref[...] = jnp.where(in_band, new, lab_in_ref[...])


@functools.partial(jax.jit, static_argnames=("cap", "block_n", "interpret"))
def multiview_band_reclassify(F, labels, W, b, start_blocks, widths, *,
                              cap: int = 4096, block_n: int = 512,
                              interpret: bool = False):
    """Union-band relabel for k views over ONE shared scratch table.

    F: (n, d) — the shared eps-clustered scratch table (one clustering for
    all views, the multi-view engine's shared-table layout); labels:
    (k, n) int8, row v aligned to the SAME row order as F, updated in
    place; W: (k, d); b: (k,); start_blocks/widths: (k,) i32 — per-view
    windows in units of block_n rows.

    Grid is (k, cap // block_n): program (v, i) streams the i-th tile of
    view v's window and relabels it under view v's model. Each view's
    window must COVER its true eps band in the shared order — relabeling a
    superset is exact, because relabeling recomputes sign(w_v·f − b_v),
    the correct current label for ANY row; the band only bounds which rows
    may have changed. Per-view windows are positioned independently via
    the scalar-prefetch starts, so one launch touches the union of the k
    (covering) bands — HBM traffic ∝ Σ_v window_v, not k·n."""
    k, n = labels.shape
    n2, d = F.shape
    assert n == n2 and cap % block_n == 0 and n % block_n == 0
    grid = (k, cap // block_n)
    scalars = jnp.stack([start_blocks.astype(jnp.int32),
                         widths.astype(jnp.int32)])

    out = pl.pallas_call(
        _mv_band_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda v, i, s: (v, 0)),
                pl.BlockSpec((1, 1), lambda v, i, s: (v, 0)),
                pl.BlockSpec((block_n, d), lambda v, i, s: (s[0, v] + i, 0)),
                pl.BlockSpec((1, block_n), lambda v, i, s: (v, s[0, v] + i)),
            ],
            out_specs=pl.BlockSpec((1, block_n), lambda v, i, s: (v, s[0, v] + i)),
        ),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.int8),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(scalars, W, b.reshape(-1, 1).astype(jnp.float32), F, labels)
    return out


@functools.partial(jax.jit, static_argnames=("cap", "block_n", "interpret"))
def band_reclassify(F_sorted, labels, w, b, start_block, width, *,
                    cap: int = 4096, block_n: int = 512,
                    interpret: bool = False):
    """F_sorted: (n, d); labels: (n, 1) int8 (updated in place);
    start_block: () i32 — band start in units of block_n rows;
    width: () i32 — band rows counted from the window start.

    Returns updated labels. HBM reads: cap rows of F + cap labels only."""
    n, d = F_sorted.shape
    assert cap % block_n == 0 and n % block_n == 0
    grid = (cap // block_n,)
    scalars = jnp.stack([start_block.astype(jnp.int32), width.astype(jnp.int32)])

    out = pl.pallas_call(
        _band_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, d), lambda i, s: (0, 0)),
                pl.BlockSpec((1, 1), lambda i, s: (0, 0)),
                pl.BlockSpec((block_n, d), lambda i, s: (s[0] + i, 0)),
                pl.BlockSpec((block_n, 1), lambda i, s: (s[0] + i, 0)),
            ],
            out_specs=pl.BlockSpec((block_n, 1), lambda i, s: (s[0] + i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int8),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(scalars, w[None, :], b.reshape(1, 1), F_sorted, labels)
    return out
