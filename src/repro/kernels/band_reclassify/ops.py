"""Public wrapper: aligns the band window to tile boundaries and clamps it."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.band_reclassify.kernel import band_reclassify as _kernel
from repro.kernels.band_reclassify.ref import band_reclassify_ref


def band_reclassify(F_sorted, labels, w, b, start_row, end_row, *,
                    cap: int = 4096, block_n: int = 512,
                    interpret: bool = False):
    """Relabel rows [start_row, end_row) of the eps-sorted table under (w,b).

    labels: (n,) int8. The window is tile-aligned and capacity-clamped; the
    caller (SKIING driver) must ensure end_row − aligned_start ≤ cap."""
    n, d = F_sorted.shape
    start_row = jnp.asarray(start_row, jnp.int32)
    end_row = jnp.asarray(end_row, jnp.int32)
    start_block = jnp.clip(start_row // block_n, 0,
                           max(0, (n - cap) // block_n))
    width = jnp.clip(end_row - start_block * block_n, 0, cap)
    out = _kernel(F_sorted, labels[:, None], w, jnp.asarray(b, jnp.float32),
                  start_block, width, cap=cap, block_n=block_n,
                  interpret=interpret)
    return out[:, 0]
